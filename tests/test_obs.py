"""Observability layer tests (obs/metrics, obs/tracing, engine wiring).

Acceptance (ISSUE 6):
  (a) registry semantics — label sets, idempotent/conflicting declaration,
      atomic snapshot, histogram bucket math + quantiles, Prometheus text;
  (b) per-request traces span queued -> prefill -> decode -> retired with
      monotonic timestamps, including rejection / cancellation paths;
  (c) the registry-backed stats view and the legacy dict agree exactly
      (cross-checked per key, including the spec-decode engine);
  (d) disabled mode is the NULL sentinel: plain-dict stats, no trace or
      metric objects, and greedy token streams BITWISE identical to the
      instrumented engine (paged and mesh-sharded);
  (e) the quantization-health probe reports finite per-site values with
      the paper's Table-1 ordering (MS-EDEN < plain SR relative MSE);
  (f) serve-layer hygiene: no print()/logging calls in src/repro/serve
      (all reporting flows through the obs hook).
"""

import ast
import json
import math
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import registry as arch_registry
from repro.models import lm
from repro.obs import (NULL, STAT_FLOAT_KEYS, STAT_KEYS, Instrumentation,
                       MetricsRegistry, RequestTrace, TraceSink,
                       legacy_stats_dict)
from repro.obs import tracing
from repro.serve.engine import (EngineConfig, QueueFull, Request, ServeEngine)

pytestmark = pytest.mark.obs


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

class TestRegistry:
    def test_counter_and_gauge(self):
        reg = MetricsRegistry()
        c = reg.counter("c", "a counter")
        c.inc()
        c.inc(2.5)
        assert c.get() == 3.5
        g = reg.gauge("g")
        g.set(7)
        g.inc(-3)
        assert g.get() == 4.0

    def test_label_series(self):
        reg = MetricsRegistry()
        c = reg.counter("req", labels=("route", "code"))
        c.labels("a", "200").inc()
        c.labels(route="a", code="200").inc()  # same series, by name
        c.labels("a", "500").inc()
        assert reg.value("req", route="a", code="200") == 2.0
        assert reg.value("req", route="a", code="500") == 1.0
        assert reg.value("req", route="b", code="200") == 0.0  # untouched

    def test_label_errors(self):
        reg = MetricsRegistry()
        c = reg.counter("c", labels=("x",))
        with pytest.raises(ValueError):
            c.labels()                       # missing value
        with pytest.raises(ValueError):
            c.labels("a", "b")               # too many
        with pytest.raises(ValueError):
            c.labels(y="a")                  # unknown name
        with pytest.raises(ValueError):
            c.inc()                          # labelled metric used bare

    def test_declare_idempotent_and_conflicting(self):
        reg = MetricsRegistry()
        a = reg.counter("m", labels=("x",))
        assert reg.counter("m", labels=("x",)) is a   # idempotent
        with pytest.raises(ValueError):
            reg.gauge("m", labels=("x",))             # kind conflict
        with pytest.raises(ValueError):
            reg.counter("m", labels=("y",))           # label conflict

    def test_histogram_bucket_math(self):
        reg = MetricsRegistry()
        h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.5, 3.0, 100.0):
            h.observe(v)
        child = h.labels()
        assert child.count == 4
        assert child.sum == 105.0
        assert child.counts == [1, 1, 1, 1]           # per-bucket
        assert child.cumulative() == [1, 2, 3, 4]     # prometheus-style
        assert child.buckets[-1] == math.inf          # +Inf auto-appended

    def test_histogram_quantile(self):
        reg = MetricsRegistry()
        h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
        assert math.isnan(h.quantile(0.5))            # empty
        for v in (0.5, 1.5, 3.0, 100.0):
            h.observe(v)
        # rank 2 lands exactly at the top of the (1, 2] bucket
        assert h.quantile(0.5) == 2.0
        assert 0.0 < h.quantile(0.1) <= 1.0
        # q in the +Inf bucket degrades to the last finite bound
        assert h.quantile(1.0) == 4.0

    def test_snapshot_shape_and_atomicity(self):
        reg = MetricsRegistry()
        reg.counter("c", "help!", labels=("k",)).labels(k="v").inc(3)
        reg.histogram("h", buckets=(1.0,)).observe(0.5)
        snap = reg.snapshot()
        assert snap["c"]["type"] == "counter"
        assert snap["c"]["series"] == [{"labels": {"k": "v"}, "value": 3.0}]
        hs = snap["h"]["series"][0]
        assert hs["count"] == 1 and hs["sum"] == 0.5
        assert hs["buckets"][0] == (1.0, 1)
        # snapshot is a copy: later updates don't mutate it
        reg.counter("c", labels=("k",)).labels(k="v").inc()
        assert snap["c"]["series"][0]["value"] == 3.0

    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("c_total", "hits", labels=("k",)).labels(k="v").inc(2)
        reg.histogram("lat", buckets=(1.0,)).observe(0.5)
        text = reg.to_prometheus()
        assert "# TYPE c_total counter" in text
        assert 'c_total{k="v"} 2' in text
        assert "# TYPE lat histogram" in text
        assert 'lat_bucket{le="1.0"} 1' in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert "lat_sum 0.5" in text
        assert "lat_count 1" in text
        json.loads(reg.to_json())  # valid JSON exposition too

    def test_child_registry_const_labels(self):
        reg = MetricsRegistry()
        child = reg.child(engine="7")
        child.counter("ticks_total").inc(4)
        child.histogram("step_s", labels=("phase",),
                        buckets=(1.0,)).labels(phase="synced").observe(0.1)
        assert reg.value("ticks_total", engine="7") == 4.0
        snap = reg.snapshot()
        assert snap["step_s"]["series"][0]["labels"] == {
            "engine": "7", "phase": "synced"}


# --------------------------------------------------------------------------
# tracing
# --------------------------------------------------------------------------

class TestTracing:
    def _retired_trace(self, req_id=0):
        tr = RequestTrace(req_id)
        tr.begin(tracing.QUEUED, 1.0)
        tr.end(tracing.QUEUED, 2.0)
        tr.begin(tracing.PREFILL, 2.0)
        tr.end(tracing.PREFILL, 5.0)
        tr.begin(tracing.DECODE, 5.0)
        tr.end(tracing.DECODE, 9.0, tokens=5)
        tr.finish(tracing.RETIRED, 9.0)
        return tr

    def test_span_ordering_and_latencies(self):
        tr = self._retired_trace()
        names = [s.name for s in tr.spans]
        assert names == ["queued", "prefill", "decode", "retired"]
        for s in tr.spans:
            assert s.t1 is not None and s.t1 >= s.t0
        t0s = [s.t0 for s in tr.spans]
        assert t0s == sorted(t0s)                       # monotonic
        assert tr.queue_wait_s == 1.0
        assert tr.ttft_s == 4.0                         # submit -> 1st token
        assert tr.decode_tok_s(5) == 1.0                # 4s / (5 - 1) tokens
        assert tr.state == tracing.RETIRED

    def test_finish_closes_open_spans(self):
        tr = RequestTrace(1)
        tr.begin(tracing.QUEUED, 0.0)
        tr.end(tracing.QUEUED, 1.0)
        tr.begin(tracing.PREFILL, 1.0)
        tr.finish(tracing.CANCELLED, 3.0)               # prefill still open
        assert tr.span(tracing.PREFILL).t1 == 3.0
        assert tr.spans[-1].name == tracing.CANCELLED
        assert tr.state == tracing.CANCELLED

    def test_sink_bounded_with_drop_count(self):
        sink = TraceSink(max_traces=2)
        for i in range(5):
            sink.append(self._retired_trace(i))
        assert len(sink.traces) == 2
        assert sink.dropped == 3
        assert [t.req_id for t in sink.traces] == [3, 4]  # oldest dropped
        assert sink.aggregates()["dropped"] == 3

    def test_aggregates_over_retired_only(self):
        sink = TraceSink()
        sink.append(self._retired_trace(0))
        rej = RequestTrace(1)
        rej.finish(tracing.REJECTED, 0.0)
        sink.append(rej)
        agg = sink.aggregates()
        assert agg["retired"] == 1 and agg["total"] == 2
        assert agg["ttft_s"]["count"] == 1
        assert agg["ttft_s"]["p50"] == 4.0
        assert agg["queue_wait_s"]["mean"] == 1.0

    def test_write_jsonl(self, tmp_path):
        sink = TraceSink()
        sink.append(self._retired_trace(0))
        path = tmp_path / "trace.jsonl"
        n = sink.write_jsonl(str(path))
        events = [json.loads(line) for line in path.read_text().splitlines()]
        assert n == len(events) == 4
        assert [e["span"] for e in events] == [
            "queued", "prefill", "decode", "retired"]
        assert all(e["state"] == "retired" for e in events)
        assert all(e["t1"] >= e["t0"] for e in events)


# --------------------------------------------------------------------------
# engine wiring
# --------------------------------------------------------------------------

def _cfg():
    return arch_registry.get("llama_200m").reduced()


def _params(cfg):
    return lm.init(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens=(9, 13)):
    rng = np.random.RandomState(1)
    return [list(map(int, rng.randint(0, cfg.vocab, n))) for n in lens]


def _engine(cfg, params, obs=None, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("prequant", False)
    return ServeEngine(cfg, params, EngineConfig(obs=obs, **kw))


class TestEngineInstrumentation:
    def test_lifecycle_counters_traces_and_result_latencies(self):
        cfg = _cfg()
        eng = _engine(cfg, _params(cfg),
                      obs=Instrumentation(registry=MetricsRegistry()))
        prompts = _prompts(cfg)
        ids = [eng.submit(Request(prompt=p, max_new=3)) for p in prompts]
        results = {r.req_id: r for r in eng.run()}
        obs, reg = eng.obs, eng.obs.registry

        # (c) registry counters == legacy stats surface, key for key
        for k in STAT_KEYS:
            name = (f"serve_engine_{k[:-2]}_seconds_total"
                    if k in STAT_FLOAT_KEYS else f"serve_engine_{k}_total")
            assert reg.value(name, engine=obs.engine_label) == pytest.approx(
                eng.stats[k]), k
        assert eng.stats["finished"] == len(prompts)

        # per-request latencies surfaced on the results
        for i in ids:
            r = results[i]
            assert r.queue_wait_s is not None and r.queue_wait_s >= 0
            assert r.ttft_s is not None and r.ttft_s >= r.queue_wait_s
            assert r.decode_tok_s is not None and r.decode_tok_s > 0

        # (b) every retired trace runs the full span ladder, monotonic
        assert len(obs.trace_sink.traces) == len(prompts)
        for tr in obs.trace_sink.traces:
            assert tr.state == tracing.RETIRED
            names = [s.name for s in tr.spans]
            assert names == ["queued", "prefill", "decode", "retired"]
            ts = [t for s in tr.spans for t in (s.t0, s.t1)]
            assert ts == sorted(ts)
        agg = obs.trace_sink.aggregates()
        assert agg["retired"] == len(prompts)
        assert agg["ttft_s"]["count"] == len(prompts)

        # prometheus exposition carries the telemetry families
        text = obs.prometheus()
        for family in ("serve_queue_depth", "serve_slots",
                       "serve_pool_free_blocks",
                       "serve_pool_fragmentation_ratio",
                       "serve_request_ttft_seconds_bucket",
                       "serve_decode_step_seconds_bucket",
                       "serve_engine_decode_tokens_total"):
            assert family in text, family
        # all slots free again at the final tick
        assert reg.value("serve_slots", engine=obs.engine_label,
                         state="free") == eng.econf.n_slots
        # step histograms saw both phases; synced >= dispatch (the cache
        # sync is included in synced only)
        dec = reg.get("serve_decode_step_seconds")
        disp = dec.labels(engine=obs.engine_label, phase="dispatch")
        sync = dec.labels(engine=obs.engine_label, phase="synced")
        assert disp.count == sync.count == eng.stats["decode_steps"]
        assert sync.sum >= disp.sum

    def test_rejection_traces(self):
        cfg = _cfg()
        eng = _engine(cfg, _params(cfg), max_queue=1,
                      obs=Instrumentation(registry=MetricsRegistry()))
        eng.submit(Request(prompt=[1, 2, 3], max_new=2))
        with pytest.raises(QueueFull):
            eng.submit(Request(prompt=[4, 5, 6], max_new=2))
        with pytest.raises(ValueError):  # unservable: exceeds pool capacity
            eng.queue.clear()
            eng.submit(Request(prompt=list(range(500)), max_new=2))
        reasons = [tr.spans[-1].attrs.get("reason")
                   for tr in eng.obs.trace_sink.traces
                   if tr.state == tracing.REJECTED]
        assert reasons == ["queue_full", "unservable"]
        assert eng.stats["rejected"] == 2

    def test_cancel_queued_and_inflight(self):
        cfg = _cfg()
        eng = _engine(cfg, _params(cfg), n_slots=1,
                      obs=Instrumentation(registry=MetricsRegistry()))
        free0 = eng.pool.free_block_count
        p1, p2 = _prompts(cfg)
        i1 = eng.submit(Request(prompt=p1, max_new=4))
        i2 = eng.submit(Request(prompt=p2, max_new=4))
        eng.step()                       # admits i1, leaves i2 queued
        assert eng.cancel(i2) is True    # queued-path cancel
        eng.step()
        assert eng.cancel(i1) is True    # in-flight cancel frees the slot
        assert eng.cancel(i1) is False   # unknown now
        assert not eng.has_work()
        assert eng.pool.free_block_count == free0   # blocks conserved
        assert eng.stats["cancelled"] == 2
        states = sorted(tr.state for tr in eng.obs.trace_sink.traces)
        assert states == ["cancelled", "cancelled"]

    def test_stats_view_is_dict_compatible(self):
        cfg = _cfg()
        eng = _engine(cfg, _params(cfg),
                      obs=Instrumentation(registry=MetricsRegistry()))
        # same key set + iteration order as the legacy dict
        assert list(eng.stats) == list(legacy_stats_dict())
        eng.submit(Request(prompt=[1, 2, 3], max_new=2))
        eng.run()
        assert isinstance(eng.stats["decode_tokens"], int)
        assert isinstance(eng.stats["decode_s"], float)
        # the bench reset idiom writes through to the registry
        for k in eng.stats:
            eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0
        assert dict(eng.stats) == legacy_stats_dict()
        assert eng.obs.registry.value(
            "serve_engine_decode_tokens_total",
            engine=eng.obs.engine_label) == 0.0
        with pytest.raises(TypeError):
            del eng.stats["ticks"]       # fixed key set

    def test_spec_engine_acceptance_histogram(self):
        cfg = _cfg()
        obs = Instrumentation(registry=MetricsRegistry())
        eng = _engine(cfg, _params(cfg), spec_k=2, draft_layers=1, obs=obs)
        for p in _prompts(cfg):
            eng.submit(Request(prompt=p, max_new=6))
        eng.run()
        assert eng.stats["spec_rounds"] > 0
        hist = obs.registry.get("serve_spec_accepted_per_round")
        child = hist.labels(engine=obs.engine_label)
        assert child.count == eng.stats["spec_rounds"]
        assert child.sum == eng.stats["accepted_tokens"]

    def test_prefix_cache_counters(self):
        cfg = _cfg()
        obs = Instrumentation(registry=MetricsRegistry())
        eng = _engine(cfg, _params(cfg), prefix_cache=True, obs=obs)
        shared = _prompts(cfg, lens=(24,))[0]
        eng.submit(Request(prompt=list(shared), max_new=2))
        eng.run()                                     # primes the cache
        eng.submit(Request(prompt=shared + [5, 6, 7], max_new=2))
        eng.run()                                     # aliases the prefix
        label = obs.engine_label
        assert obs.registry.value("serve_prefix_cache_hits_total",
                                  engine=label) == eng.stats["prefix_hits"]
        assert obs.registry.value(
            "serve_prefix_cache_hit_tokens_total",
            engine=label) == eng.stats["prefill_skipped_tokens"] > 0
        assert obs.registry.value("serve_pool_blocks_allocated_total",
                                  engine=label) > 0


# --------------------------------------------------------------------------
# disabled mode + determinism
# --------------------------------------------------------------------------

def _tokens(cfg, params, prompts, obs=None, **kw):
    eng = _engine(cfg, params, obs=obs, **kw)
    ids = [eng.submit(Request(prompt=p, max_new=4)) for p in prompts]
    res = {r.req_id: r for r in eng.run()}
    return [res[i].tokens for i in ids], [res[i] for i in ids]


class TestDisabledAndDeterminism:
    def test_disabled_mode_is_null_sentinel(self):
        cfg = _cfg()
        eng = _engine(cfg, _params(cfg))     # obs=None
        assert eng.obs is NULL
        assert NULL.enabled is False
        assert type(eng.stats) is dict       # plain legacy dict, no view
        # the sentinel carries NOTHING: any accidental hook use fails loudly
        with pytest.raises(AttributeError):
            NULL.on_submit
        with pytest.raises(AttributeError):
            NULL.extra = 1                   # slotted: no attr creation
        _, results = _tokens(cfg, _params(cfg), _prompts(cfg))
        assert all(r.ttft_s is None and r.queue_wait_s is None
                   for r in results)

    def test_streams_bitwise_unchanged_paged(self):
        cfg, params = _cfg(), None
        params = _params(cfg)
        prompts = _prompts(cfg)
        plain, _ = _tokens(cfg, params, prompts)
        traced, _ = _tokens(cfg, params, prompts,
                            obs=Instrumentation(registry=MetricsRegistry()))
        assert plain == traced

    @pytest.mark.skipif(jax.device_count() < 2,
                        reason="simulated mesh needs >= 2 host devices")
    def test_streams_bitwise_unchanged_sharded(self):
        from repro.launch.mesh import make_serve_mesh
        cfg = _cfg()
        params = _params(cfg)
        prompts = _prompts(cfg)
        mesh = make_serve_mesh(2, 1)
        plain, _ = _tokens(cfg, params, prompts, mesh=mesh)
        traced, _ = _tokens(cfg, params, prompts, mesh=mesh,
                            obs=Instrumentation(registry=MetricsRegistry()))
        assert plain == traced


# --------------------------------------------------------------------------
# quantization-health probe
# --------------------------------------------------------------------------

class TestQuantProbe:
    def test_probe_values_and_table1_ordering(self):
        from repro.obs.quant_probe import QuantProbe
        cfg = _cfg()
        params = _params(cfg)
        reg = MetricsRegistry()
        probe = QuantProbe(scheme="quartet2", max_sites=2, registry=reg)
        out = probe.probe_params(params, phase="prequant")
        assert out
        for site, vals in out.items():
            assert all(math.isfinite(v) for v in vals.values())
            # paper Table 1 on real weights: MS-EDEN beats plain SR
            assert vals["ms_eden_mse_rel"] < vals["sr_mse_rel"]
            assert 0.0 <= vals["fwd_scale_sat_frac"] <= 1.0
            assert 0.0 <= vals["rht_outlier_mass"] < 0.5
            assert reg.value("nvfp4_quant_mse_rel", site=site,
                             phase="prequant", quantizer="ms_eden"
                             ) == pytest.approx(vals["ms_eden_mse_rel"])
        assert reg.value("nvfp4_probe_samples_total",
                         phase="prequant") == len(out)

    def test_probe_deterministic(self):
        from repro.obs.quant_probe import QuantProbe
        cfg = _cfg()
        params = _params(cfg)
        a = QuantProbe(max_sites=2, registry=MetricsRegistry())
        b = QuantProbe(max_sites=2, registry=MetricsRegistry())
        assert a.probe_params(params, step=5) == b.probe_params(params, step=5)

    def test_should_sample_schedule(self):
        from repro.obs.quant_probe import QuantProbe
        probe = QuantProbe(registry=MetricsRegistry())
        assert not any(probe.should_sample(s) for s in range(10))  # off
        probe.every_n = 5
        assert [s for s in range(11) if probe.should_sample(s)] == [0, 5, 10]


# --------------------------------------------------------------------------
# serve-layer hygiene (satellite: everything reports through obs)
# --------------------------------------------------------------------------

def test_no_print_or_logging_in_serve_layer():
    serve_dir = (pathlib.Path(__file__).resolve().parent.parent
                 / "src" / "repro" / "serve")
    offenders = []
    for path in sorted(serve_dir.glob("*.py")):
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name) and f.id == "print":
                offenders.append(f"{path.name}:{node.lineno} print()")
            if (isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name)
                    and f.value.id == "logging"):
                offenders.append(f"{path.name}:{node.lineno} logging call")
    assert not offenders, offenders
