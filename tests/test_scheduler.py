"""Latency-aware scheduler policies (serve/scheduler.py).

Ordering-only behavior, pinned on a tiny 1-layer model (the scheduler never
touches numerics — stream-parity claims live in test_serve.py):

  - the default policy (scheduler=None -> FifoPolicy) reproduces the
    pre-policy engine exactly: submission-order admission with head-of-line
    blocking, lowest-index prefill slot;
  - under a saturated queue, a high-priority request with a deadline is
    admitted before older low-priority requests;
  - no request starves: tick-based aging lifts a waiting request's
    effective priority above any fixed competitor within a provable bound;
  - a latency-critical admission preempts the prefill queue (its prompt
    chunks run before an older, lower-priority slot's remaining chunks);
  - non-head-of-line admission lets a small fitting request overtake a
    large one the pool cannot back yet;
  - with the prefix cache, a larger cached prefix sorts first among
    otherwise-equal requests (cache-aware admission).
"""

import jax
import pytest

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.scheduler import FifoPolicy, LatencyPolicy, SchedulerPolicy

pytestmark = pytest.mark.serve


def _cfg() -> ArchConfig:
    """Smallest decode-capable arch: scheduling is numerics-agnostic."""
    return ArchConfig(name="sched-test", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=32,
                      vocab=64, head_dim=16)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, lm.init(cfg, jax.random.PRNGKey(0))


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("n_slots", 1)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 16)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("prequant", False)
    return ServeEngine(cfg, params, EngineConfig(**kw))


def _prompt(np_rng, n=8):
    return list(map(int, np_rng.randint(0, 64, n)))


# --------------------------------------------------------------------------
# default policy == the original FIFO engine
# --------------------------------------------------------------------------

def test_default_policy_is_fifo(model, np_rng):
    eng = _engine(model)
    assert isinstance(eng.sched, FifoPolicy)
    assert eng.sched.head_of_line
    # priorities/deadlines are IGNORED by the throughput-shaped default:
    # completion stays in submission order under a saturated queue
    reqs = [Request(prompt=_prompt(np_rng), max_new=2,
                    priority=p, deadline_s=0.01 if p else None)
            for p in (0, 3, 9, 1)]
    ids = [eng.submit(r) for r in reqs]
    done = [r.req_id for r in eng.run()]
    assert done == ids


def test_explicit_fifo_matches_default(model, np_rng):
    prompts = [_prompt(np_rng) for _ in range(4)]

    def run(sched):
        eng = _engine(model, n_slots=2, scheduler=sched)
        ids = [eng.submit(Request(prompt=p, max_new=3)) for p in prompts]
        return [(r.req_id, r.tokens) for r in eng.run()], ids

    a, ids_a = run(None)
    b, ids_b = run(FifoPolicy())
    assert [t for _, t in a] == [t for _, t in b]      # same streams
    assert [i for i, _ in a] == ids_a and [i for i, _ in b] == ids_b


def test_base_policy_hooks_are_fifo():
    reqs = [Request(prompt=[1], max_new=1, req_id=i) for i in range(3)]
    pol = SchedulerPolicy()
    assert pol.admission_order(reqs, 0.0) == reqs
    assert pol.pick_prefill([(2, None), (5, None)], 0.0) == 2


# --------------------------------------------------------------------------
# priority + deadline admission
# --------------------------------------------------------------------------

def test_high_priority_deadline_admitted_before_older_low(model, np_rng):
    """Acceptance: saturated queue, ONE slot — the high-priority deadline
    request (submitted LAST) is admitted before every queued low-priority
    request, so it finishes right after the in-flight one."""
    eng = _engine(model, scheduler=LatencyPolicy(aging_ticks=10_000))
    low = [eng.submit(Request(prompt=_prompt(np_rng), max_new=2))
           for _ in range(4)]
    hi = eng.submit(Request(prompt=_prompt(np_rng), max_new=2,
                            priority=5, deadline_s=0.25))
    done = [r.req_id for r in eng.run()]
    assert done.index(hi) < min(done.index(i) for i in low)


def test_deadline_slack_breaks_priority_ties(model, np_rng):
    """Equal priority: the tighter deadline is admitted first even when
    submitted later (EDF within a priority class)."""
    eng = _engine(model, scheduler=LatencyPolicy(aging_ticks=10_000))
    loose = eng.submit(Request(prompt=_prompt(np_rng), max_new=2,
                               deadline_s=60.0))
    tight = eng.submit(Request(prompt=_prompt(np_rng), max_new=2,
                               deadline_s=0.05))
    none = eng.submit(Request(prompt=_prompt(np_rng), max_new=2))
    done = [r.req_id for r in eng.run()]
    assert done.index(tight) < done.index(loose) < done.index(none)


def test_results_carry_latency_and_deadline(model, np_rng):
    eng = _engine(model)
    eng.submit(Request(prompt=_prompt(np_rng), max_new=2, deadline_s=120.0))
    eng.submit(Request(prompt=_prompt(np_rng), max_new=2))
    res = eng.run()
    assert all(r.latency_s > 0 for r in res)
    assert res[0].deadline_met is True          # two tiny requests < 120s
    assert res[1].deadline_met is None          # no deadline set


# --------------------------------------------------------------------------
# starvation-free aging
# --------------------------------------------------------------------------

def test_aging_bounds_starvation(model, np_rng):
    """A priority-0 request under a continuous stream of priority-3
    arrivals is admitted once aging lifts it past them: queued_ticks at
    admission is bounded by (gap+1)*aging_ticks plus one slot-occupancy
    interval — asserted exactly via the engine's tick accounting."""
    aging, gap = 2, 3
    eng = _engine(model, scheduler=LatencyPolicy(aging_ticks=aging))
    low = Request(prompt=_prompt(np_rng), max_new=2)
    eng.submit(low)
    ticks_per_req = []
    admitted_at = None
    hi_done = 0
    t0 = None
    for tick in range(200):
        # keep the queue saturated with fresh high-priority work
        while len(eng.queue) < 2 or all(r.priority == 0 for r in eng.queue):
            eng.submit(Request(prompt=_prompt(np_rng), max_new=2,
                               priority=gap))
        done = eng.step()
        hi_done += sum(1 for r in done if r.req_id != low.req_id)
        if admitted_at is None and all(
                r.req_id != low.req_id for r in eng.queue):
            admitted_at = tick
            break
    assert admitted_at is not None, "low-priority request starved"
    # effective priority beats `gap` after (gap+1)*aging queue ticks; it
    # then waits at most one request's slot occupancy before a slot frees
    slot_interval = 8  # generous: 1 prefill + 2 decode + retire ticks << 8
    assert low.queued_ticks <= (gap + 1) * aging + slot_interval
    assert hi_done >= 1  # the stream actually competed (starvation threat)
    eng.run()


# --------------------------------------------------------------------------
# prefill preemption
# --------------------------------------------------------------------------

def test_latency_critical_preempts_prefill(model, np_rng):
    """A freshly admitted high-priority request's prompt chunks run before
    an older low-priority slot's remaining chunks; under FIFO the older
    slot finishes its prompt first."""
    long_a = _prompt(np_rng, 16)     # 4 chunks of 4
    long_b = _prompt(np_rng, 16)

    def first_to_finish_prefill(sched):
        eng = _engine(model, n_slots=2, prefill_chunk=4, scheduler=sched)
        a = eng.submit(Request(prompt=list(long_a), max_new=2))
        eng.step()                   # A admitted, first chunk done
        b = eng.submit(Request(prompt=list(long_b), max_new=2,
                               priority=7, deadline_s=0.25))
        order = []
        for _ in range(12):
            eng.step()
            for rid, slot in ((a, eng.slots[0]), (b, eng.slots[1])):
                if slot.req is not None and slot.state == "decode" \
                        and rid not in order:
                    order.append(rid)
            if len(order) == 2:
                break
        eng.run()
        return order, a, b

    order, a, b = first_to_finish_prefill(LatencyPolicy())
    assert order[0] == b             # B's prompt preempted A's
    order, a, b = first_to_finish_prefill(None)
    assert order[0] == a             # FIFO: lowest slot index first


# --------------------------------------------------------------------------
# non-head-of-line admission + cache-aware ordering
# --------------------------------------------------------------------------

def test_latency_policy_skips_unfittable_head(model, np_rng):
    """A large request the pool cannot back YET must not block a small one
    behind it under LatencyPolicy — and must still block it under FIFO
    (admission order observed directly; completion order would be
    confounded by request lengths)."""
    def admission_order(sched):
        eng = _engine(model, n_slots=2, max_len=64, block_size=16,
                      n_blocks=6, scheduler=sched)
        r1 = eng.submit(Request(prompt=[1] * 16, max_new=31))  # 3 blocks
        big = eng.submit(Request(prompt=[2] * 32, max_new=31))  # 4 blocks
        small = eng.submit(Request(prompt=[3] * 8, max_new=4))  # 1 block
        admitted = []
        while eng.has_work():
            eng.step()
            for s in eng.slots:
                if s.req is not None and s.req.req_id not in admitted:
                    admitted.append(s.req.req_id)
        return admitted, big, small

    adm, big, small = admission_order(LatencyPolicy())
    assert adm.index(small) < adm.index(big)   # overtook the blocked head
    adm, big, small = admission_order(None)
    assert adm.index(big) < adm.index(small)   # FIFO head-of-line


def test_cache_aware_admission_prefers_cached_prefix(model, np_rng):
    """Among equal-priority queued requests, the one with the larger cached
    prefix admits first (it is cheaper: its prefill is mostly skipped)."""
    cached_prompt = _prompt(np_rng, 16)
    other_prompt = _prompt(np_rng, 16)
    eng = _engine(model, block_size=4, prefix_cache=True,
                  scheduler=LatencyPolicy(aging_ticks=10_000))
    eng.submit(Request(prompt=list(cached_prompt), max_new=2))
    eng.run()                                    # prime the cache
    filler = eng.submit(Request(prompt=_prompt(np_rng), max_new=4))
    cold = eng.submit(Request(prompt=list(other_prompt), max_new=2))
    hot = eng.submit(Request(prompt=list(cached_prompt), max_new=2))
    done = [r.req_id for r in eng.run()]
    assert done.index(hot) < done.index(cold)    # cached-prefix first
    assert eng.stats["prefill_skipped_tokens"] > 0


def test_prefill_aging_prevents_preemption_starvation(model, np_rng):
    """Preemption must not starve an admitted prompt: slots passed over by
    pick_prefill keep aging (the engine bumps their queued_ticks), so a
    low-priority prompt sharing the prefill stage with a strictly
    higher-priority one still gets chunks BEFORE the high-priority prompt
    finishes — within the same (gap+1)*aging_ticks bound as admission."""
    eng = _engine(model, n_slots=2, prefill_chunk=1,
                  scheduler=LatencyPolicy(aging_ticks=2))
    a = eng.submit(Request(prompt=_prompt(np_rng, 12), max_new=2))
    b = eng.submit(Request(prompt=_prompt(np_rng, 12), max_new=2,
                           priority=5))

    def slot_of(rid):
        return next((s for s in eng.slots
                     if s.req is not None and s.req.req_id == rid), None)

    served_a_while_b_prefilling = False
    for _ in range(30):
        eng.step()
        sa, sb = slot_of(a), slot_of(b)
        if (sa is not None and sa.cursor > 0
                and sb is not None and sb.state == "prefill"):
            served_a_while_b_prefilling = True
            break
    # without slot aging the priority-5 prompt monopolizes every prefill
    # tick until its whole 12-token prompt is done
    assert served_a_while_b_prefilling
    eng.run()
