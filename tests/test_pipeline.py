"""Pipeline parallelism: GPipe schedule == sequential stage application."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.dist.pipeline import bubble_fraction, stack_stage_params


def test_bubble_fraction():
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    assert bubble_fraction(1, 8) == 0.0


def test_stack_stage_params():
    import jax.numpy as jnp
    p = {"w": jnp.arange(24).reshape(8, 3)}
    s = stack_stage_params(p, 4)
    assert s["w"].shape == (4, 2, 3)


def test_gpipe_matches_sequential():
    """4-stage pipe on 4 virtual devices == applying the 4 stages in order
    (subprocess: the test env exposes a single device)."""
    code = textwrap.dedent('''
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import shard_map  # version-compat wrapper
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.pipeline import gpipe

        S, M, MB, D = 4, 8, 2, 16
        mesh = Mesh(np.asarray(jax.devices()), ("pipe",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) / np.sqrt(D)
        xs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        run = gpipe(stage_fn, n_stages=S, n_micro=M)
        f = jax.jit(shard_map(run, mesh=mesh,
                              in_specs=(P("pipe"), P()), out_specs=P(),
                              check_vma=False))
        got = f(ws, xs)

        want = xs
        for s in range(S):
            want = jnp.tanh(want @ ws[s])
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-5, err
        print("OK", err)
    ''')
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd=os.getcwd())
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr


def test_gpipe_compressed_boundary():
    """NVFP4-compressed stage boundaries stay within quantization tolerance."""
    code = textwrap.dedent('''
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import shard_map  # version-compat wrapper
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.dist.pipeline import gpipe

        S, M, MB, D = 4, 4, 2, 32
        mesh = Mesh(np.asarray(jax.devices()), ("pipe",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) / np.sqrt(D)
        xs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
        stage_fn = lambda w, x: jnp.tanh(x @ w)
        f = jax.jit(shard_map(gpipe(stage_fn, S, M, compress=True), mesh=mesh,
                              in_specs=(P("pipe"), P()), out_specs=P(),
                              check_vma=False))
        got = f(ws, xs)
        want = xs
        for s in range(S):
            want = jnp.tanh(want @ ws[s])
        rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
        # ~9.5% RTN rel-err per NVFP4 boundary x 3 hops, partially damped by
        # tanh: bounded but aggressive (FP8 boundaries are the usual choice;
        # FP4 shown here for the wire-format plumbing)
        assert rel < 0.30, rel
        print("OK", rel)
    ''')
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, cwd=os.getcwd())
    assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr
