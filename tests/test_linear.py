"""QuartetLinear behaviour: gradient quality ordering, unbiasedness (Fig. 9),
scheme plumbing, packed residuals."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schemes as S
from repro.core.linear import qlinear

SEED = jnp.array([3, 7], jnp.uint32)


@pytest.fixture(scope="module")
def xw():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 256), jnp.bfloat16)
    w = (jax.random.normal(jax.random.PRNGKey(1), (384, 256)) / 16.0).astype(jnp.bfloat16)
    return x, w


def grads(x, w, scheme, seed=SEED):
    def loss(x, w):
        return jnp.sum(qlinear(x, w, seed, scheme).astype(jnp.float32) ** 2)
    return jax.grad(loss, (0, 1))(x, w)


ALL_SCHEMES = S.names()


@pytest.mark.parametrize("scheme", ALL_SCHEMES)
def test_all_schemes_run_and_finite(xw, scheme):
    x, w = xw
    y = qlinear(x, w, SEED, scheme)
    assert y.shape == (2, 64, 384) and y.dtype == x.dtype
    assert not bool(jnp.isnan(y.astype(jnp.float32)).any())
    dx, dw = grads(x, w, scheme)
    assert dx.shape == x.shape and dw.shape == w.shape
    assert not bool(jnp.isnan(dx.astype(jnp.float32)).any())
    assert not bool(jnp.isnan(dw.astype(jnp.float32)).any())


def test_bf16_scheme_is_exact_linear(xw):
    x, w = xw
    y = qlinear(x, w, SEED, "bf16")
    ref = jax.lax.dot_general(x, w, (((2,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    assert np.allclose(np.asarray(y, np.float32), np.asarray(ref), rtol=1e-2)


def test_forward_quant_error_ordering(xw):
    """4/6 < plain RTN < square-block forward error (paper Fig. 2 / Table 1)."""
    x, w = xw
    ref = np.asarray(qlinear(x, w, SEED, "bf16"), np.float32)

    def err(scheme):
        y = np.asarray(qlinear(x, w, SEED, scheme), np.float32)
        return np.linalg.norm(y - ref) / np.linalg.norm(ref)

    e_fos, e_rtn, e_sq = err("fwd_rtn_1x16_fos"), err("fwd_rtn_1x16"), err("fwd_square")
    assert e_fos < e_rtn < e_sq, (e_fos, e_rtn, e_sq)


def test_quartet2_beats_sr_baselines(xw):
    """Gradient error: quartet2 < tetrajet_v2 / nvidia (paper Fig. 4)."""
    x, w = xw
    rdx, rdw = grads(x, w, "bf16")

    def err(scheme, n=8):
        tot = 0.0
        for i in range(n):
            dx, dw = grads(x, w, scheme, jnp.array([11, i], jnp.uint32))
            tot += float(jnp.linalg.norm((dw - rdw).astype(jnp.float32)))
        return tot / n

    q2, tj, nv = err("quartet2"), err("tetrajet_v2"), err("nvidia")
    assert q2 < tj and q2 < nv, (q2, tj, nv)


def test_mseden_requant_beats_sr_norequant(xw):
    """Fig. 1 (e) vs (d): the paper's argument for dropping square blocks."""
    x, w = xw
    rdx, _ = grads(x, w, "bf16")

    def err(scheme, n=8):
        tot = 0.0
        for i in range(n):
            dx, _ = grads(x, w, scheme, jnp.array([13, i], jnp.uint32))
            tot += float(jnp.linalg.norm((dx - rdx).astype(jnp.float32)))
        return tot / n

    assert err("abl_e_ms_eden") < err("abl_d_sr")


def test_backward_unbiasedness_concentration():
    """Fig. 9: averaged quantized grad -> exact grad at rate ~1/B for the
    unbiased schemes; MS-EDEN has the lowest variance."""
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 256), jnp.float32)
    w = (jax.random.normal(jax.random.PRNGKey(1), (256, 256)) / 16).astype(jnp.float32)
    ct = jax.random.normal(jax.random.PRNGKey(2), (128, 256), jnp.float32)

    def gradw(seed, scheme):
        return jax.grad(lambda w: jnp.sum(qlinear(x, w, seed, scheme) * ct))(w)

    ref = gradw(jnp.array([0, 0], jnp.uint32), "bf16")

    def errs(scheme, batches=(8, 128)):
        f = jax.jit(jax.vmap(lambda s: gradw(s, scheme)))
        out = []
        for b in batches:
            seeds = jnp.stack([jnp.full((b,), 17, jnp.uint32),
                               jnp.arange(b, dtype=jnp.uint32)], -1)
            g = jnp.mean(f(seeds), 0)
            out.append(float(jnp.sum((g - ref) ** 2) / jnp.sum(ref ** 2)))
        return out

    e_eden = errs("abl_e_ms_eden")
    e_sr = errs("abl_e_sr")
    # 16x more samples -> ~16x lower error (allow slack for MC noise)
    assert e_eden[0] / e_eden[1] > 8, e_eden
    assert e_sr[0] / e_sr[1] > 8, e_sr
    # MS-EDEN variance < SR variance (paper's central claim)
    assert e_eden[0] < e_sr[0]


def test_padding_non_multiple_of_128_tokens():
    """dW inner dim M=batch*seq gets zero-padded; grads stay correct-shaped."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 64), jnp.bfloat16)  # M=48
    w = (jax.random.normal(jax.random.PRNGKey(1), (128, 64)) / 8).astype(jnp.bfloat16)
    dx, dw = grads(x, w, "quartet2")
    assert dx.shape == x.shape and dw.shape == w.shape
    assert not bool(jnp.isnan(dw.astype(jnp.float32)).any())


def test_determinism_given_seed(xw):
    x, w = xw
    a = grads(x, w, "quartet2", jnp.array([5, 5], jnp.uint32))
    b = grads(x, w, "quartet2", jnp.array([5, 5], jnp.uint32))
    assert np.array_equal(np.asarray(a[1], np.float32), np.asarray(b[1], np.float32))
    c = grads(x, w, "quartet2", jnp.array([5, 6], jnp.uint32))
    assert not np.array_equal(np.asarray(a[1], np.float32), np.asarray(c[1], np.float32))
