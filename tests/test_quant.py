"""Quantizer tests, including the paper's Table 1 MSE reproduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded-random fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import formats as F
from repro.core import ms_eden as ME
from repro.core import quant as Q
from repro.core import rht as R

pytestmark = pytest.mark.quant


@pytest.fixture(scope="module")
def gauss():
    return jax.random.normal(jax.random.PRNGKey(0), (2048, 1024), jnp.float32)


class TestTable1:
    """Paper Table 1: quadratic error over N(0,1), MSE x 1e-3.

    | RTN 1x16 | 9.0 |  | +4/6 | 7.6 |  | RTN 16x16 | 12.4 |
    | SR 1x16  | 23.5 |  | MS-EDEN | 9.4 |
    (tolerances cover sampling noise and grid-placement minutiae)
    """

    def test_rtn_1x16(self, gauss):
        m = float(Q.mse(gauss, Q.quant_rtn(gauss, s=Q.S_EDEN))) * 1e3
        assert 8.0 < m < 10.0, m

    def test_rtn_4over6(self, gauss):
        m = float(Q.mse(gauss, Q.quant_four_over_six(gauss))) * 1e3
        assert 6.8 < m < 8.4, m

    def test_rtn_square(self, gauss):
        m = float(Q.mse(gauss, Q.quant_square_block(gauss))) * 1e3
        assert 11.0 < m < 14.5, m

    def test_sr_1x16(self, gauss):
        m = float(Q.mse(gauss, Q.quant_sr(gauss, jax.random.PRNGKey(1)))) * 1e3
        assert 21.0 < m < 26.0, m

    def test_ms_eden(self, gauss):
        out = ME.ms_eden(gauss, jax.random.PRNGKey(2), jax.random.PRNGKey(3))
        deq = ME.ms_eden_dequant(out, rotated=False)
        m = float(jnp.mean((deq - gauss) ** 2)) * 1e3
        assert 8.4 < m < 10.6, m

    def test_ordering(self, gauss):
        """The paper's headline: MS-EDEN is unbiased with >2x lower MSE than SR."""
        sr = float(Q.mse(gauss, Q.quant_sr(gauss, jax.random.PRNGKey(1))))
        out = ME.ms_eden(gauss, jax.random.PRNGKey(2), jax.random.PRNGKey(3))
        eden = float(jnp.mean((ME.ms_eden_dequant(out, rotated=False) - gauss) ** 2))
        assert sr > 2.0 * eden


class TestQuantizerInvariants:
    SCHEMES = {
        "rtn": lambda x: Q.quant_rtn(x),
        "rtn_clip": lambda x: Q.quant_rtn(x, s=Q.S_EDEN),
        "fos": Q.quant_four_over_six,
        "sr": lambda x: Q.quant_sr(x, jax.random.PRNGKey(7)),
    }

    @pytest.mark.parametrize("name", sorted(SCHEMES))
    def test_scales_on_e4m3_grid(self, gauss, name):
        qt = self.SCHEMES[name](gauss[:64])
        s = np.asarray(qt.scales)
        assert np.array_equal(
            s, np.asarray(jnp.asarray(s).astype(jnp.float8_e4m3fn).astype(jnp.float32)))

    @pytest.mark.parametrize("name", sorted(SCHEMES))
    def test_codes_in_range(self, gauss, name):
        qt = self.SCHEMES[name](gauss[:64])
        assert int(qt.codes.max()) <= 15

    @pytest.mark.parametrize("name", sorted(SCHEMES))
    def test_zero_tensor(self, name):
        qt = self.SCHEMES[name](jnp.zeros((8, 64)))
        assert np.array_equal(np.asarray(Q.dequant(qt)), np.zeros((8, 64), np.float32))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(1e-4, 1e4))
    def test_scale_invariance(self, seed, scale):
        """Quantization relative error is invariant to per-tensor scaling."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (16, 128))
        a = Q.dequant(Q.quant_rtn(x))
        b = Q.dequant(Q.quant_rtn(x * scale))
        assert np.allclose(np.asarray(a) * scale, np.asarray(b), rtol=1e-4, atol=1e-6 * scale)

    def test_sr_never_clips(self):
        """Q_SR constants guarantee |x / (s_g * gscale)| <= 6 (unbiasedness)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (256, 64)) ** 3  # heavy tails
        qt = Q.quant_sr(x, jax.random.PRNGKey(1))
        denom = jnp.repeat(qt.scales, F.GROUP, -1) * qt.gscale
        ratio = jnp.abs(x) / jnp.where(denom == 0, 1.0, denom)
        assert float(ratio.max()) <= 6.0 + 1e-5

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(2 ** -20, 2 ** 20))
    def test_sr_scale_chain_boundary(self, seed, mag):
        """The 16/17 margin at its EDGE: groups whose absmax sits exactly at
        (and adversarially near) e4m3 binade boundaries, scaled across 40
        orders of magnitude. The e4m3-rounded group scales must never push
        a normalized value past the E2M1 grid edge — the boundary where the
        silent saturation bias of `fp4_sr` (now documented in its contract)
        would otherwise activate. Checked through the quant_sr chain AND
        the `fp4_overflow_fraction` debug detector."""
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (32, 64)) * mag
        # plant worst-case group maxima: absmax exactly on / just above the
        # value whose /(6*16/17) image lands mid-lattice in e4m3
        edge = mag * jnp.float32(6.0 * F.FP8_RTN_MARGIN)
        x = x.at[:, 0].set(edge * (1.0 + 2.0 ** -9))
        x = x.at[:, F.GROUP].set(-edge)
        qt = Q.quant_sr(x, jax.random.fold_in(key, 1))
        denom = jnp.repeat(qt.scales, F.GROUP, -1) * qt.gscale
        norm = x / jnp.where(denom == 0, 1.0, denom)
        assert float(jnp.abs(norm).max()) <= 6.0 + 1e-5
        assert float(F.fp4_overflow_fraction(norm)) == 0.0

    def test_fp4_sr_saturates_beyond_grid(self):
        """The documented out-of-contract behavior: |x| > 6 saturates
        DETERMINISTICALLY (a bias — which is exactly why the scale chain
        must prevent it, and why `fp4_overflow_fraction` exists to detect
        any caller that fails to)."""
        x = jnp.asarray([6.5, 100.0, -7.0, -1e6], jnp.float32)
        q = F.fp4_sr(x, jax.random.PRNGKey(0))
        assert np.array_equal(np.asarray(q), [6.0, 6.0, -6.0, -6.0])
        assert float(F.fp4_overflow_fraction(x)) == 1.0
        assert float(F.fp4_overflow_fraction(q)) == 0.0

    def test_square_block_scale_sharing(self, gauss):
        qt = Q.quant_square_block(gauss[:64, :64])
        s = np.asarray(qt.scales).reshape(4, 16, 4)
        assert (s == s[:, :1, :]).all()  # 16 rows of a tile share the scale


class TestRHT:
    def test_orthogonal(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 384))
        k = jax.random.PRNGKey(5)
        y = R.rht(x, k)
        assert np.allclose(np.asarray(R.rht_inv(y, k)), np.asarray(x), atol=1e-4)
        assert np.isclose(float(jnp.linalg.norm(y)), float(jnp.linalg.norm(x)), rtol=1e-5)

    def test_block_size_selection(self):
        assert R.block_size(1024) == 128
        assert R.block_size(1408) == 128
        assert R.block_size(192) == 64
        assert R.block_size(48) == 16
        with pytest.raises(ValueError):
            R.block_size(40)

    def test_gemm_cancellation(self):
        """(A @ DH)(B @ DH)^T == A B^T — why no inverse rotation is needed."""
        a = jax.random.normal(jax.random.PRNGKey(0), (16, 256))
        b = jax.random.normal(jax.random.PRNGKey(1), (24, 256))
        k = jax.random.PRNGKey(2)
        ref = a @ b.T
        rot = R.rht(a, k) @ R.rht(b, k).T
        assert np.allclose(np.asarray(rot), np.asarray(ref), atol=1e-3)


class TestMSEden:
    def test_unbiased_after_inverse_rotation(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (32, 128)) * 0.5

        def draw(i):
            k = jax.random.PRNGKey(i)
            o = ME.ms_eden(x, jax.random.fold_in(k, 0), jax.random.fold_in(k, 1))
            return ME.ms_eden_dequant(o, rotated=False)

        avg = jnp.mean(jax.vmap(draw)(jnp.arange(2048)), 0)
        rel = float(jnp.linalg.norm(avg - x) / jnp.linalg.norm(x))
        assert rel < 0.01, rel

    def test_lower_variance_than_sr(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 256))

        def eden_err(i):
            k = jax.random.PRNGKey(i)
            o = ME.ms_eden(x, jax.random.fold_in(k, 0), jax.random.fold_in(k, 1))
            d = ME.ms_eden_dequant(o, rotated=False) - x
            return jnp.sum(d * d)

        def sr_err(i):
            d = Q.dequant(Q.quant_sr(x, jax.random.PRNGKey(i))) - x
            return jnp.sum(d * d)

        e = float(jnp.mean(jax.vmap(eden_err)(jnp.arange(64))))
        s = float(jnp.mean(jax.vmap(sr_err)(jnp.arange(64))))
        assert s > 2.0 * e, (s, e)

    def test_posthoc_matches_direct_statistically(self):
        """ER-NVFP4 post-hoc path is a valid MS-EDEN: unbiased, similar MSE."""
        x = jax.random.normal(jax.random.PRNGKey(9), (64, 256))

        def draw(i):
            k = jax.random.PRNGKey(i)
            p1 = ME.ms_eden_phase1(x, jax.random.fold_in(k, 0))
            qt = ME.ms_eden_phase2(p1, jax.random.fold_in(k, 1))
            return R.rht_inv(Q.dequant(qt), jax.random.fold_in(k, 0))

        samples = jax.vmap(draw)(jnp.arange(1024))
        avg = jnp.mean(samples, 0)
        rel = float(jnp.linalg.norm(avg - x) / jnp.linalg.norm(x))
        assert rel < 0.02, rel
        mse = float(jnp.mean((samples[0] - x) ** 2))
        assert mse < 2.2e-2  # same ballpark as direct path on N(0,1)

    def test_posthoc_vs_direct_mse_parity(self):
        """phase1+phase2 vs direct `ms_eden` head-to-head on the SAME keys:
        the two paths are NOT bit-identical (the post-hoc path rounds
        through e8m3 pseudo-scales before the phase-2 global alignment, a
        different scale-rounding order), so parity is statistical — matched
        mean MSE within 10% over many key draws, and both unbiased (the
        unbiasedness halves are pinned by the two tests above)."""
        x = jax.random.normal(jax.random.PRNGKey(11), (64, 256))

        def direct_err(i):
            k = jax.random.PRNGKey(i)
            o = ME.ms_eden(x, jax.random.fold_in(k, 0),
                           jax.random.fold_in(k, 1))
            d = ME.ms_eden_dequant(o, rotated=False) - x
            return jnp.mean(d * d)

        def posthoc_err(i):
            k = jax.random.PRNGKey(i)
            p1 = ME.ms_eden_phase1(x, jax.random.fold_in(k, 0))
            qt = ME.ms_eden_phase2(p1, jax.random.fold_in(k, 1))
            d = R.rht_inv(Q.dequant(qt), jax.random.fold_in(k, 0)) - x
            return jnp.mean(d * d)

        de = float(jnp.mean(jax.vmap(direct_err)(jnp.arange(128))))
        pe = float(jnp.mean(jax.vmap(posthoc_err)(jnp.arange(128))))
        assert abs(pe - de) < 0.10 * de, (de, pe)

    def test_scales_within_fp8_after_correction(self):
        """FP8 cap 256 leaves room for the EDEN up-correction (Sec. 3.3)."""
        x = jax.random.normal(jax.random.PRNGKey(4), (128, 256)) ** 3
        o = ME.ms_eden(x, jax.random.PRNGKey(0), jax.random.PRNGKey(1))
        assert float(o.qt.scales.max()) <= F.FP8_MAX

    def test_unbiasedness_regression_vs_sr(self, base_key):
        """Statistical regression pin (paper Secs. 3-4): over fixed-seed
        draws on the same tensor, (i) the confidence interval of MS-EDEN's
        mean dequantization error contains 0 (unbiased), and (ii) MS-EDEN's
        MSE is decisively below SR's. Cheap enough for tier-1: 256 draws on
        a 32x128 tensor."""
        x = jax.random.normal(jax.random.fold_in(base_key, 17), (32, 128))
        n = 256

        def eden_err(i):
            k = jax.random.PRNGKey(i)
            o = ME.ms_eden(x, jax.random.fold_in(k, 0),
                           jax.random.fold_in(k, 1))
            return ME.ms_eden_dequant(o, rotated=False) - x

        errs = jax.vmap(eden_err)(jnp.arange(n))       # (n, 32, 128)
        per_draw_mean = jnp.mean(errs, axis=(1, 2))    # (n,)
        mean = float(jnp.mean(per_draw_mean))
        sem = float(jnp.std(per_draw_mean)) / np.sqrt(n)
        assert abs(mean) <= 3.0 * sem, (mean, sem)     # CI contains 0
        eden_mse = float(jnp.mean(errs ** 2))

        def sr_err(i):
            return Q.dequant(Q.quant_sr(x, jax.random.PRNGKey(i))) - x

        sr_errs = jax.vmap(sr_err)(jnp.arange(64))
        # SR is unbiased too — but with > 2x the MSE on the same tensors
        sr_mse = float(jnp.mean(sr_errs ** 2))
        assert sr_mse > 2.0 * eden_mse, (sr_mse, eden_mse)
        sr_mean = float(jnp.mean(sr_errs))
        sr_sem = float(jnp.std(jnp.mean(sr_errs, axis=(1, 2)))) / np.sqrt(64)
        assert abs(sr_mean) <= 3.0 * sr_sem

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.sampled_from([(8, 64), (16, 128), (4, 1408), (32, 384)]))
    def test_shape_dtype_sweep(self, seed, shape):
        x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
        for dt in (jnp.float32, jnp.bfloat16):
            o = ME.ms_eden(x.astype(dt), jax.random.PRNGKey(0), jax.random.PRNGKey(1))
            v = ME.ms_eden_dequant(o, rotated=False)
            assert v.shape == shape and not bool(jnp.isnan(v).any())
