"""Distribution-layer unit tests: sharding rules, HLO cost parser, MXFP4."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded-random fallback shim
    from _hypothesis_compat import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core import mxfp4 as MX
from repro.core import quant as Q
from repro.dist import sharding as SH
from repro.launch import hlo_cost as H


class TestShardingRules:
    def test_weight_prefers_out_dim(self):
        spec = SH.param_spec("stages/0/l0/mix/wq", (4096, 4096),
                             model=16, data=16, fsdp=True)
        assert spec == P(None, "model", "data") or spec == P("model", "data")
        # 2D weight: out-dim model, in-dim data (fsdp)
        spec = SH.param_spec("head", (64000, 4096), model=16, data=16, fsdp=True)
        assert tuple(spec) == ("model", "data")

    def test_indivisible_out_falls_back(self):
        # whisper vocab 51865 is not divisible by 16 -> model goes elsewhere
        spec = SH.param_spec("dec_head", (51865, 384), model=16, data=16, fsdp=False)
        assert tuple(spec) == (None, "model")

    def test_norms_replicated(self):
        assert tuple(SH.param_spec("n1/g", (4096,), model=16, data=16,
                                   fsdp=True)) == (None,)

    def test_router_replicated(self):
        spec = SH.param_spec("ff/router", (256, 7168), model=16, data=16, fsdp=True)
        assert all(s is None for s in spec)

    def test_expert_weights_ep(self):
        # (L, E, f, d): experts -> model
        spec = SH.param_spec("stages/0/l0/ff/wi", (61, 256, 2048, 7168),
                             model=16, data=16, fsdp=True)
        assert spec[1] == "model"

    def test_stacked_leading_axis_never_sharded(self):
        spec = SH.param_spec("stages/0/l0/mix/wq", (48, 4096, 4096),
                             model=16, data=16, fsdp=True)
        assert spec[0] is None

    def test_cache_spec(self):
        # (L, B, S, KV, hd): batch -> data, hd -> model, S untouched
        spec = SH.cache_spec("kv", (48, 128, 32768, 4, 128), model=16, data=16)
        assert spec[1] == "data" and spec[2] is None and spec[4] == "model"

    @settings(max_examples=25, deadline=None)
    @given(st.tuples(st.sampled_from([16, 128, 512, 4096, 11008]),
                     st.sampled_from([16, 128, 384, 4096])))
    def test_spec_dims_always_divisible(self, shape):
        spec = SH.param_spec("w", shape, model=16, data=16, fsdp=True)
        for dim, ax in zip(shape, spec):
            if ax == "model" or ax == "data":
                assert dim % 16 == 0


class TestHLOCostParser:
    HLO = """
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8] get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ip, %d)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%tup), condition=%cond, body=%body
  %ag = f32[64,8]{1,0} all-gather(%a), replica_groups=[2,8]<=[16], dimensions={0}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=1
}
"""

    def test_trip_count_applied(self):
        c = H.analyze(self.HLO)
        # 7 iterations x 2*8*8*8 flops
        assert c.flops == pytest.approx(7 * 2 * 8 * 8 * 8)

    def test_collective_ring_accounting(self):
        c = H.analyze(self.HLO)
        # all-gather of 64x8 f32 output with group size 8: (g-1)/g * out
        assert c.wire_bytes == pytest.approx(64 * 8 * 4 * 7 / 8)

    def test_shape_bytes(self):
        assert H._shape_elems_bytes("f32[4,4]") == 64
        assert H._shape_elems_bytes("(bf16[2,2], u8[8])") == 16
        assert H._shape_elems_bytes("f8e4m3fn[16]") == 16


class TestMXFP4:
    def test_nvfp4_beats_mxfp4(self):
        """Paper Sec. 3.1: NVFP4's FP8 16-group scales beat MXFP4's 2^k
        32-group scales — checkable here: >3x MSE gap on N(0,1)."""
        x = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
        mx = float(Q.mse(x, MX.quant_mxfp4(x)))
        nv = float(Q.mse(x, Q.quant_rtn(x, s=Q.S_EDEN)))
        assert mx > 3 * nv

    def test_mxfp4_scales_are_powers_of_two(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 64)) * 37
        qt = MX.quant_mxfp4(x)
        s = np.asarray(qt.scales)
        assert np.allclose(np.exp2(np.round(np.log2(s))), s)

    def test_mxfp4_sr_unbiased(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (256,))[None, :]
        qs = jnp.stack([Q.dequant(MX.quant_mxfp4_sr(x, jax.random.PRNGKey(i)))
                        for i in range(512)])
        rel = float(jnp.linalg.norm(jnp.mean(qs, 0) - x) / jnp.linalg.norm(x))
        assert rel < 0.03
