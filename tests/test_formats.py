"""Unit + property tests for the scalar format primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded-random fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import formats as F

jax.config.update("jax_platform_name", "cpu")


class TestFP4:
    def test_grid_roundtrip(self):
        vals = np.concatenate([F.FP4_GRID, -F.FP4_GRID])
        x = jnp.asarray(vals)
        assert np.allclose(F.fp4_rtn(x), vals)
        codes = F.fp4_code(x)
        assert np.allclose(F.fp4_decode(codes), vals)

    def test_rtn_nearest(self):
        x = jnp.asarray([0.2, 0.3, 0.7, 1.2, 2.4, 2.6, 3.6, 4.9, 5.1, 100.0])
        expect = [0.0, 0.5, 0.5, 1.0, 2.0, 3.0, 4.0, 4.0, 6.0, 6.0]
        assert np.allclose(F.fp4_rtn(x), expect)
        assert np.allclose(F.fp4_rtn(-x), [-e for e in expect])

    def test_rtn_ties_to_even(self):
        # midpoints: .25->0, .75->1, 2.5->2, 3.5->4, 5->4
        x = jnp.asarray([0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0])
        assert np.allclose(F.fp4_rtn(x), [0.0, 1.0, 1.0, 2.0, 2.0, 4.0, 4.0])

    @settings(max_examples=30, deadline=None)
    @given(st.floats(-6, 6), st.integers(0, 2**31 - 1))
    def test_sr_lands_on_neighbours(self, v, seed):
        """Every SR draw is one of the two grid points bracketing v."""
        x = jnp.full((64,), v, jnp.float32)
        q = np.asarray(F.fp4_sr(x, jax.random.PRNGKey(seed)))
        mag = abs(v)
        lo = F.FP4_GRID[F.FP4_GRID <= mag + 1e-7].max()
        hi = F.FP4_GRID[F.FP4_GRID >= mag - 1e-7].min()
        allowed = {np.sign(v) * lo, np.sign(v) * hi} if v else {0.0}
        assert all(any(np.isclose(qi, a) for a in allowed) for qi in q), \
            (v, set(np.unique(q)), allowed)

    def test_sr_unbiased(self):
        x = jax.random.uniform(jax.random.PRNGKey(0), (512,), minval=-6, maxval=6)
        qs = jax.vmap(lambda i: F.fp4_sr(x, jax.random.PRNGKey(i)))(jnp.arange(4096))
        bias = jnp.abs(jnp.mean(qs, 0) - x)
        assert float(jnp.max(bias)) < 0.05  # MC tolerance


class TestFP8:
    def test_rtn_matches_dtype_cast(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 100
        ref = jnp.clip(x, -448, 448).astype(jnp.float8_e4m3fn).astype(jnp.float32)
        assert np.array_equal(np.asarray(F.fp8_rtn(x)), np.asarray(ref))

    def test_rtn_margin(self):
        # RTN_FP8 increases values by at most 17/16 -> margin constant 16/17
        x = jnp.linspace(0.01, 440.0, 100001)
        r = F.fp8_rtn(x)
        ratio = np.asarray(r) / np.asarray(x)
        assert ratio.max() <= 1.0 / F.FP8_RTN_MARGIN + 1e-6

    def test_sr_pos_on_lattice_and_unbiased(self):
        v = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (256,))) * 50 + 0.5
        q = F.fp8_sr_pos(v, jax.random.PRNGKey(2))
        # every output is exactly representable in e4m3
        assert np.array_equal(
            np.asarray(q), np.asarray(q.astype(jnp.float8_e4m3fn).astype(jnp.float32)))
        qs = jax.vmap(lambda i: F.fp8_sr_pos(v, jax.random.PRNGKey(i)))(jnp.arange(4096))
        rel = jnp.abs(jnp.mean(qs, 0) - v) / v
        assert float(jnp.max(rel)) < 0.01

    def test_sr_pos_exact_values_stay(self):
        exact = jnp.asarray([0.0, 1.0, 1.5, 448.0, 0.25])
        q = F.fp8_sr_pos(exact, jax.random.PRNGKey(0))
        assert np.array_equal(np.asarray(q), np.asarray(exact))


class TestE8M3:
    def test_mantissa_3_bits(self):
        x = jnp.asarray([1.0 + i / 64 for i in range(64)])
        q = np.asarray(F.e8m3_rtn(x))
        # representable values between 1 and 2 step 1/8
        assert np.allclose(q * 8, np.round(q * 8))

    def test_extended_range(self):
        # values way beyond FP8_MAX survive (no overflow) — the ER property
        x = jnp.asarray([1e6, 3e-6, 448.0, 70000.0])
        q = np.asarray(F.e8m3_rtn(x))
        assert np.all(np.isfinite(q)) and q[0] > 9e5
        # and bf16 storage is exact
        assert np.array_equal(q, np.asarray(jnp.asarray(q).astype(jnp.bfloat16).astype(jnp.float32)))


class TestPacking:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64, 128, 256]))
    def test_roundtrip(self, seed, d):
        codes = jax.random.randint(jax.random.PRNGKey(seed), (8, d), 0, 16, jnp.uint8)
        assert np.array_equal(np.asarray(F.unpack_fp4(F.pack_fp4(codes))), np.asarray(codes))

    def test_wire_size(self):
        codes = jnp.zeros((4, 256), jnp.uint8)
        assert F.pack_fp4(codes).size * 8 == codes.size * 4  # 4 bits/element
