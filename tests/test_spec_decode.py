"""Self-speculative decoding tests: exact bitwise verification.

Acceptance (ISSUE 2):
  (a) with spec_k > 0 the emitted token stream is BITWISE identical to the
      non-speculative greedy engine for every covered arch family — gqa,
      mla(+moe), rwkv (state snapshot/replay), hybrid rec+lattn — in both
      paged and dense cache layouts (bf16: chunk-size-invariant per-row
      arithmetic makes the verify chunk exactly the S=1 steps);
  (b) quartet2 speculative streams are deterministic run-to-run, and the
      quantize-once packed draft weights are bit-identical to re-quantizing;
  (c) rollback bookkeeping: slots/blocks reclaimed across retirement and
      re-admission, admission margin enforced;
  (d) stochastic requests speculate through the rejection-sampling hook
      (sampling.speculative_resample): token-by-token the emitted stream
      preserves the engine's sampling distribution EXACTLY (TV-distance
      test against the analytic target), streams are reproducible, and
      greedy rows in a mixed batch stay bitwise unperturbed.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serve import sampling
from repro.serve.engine import EngineConfig, Request, ServeEngine

pytestmark = pytest.mark.serve

SEED = jnp.array([7, 7], jnp.uint32)


def _cfg(arch):
    cfg = registry.get(arch).reduced()
    if cfg.moe:  # exactness needs no capacity drops (cf. test_serve)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _prompts(cfg, np_rng, lens=(9, 13)):
    return [list(map(int, np_rng.randint(0, cfg.vocab, n))) for n in lens]


def _run(cfg, params, prompts, max_new, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("prequant", False)
    eng = ServeEngine(cfg, params, EngineConfig(**kw))
    ids = [eng.submit(Request(prompt=p, max_new=max_new)) for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids], eng


# --------------------------------------------------------------------------
# (a) bitwise stream equality across arch families, paged and dense
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi_9b", "deepseek_v3_671b", "rwkv6_7b",
                                  "recurrentgemma_9b"])
def test_spec_stream_bitwise_matches_nonspec(arch, base_key, np_rng):
    """gqa / mla+moe / rwkv / rec+lattn: the speculative engine must emit
    exactly the non-speculative greedy stream, with paged AND dense caches.
    rwkv's spec_k keeps the verify chunk under cfg.rwkv.chunk so the
    per-token WKV tail path (bitwise == S=1 steps) is used."""
    cfg = _cfg(arch)
    params = lm.init(cfg, base_key)
    prompts = _prompts(cfg, np_rng)
    base, _ = _run(cfg, params, prompts, 6, paged=True)
    for paged in (True, False):
        spec, eng = _run(cfg, params, prompts, 6, paged=paged,
                         spec_k=3, draft_layers=1)
        assert spec == base, (arch, paged)
        assert eng.stats["spec_rounds"] > 0
        assert eng.stats["draft_tokens"] > 0


def test_spec_continuous_batching_reclaims_and_matches(base_key, np_rng):
    """More requests than slots: retirement releases BOTH pools, readmission
    resets the draft slot, and every stream still matches non-spec."""
    cfg = _cfg("yi_9b")
    params = lm.init(cfg, base_key)
    prompts = _prompts(cfg, np_rng, lens=(9, 13, 7, 11, 5))
    base, _ = _run(cfg, params, prompts, 4)
    spec, eng = _run(cfg, params, prompts, 4, spec_k=3, draft_layers=1)
    assert spec == base
    assert eng.free_slots == 2
    assert eng.pool.free_block_count == eng.pool.n_blocks
    assert eng.draft.pool.free_block_count == eng.draft.pool.n_blocks
    assert eng.stats["finished"] == 5


# --------------------------------------------------------------------------
# (b) quartet2: determinism + packed-draft bit-identity
# --------------------------------------------------------------------------

def test_spec_quartet2_deterministic_and_prequant_bitwise(base_key, np_rng):
    cfg = _cfg("yi_9b")
    params = lm.init(cfg, base_key)
    prompts = _prompts(cfg, np_rng)
    a, ea = _run(cfg, params, prompts, 6, scheme="quartet2", prequant=True,
                 spec_k=3, draft_layers=1)
    b, _ = _run(cfg, params, prompts, 6, scheme="quartet2", prequant=True,
                spec_k=3, draft_layers=1)
    assert a == b  # deterministic forward + greedy acceptance
    # quantize-once packed weights in BOTH stacks == per-step quantization
    c, _ = _run(cfg, params, prompts, 6, scheme="quartet2", prequant=False,
                spec_k=3, draft_layers=1)
    assert a == c
    assert ea.stats["accepted_tokens"] >= 0


# --------------------------------------------------------------------------
# (c) rollback bookkeeping, margins, validation, sampling hook
# --------------------------------------------------------------------------

def test_spec_admission_margin(base_key, np_rng):
    """The verify chunk overshoots a sequence's final token by up to spec_k
    positions: admission must reserve prompt + max_new + spec_k, so a
    request that fits exactly WITH margin is served and one that only fits
    WITHOUT it is rejected up front."""
    cfg = _cfg("yi_9b")
    params = lm.init(cfg, base_key)
    # 9 + 20 + 3 == 32 == max_len: served, and matches non-spec
    prompts = _prompts(cfg, np_rng, lens=(9,))
    base, _ = _run(cfg, params, prompts, 20, max_len=32, n_slots=1)
    spec, _ = _run(cfg, params, prompts, 20, max_len=32, n_slots=1,
                   spec_k=3, draft_layers=1)
    assert spec == base
    # 9 + 23 == 32 fits only without the margin: must reject at submit
    eng = ServeEngine(cfg, params,
                      EngineConfig(n_slots=1, max_len=32, scheme="bf16",
                                   prequant=False, spec_k=3, draft_layers=1))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=prompts[0], max_new=23))


def test_spec_config_validation(base_key):
    cfg = _cfg("yi_9b")
    params = lm.init(cfg, base_key)
    with pytest.raises(ValueError):  # spec needs a draft depth
        ServeEngine(cfg, params, EngineConfig(spec_k=2, draft_layers=0))
    with pytest.raises(ValueError):  # draft must be a strict prefix
        ServeEngine(cfg, params,
                    EngineConfig(spec_k=2, draft_layers=cfg.n_layers))
    # rwkv: the verify chunk must stay below the chunked-WKV threshold or
    # the bitwise-equality guarantee would silently break
    rcfg = _cfg("rwkv6_7b")
    rparams = lm.init(rcfg, base_key)
    with pytest.raises(ValueError):
        ServeEngine(rcfg, rparams,
                    EngineConfig(spec_k=rcfg.rwkv.chunk - 1, draft_layers=1))


def test_resample_preserves_target_distribution():
    """The distribution-preservation guarantee of rejection sampling: with a
    deterministic (point-mass) draft, the marginal of the FIRST emitted
    token equals q_0 = softmax(logits_0 / T) exactly, and — conditioned on
    the first draft being accepted — the second emission follows q_1. TV
    distances against the analytic law must sit at sampling-noise level."""
    v, draws = 8, 20_000
    rng = np.random.RandomState(0)
    tl = jnp.asarray(rng.randn(3, v) * 2, jnp.float32)   # K=2 drafts + bonus
    temp = 0.8
    q = np.asarray(sampling.sampling_probs(tl, temp, 0))
    draft = jnp.asarray([3, 5], jnp.int32)
    f = jax.jit(jax.vmap(lambda k: sampling.speculative_resample(
        draft, None, tl, k, temperature=temp, top_k=0)))
    toks, cnt = f(jax.random.split(jax.random.PRNGKey(1), draws))
    toks, cnt = np.asarray(toks), np.asarray(cnt)
    emp = np.bincount(toks[:, 0], minlength=v) / draws
    assert 0.5 * np.abs(emp - q[0]).sum() < 0.02
    m = (cnt >= 2) & (toks[:, 0] == 3)                   # draft 0 accepted
    emp2 = np.bincount(toks[m, 1], minlength=v) / m.sum()
    assert 0.5 * np.abs(emp2 - q[1]).sum() < 0.03


def test_resample_general_draft_distribution():
    """With a non-degenerate draft distribution p (draft token SAMPLED from
    p, accept w.p. min(1, q/p), residual max(q-p, 0)), the emitted marginal
    is still exactly q — including under a top-k filter."""
    v, draws, temp, topk = 8, 20_000, 1.2, 5
    rng = np.random.RandomState(2)
    tl = jnp.asarray(rng.randn(2, v), jnp.float32)       # K=1 draft + bonus
    dl = jnp.asarray(rng.randn(1, v), jnp.float32)       # draft logits
    q = np.asarray(sampling.sampling_probs(tl, temp, topk))
    p = sampling.sampling_probs(dl, temp, topk)

    def one(k):
        kd, kr = jax.random.split(k)
        d = jax.random.categorical(kd, jnp.log(p))        # d ~ p
        return sampling.speculative_resample(
            d.astype(jnp.int32), dl, tl, kr, temperature=temp, top_k=topk)

    toks, _ = jax.jit(jax.vmap(one))(
        jax.random.split(jax.random.PRNGKey(3), draws))
    emp = np.bincount(np.asarray(toks)[:, 0], minlength=v) / draws
    assert 0.5 * np.abs(emp - q[0]).sum() < 0.02


def test_spec_serves_stochastic_requests(base_key, np_rng):
    """End-to-end: stochastic requests speculate (no refusal), produce full
    streams, reproduce run-to-run, and do NOT perturb a greedy neighbor —
    the greedy slot's stream stays bitwise equal to an all-greedy engine."""
    from repro.serve.sampling import SamplingParams
    cfg = _cfg("yi_9b")
    params = lm.init(cfg, base_key)
    prompts = _prompts(cfg, np_rng)
    greedy_only, _ = _run(cfg, params, prompts, 6, spec_k=2, draft_layers=1)

    def mixed():
        eng = ServeEngine(cfg, params,
                          EngineConfig(n_slots=2, max_len=64, prefill_chunk=8,
                                       scheme="bf16", prequant=False,
                                       spec_k=2, draft_layers=1))
        ids = [eng.submit(Request(prompt=prompts[0], max_new=6)),
               eng.submit(Request(prompt=prompts[1], max_new=6,
                                  sampling=SamplingParams(temperature=0.9,
                                                          top_k=4)))]
        res = {r.req_id: r.tokens for r in eng.run()}
        return [res[i] for i in ids]

    a, b = mixed(), mixed()
    assert a == b                        # reproducible stochastic stream
    assert len(a[1]) == 6
    assert a[0] == greedy_only[0]        # greedy row bitwise unperturbed


def test_accept_greedy_prefix_semantics():
    assert sampling.accept_greedy([5, 6, 7], [5, 6, 7, 9]) == 3
    assert sampling.accept_greedy([5, 6, 7], [5, 8, 7, 9]) == 1
    assert sampling.accept_greedy([5, 6, 7], [4, 6, 7, 9]) == 0
    assert sampling.accept_greedy([], [4]) == 0


# --------------------------------------------------------------------------
# draft prefix forward: unit-level checks
# --------------------------------------------------------------------------

def test_prefix_specs_cover_all_archs():
    for arch in ("yi_9b", "deepseek_v3_671b", "rwkv6_7b",
                 "recurrentgemma_9b"):
        cfg = _cfg(arch)
        total = lm.total_layers(cfg)
        for n in range(1, total):
            specs = lm.prefix_specs(cfg, n)
            assert sum(c * len(p) for p, c in specs) == n, (arch, n)
        with pytest.raises(ValueError):
            lm.prefix_specs(cfg, 0)
        with pytest.raises(ValueError):
            lm.prefix_specs(cfg, total)


def test_forward_prefix_matches_truncated_model(base_key):
    """A 1-layer prefix of a 2-layer model must equal a 1-layer model built
    from the same sliced params — layer ids (and site seeds) aligned."""
    cfg = _cfg("yi_9b")
    params = lm.init(cfg, base_key)
    toks = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6]])
    got, _, _ = lm.forward_prefix(params, cfg, {"tokens": toks}, "quartet2",
                                  SEED, n_prefix=1, mode="train")
    small_cfg = dataclasses.replace(cfg, n_layers=1)
    small = {k: v for k, v in params.items() if k != "stages"}
    small["stages"] = [jax.tree.map(lambda x: x[:1], params["stages"][0])]
    want, _, _ = lm.forward(small, small_cfg, {"tokens": toks}, "quartet2",
                            SEED, mode="train")
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
