"""Shared fixtures: one seed to rule every test's randomness.

Determinism policy (tier-1 must be reproducible run-to-run): tests derive
ALL randomness — jax PRNG keys, numpy RandomStates, prompt contents — from
the `base_seed` fixture (or an explicit literal), never from entropy
sources. The `_hypothesis_compat` shim already seeds itself from the test's
qualified name, so property tests reproduce too.
"""

import jax
import numpy as np
import pytest

BASE_SEED = 0

# Files whose every test is a Pallas-kernel parity check: the `kernels`
# marker (pytest.ini) is wired here by path, so `-m kernels` selects the
# whole contract suite (and `-m "not kernels"` skips interpret-mode Pallas
# on machines where it is slow) without per-file pytestmark boilerplate.
_KERNEL_SUITES = {"test_kernels.py", "test_paged_attention.py"}


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.fspath.basename in _KERNEL_SUITES:
            item.add_marker(pytest.mark.kernels)


@pytest.fixture(scope="session")
def base_seed() -> int:
    return BASE_SEED


@pytest.fixture()
def base_key(base_seed):
    """Fresh jax PRNG key per test, derived from the shared seed."""
    return jax.random.PRNGKey(base_seed)


@pytest.fixture()
def np_rng(base_seed):
    """Fresh numpy RandomState per test, derived from the shared seed."""
    return np.random.RandomState(base_seed)
