"""Shared fixtures: one seed to rule every test's randomness.

Determinism policy (tier-1 must be reproducible run-to-run): tests derive
ALL randomness — jax PRNG keys, numpy RandomStates, prompt contents — from
the `base_seed` fixture (or an explicit literal), never from entropy
sources. The `_hypothesis_compat` shim already seeds itself from the test's
qualified name, so property tests reproduce too.
"""

import os

# Simulated 2-device host platform for the mesh-sharded serving suite
# (tests/test_serve_sharded.py drives shard_map over a (data=2, model=1)
# mesh in-process). MUST run before the first jax import anywhere — jax
# locks the device count at first init; pytest imports conftest.py before
# any test module, so this is the one reliable hook. Every other test is
# device-count agnostic (unsharded computations land on device 0 and
# produce bit-identical results), and the multi-device subprocess tests
# (test_pipeline / test_substrate / test_dist) set their own flags.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2").strip()

import jax
import numpy as np
import pytest

BASE_SEED = 0

# Files whose every test is a Pallas-kernel parity check: the `kernels`
# marker (pytest.ini) is wired here by path, so `-m kernels` selects the
# whole contract suite (and `-m "not kernels"` skips interpret-mode Pallas
# on machines where it is slow) without per-file pytestmark boilerplate.
_KERNEL_SUITES = {"test_kernels.py", "test_paged_attention.py"}

# Distribution-layer suites (sharding rules, pipeline/compression shard_map
# programs, the mesh-sharded serving engine): `-m dist` selects them, wired
# by path like the kernel marker above.
_DIST_SUITES = {"test_dist.py", "test_pipeline.py", "test_serve_sharded.py"}

# Scheduler-policy suite (admission ordering, aging, prefill preemption):
# `-m scheduler` selects it, wired by path like the markers above.
_SCHED_SUITES = {"test_scheduler.py"}

# Observability suite (metrics registry, request tracing, engine telemetry,
# quantization-health probe): `-m obs` selects it, wired by path.
_OBS_SUITES = {"test_obs.py"}

# Quantized-KV-cache suite (NVFP4 cache codec, PackedKV pools, packed-operand
# decode kernels, kv_quant engine parity): `-m kvq` selects it, wired by path.
_KVQ_SUITES = {"test_kv_quant.py"}

# Streaming-frontend suites (asyncio HTTP/SSE server, engine-thread bridge,
# cancellation races): `-m frontend` selects them, wired by path. These
# tests get a hard per-test wall-clock guard (see `_frontend_timeout`) — a
# wedged stream or deadlocked thread boundary fails fast instead of hanging
# the whole tier-1 run.
_FRONTEND_SUITES = {"test_frontend.py", "test_cancel_races.py"}

# Hierarchical prefix-cache suite (host-RAM spill tier, swap-in, cross-shard
# replication, disaggregated handoff conservation): `-m tiered` selects it,
# wired by path. Shares the frontend suites' SIGALRM wall-clock guard — the
# fuzz walks and swap-in paths touch the same engine/pool machinery a
# deadlock would wedge.
_TIERED_SUITES = {"test_prefix_tiers.py"}

#: per-test wall-clock ceiling for the frontend suites, seconds. Generous —
#: normal tests finish in a few seconds even with XLA compiles; the guard
#: exists to catch deadlocks/hangs, not slowness.
FRONTEND_TEST_TIMEOUT_S = 180


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.fspath.basename in _KERNEL_SUITES:
            item.add_marker(pytest.mark.kernels)
        if item.fspath.basename in _DIST_SUITES:
            item.add_marker(pytest.mark.dist)
        if item.fspath.basename in _SCHED_SUITES:
            item.add_marker(pytest.mark.scheduler)
        if item.fspath.basename in _OBS_SUITES:
            item.add_marker(pytest.mark.obs)
        if item.fspath.basename in _KVQ_SUITES:
            item.add_marker(pytest.mark.kvq)
        if item.fspath.basename in _FRONTEND_SUITES:
            item.add_marker(pytest.mark.frontend)
            item.add_marker(pytest.mark.usefixtures("_frontend_timeout"))
        if item.fspath.basename in _TIERED_SUITES:
            item.add_marker(pytest.mark.tiered)
            item.add_marker(pytest.mark.usefixtures("_frontend_timeout"))


@pytest.fixture()
def _frontend_timeout():
    """SIGALRM-based per-test timeout for the frontend suites (no external
    timeout plugin in the image). Applied via marker wiring above, main
    thread only — SIGALRM interrupts a hung `asyncio.run` / `Event.wait`
    with a loud failure instead of wedging CI. No-op off-POSIX or when a
    previous alarm is pending (never clobber someone else's timer)."""
    import signal

    if (not hasattr(signal, "SIGALRM")
            or signal.getsignal(signal.SIGALRM) not in
            (signal.SIG_DFL, signal.SIG_IGN, None)):
        yield
        return

    def _fail(signum, frame):
        raise TimeoutError(
            f"frontend test exceeded {FRONTEND_TEST_TIMEOUT_S}s wall clock "
            "(deadlocked stream/bridge?)")

    old = signal.signal(signal.SIGALRM, _fail)
    signal.alarm(FRONTEND_TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session")
def base_seed() -> int:
    return BASE_SEED


@pytest.fixture()
def base_key(base_seed):
    """Fresh jax PRNG key per test, derived from the shared seed."""
    return jax.random.PRNGKey(base_seed)


@pytest.fixture()
def np_rng(base_seed):
    """Fresh numpy RandomState per test, derived from the shared seed."""
    return np.random.RandomState(base_seed)
