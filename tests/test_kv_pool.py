"""Property-style KVPool allocator suite.

Random alloc/ensure/truncate/release/reset sequences against a host-side
reference model, checking after every op:

  - free-list conservation: free + sum(owned) == n_blocks, always;
  - no aliasing: a physical block belongs to at most one slot, and never to
    both a slot and the free list;
  - block-table consistency: a slot's table row is exactly its owned blocks
    followed by the OOB sentinel;
  - OutOfBlocks raised exactly when the capacity math says so;
  - misuse (double-free, ops on unbound slots, reset of a live slot) raises
    SlotError instead of silently corrupting accounting.

Strategies come from tests/_hypothesis_compat.py when hypothesis is absent
(offline container): examples are seeded by the test's qualified name, so
failures reproduce deterministically.
"""

import dataclasses
import math
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded-random fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import ArchConfig
from repro.serve.kv_pool import (KVPool, OutOfBlocks, SlotError,
                                 reclaim_window)

pytestmark = pytest.mark.serve

N_SLOTS, MAX_LEN, BLOCK = 3, 32, 4
MAX_BLOCKS = MAX_LEN // BLOCK


def _tiny_cfg() -> ArchConfig:
    """Smallest decode-capable arch: allocator logic is cache-agnostic."""
    return ArchConfig(name="pool-test", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      head_dim=16)


def _pool(n_blocks=10, paged=True) -> KVPool:
    return KVPool(_tiny_cfg(), N_SLOTS, MAX_LEN, paged=paged,
                  block_size=BLOCK, n_blocks=n_blocks)


class _Ref:
    """Reference allocator model mirrored against the real pool."""

    def __init__(self, n_blocks):
        self.n_blocks = n_blocks
        self.free = n_blocks
        self.bound = [False] * N_SLOTS
        self.owned = [0] * N_SLOTS
        self.length = [0] * N_SLOTS


def _check_invariants(pool: KVPool, ref: _Ref):
    assert pool.free_block_count == ref.free
    # conservation
    assert pool.free_block_count + sum(
        len(o) for o in pool._owned) == pool.n_blocks
    # no aliasing: every block appears exactly once across free + owned
    seen = list(pool._free)
    for o in pool._owned:
        seen.extend(o)
    assert sorted(seen) == list(range(pool.n_blocks))
    # table rows mirror ownership
    for s in range(N_SLOTS):
        own = pool._owned[s]
        assert list(pool._table[s, : len(own)]) == own
        assert all(pool._table[s, len(own):] == pool.sentinel)
        assert pool.length(s) == ref.length[s]
        assert len(own) == ref.owned[s]


def _apply(pool: KVPool, ref: _Ref, op, rng: random.Random):
    slot = rng.randrange(N_SLOTS)
    if op == "commit":
        total = rng.randint(1, MAX_LEN + 8)
        if ref.bound[slot]:
            with pytest.raises(SlotError):
                pool.commit(slot, total)
        elif total > MAX_LEN:
            with pytest.raises(OutOfBlocks):
                pool.commit(slot, total)
        else:
            pool.commit(slot, total)
            ref.bound[slot] = True
    elif op == "ensure":
        n = rng.randint(1, MAX_LEN)
        if not ref.bound[slot]:
            with pytest.raises(SlotError):
                pool.ensure(slot, n)
            return
        if not pool.paged:
            pool.ensure(slot, n)  # dense: capacity is max_len, no blocks
            ref.length[slot] = max(ref.length[slot], n)
            return
        need = math.ceil(n / BLOCK)
        extra = max(0, need - ref.owned[slot])
        if extra > ref.free:
            # capacity math says no: the pool must raise, consuming at most
            # what was free (conservation still holds afterwards)
            with pytest.raises(OutOfBlocks):
                pool.ensure(slot, n)
            ref.owned[slot] += ref.free
            ref.free = 0
        else:
            pool.ensure(slot, n)
            ref.owned[slot] += extra
            ref.free -= extra
            ref.length[slot] = max(ref.length[slot], n)
    elif op == "truncate":
        n = rng.randint(0, MAX_LEN)
        if not ref.bound[slot]:
            with pytest.raises(SlotError):
                pool.truncate(slot, n)
        elif n > ref.length[slot]:
            with pytest.raises(SlotError):
                pool.truncate(slot, n)
        else:
            pool.truncate(slot, n)
            ref.length[slot] = n  # logical only: owned blocks unchanged
    elif op == "release":
        if not ref.bound[slot]:
            with pytest.raises(SlotError):
                pool.release(slot)
        else:
            pool.release(slot)
            ref.free += ref.owned[slot]
            ref.owned[slot] = 0
            ref.length[slot] = 0
            ref.bound[slot] = False
    elif op == "reset":
        if ref.bound[slot]:
            with pytest.raises(SlotError):
                pool.reset_slot(slot)
        else:
            pool.reset_slot(slot)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_allocator_random_sequences(seed):
    rng = random.Random(seed)
    n_blocks = rng.choice([6, 10, N_SLOTS * MAX_BLOCKS])
    pool = _pool(n_blocks=n_blocks)
    ref = _Ref(n_blocks)
    ops = ["commit", "ensure", "ensure", "truncate", "release", "reset"]
    for _ in range(50):
        _apply(pool, ref, rng.choice(ops), rng)
        _check_invariants(pool, ref)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_allocator_random_sequences_dense(seed):
    """Dense mode shares the binding/length state machine (no blocks)."""
    rng = random.Random(seed)
    pool = _pool(paged=False)
    ref = _Ref(pool.n_blocks)
    for _ in range(40):
        _apply(pool, ref, rng.choice(
            ["commit", "ensure", "truncate", "release", "reset"]), rng)
        for s in range(N_SLOTS):
            assert pool.length(s) == ref.length[s]


# ---- explicit guard paths (the satellite's double-free / misuse cases) ----

def test_release_double_free_raises():
    pool = _pool()
    pool.commit(0, 8)
    pool.ensure(0, 8)
    pool.release(0)
    with pytest.raises(SlotError):
        pool.release(0)


def test_release_unallocated_slot_raises():
    pool = _pool()
    with pytest.raises(SlotError):
        pool.release(1)


def test_reset_bound_slot_raises():
    pool = _pool()
    pool.commit(2, 4)
    with pytest.raises(SlotError):
        pool.reset_slot(2)
    pool.release(2)
    pool.reset_slot(2)  # unbound again: fine


def test_ensure_and_truncate_require_binding():
    pool = _pool()
    with pytest.raises(SlotError):
        pool.ensure(0, 4)
    with pytest.raises(SlotError):
        pool.truncate(0, 0)


def test_truncate_keeps_blocks_no_churn():
    """Speculative rollback must not return blocks (they are regrown into
    immediately); only the logical length moves."""
    pool = _pool()
    pool.commit(0, 24)
    pool.ensure(0, 17)            # 5 blocks
    owned = list(pool._owned[0])
    free0 = pool.free_block_count
    pool.truncate(0, 9)
    assert pool.length(0) == 9
    assert pool._owned[0] == owned          # same physical blocks
    assert pool.free_block_count == free0   # nothing churned
    pool.ensure(0, 17)                      # regrow: no new allocation
    assert pool._owned[0] == owned
    with pytest.raises(SlotError):
        pool.truncate(0, 18)                # beyond current length


def test_out_of_blocks_exact_boundary():
    """OutOfBlocks fires exactly when need exceeds free + owned."""
    pool = _pool(n_blocks=4)
    pool.commit(0, 16)
    pool.ensure(0, 16)            # all 4 blocks
    pool.commit(1, 4)
    with pytest.raises(OutOfBlocks):
        pool.ensure(1, 1)         # pool exhausted
    pool.release(0)
    pool.ensure(1, 4)             # now fine
    # per-slot table capacity is also a hard bound
    pool2 = _pool(n_blocks=24)
    pool2.commit(0, MAX_LEN)
    with pytest.raises(OutOfBlocks):
        pool2.ensure(0, MAX_LEN + 1)


# ---- sliding-window block reclamation (paged lattn stacks) ----------------

WINDOW = 8


def _lattn_cfg() -> ArchConfig:
    """Pure sliding-window stack (every token-cache layer is lattn)."""
    from repro.configs import registry
    base = registry.get("recurrentgemma_9b").reduced()
    return dataclasses.replace(
        base, griffin=dataclasses.replace(base.griffin, window=WINDOW,
                                          pattern=("attn", "attn")))


def _wpool(n_blocks=12) -> KVPool:
    return KVPool(_lattn_cfg(), N_SLOTS, MAX_LEN, paged=True,
                  block_size=BLOCK, n_blocks=n_blocks)


def _conserved(pool: KVPool):
    """Free-list conservation + no aliasing, reclamation included."""
    assert pool.free_block_count + sum(
        len(o) for o in pool._owned) == pool.n_blocks
    seen = list(pool._free)
    for o in pool._owned:
        seen.extend(o)
    assert sorted(seen) == list(range(pool.n_blocks))


def test_reclaim_window_detection():
    assert reclaim_window(_lattn_cfg()) == WINDOW
    assert reclaim_window(_tiny_cfg()) is None           # full attention
    from repro.configs import registry
    # griffin hybrids qualify too: rec layers hold O(1) slot state, so
    # lattn layers are the only block owners
    rg = registry.get("recurrentgemma_9b").reduced()
    assert reclaim_window(rg) == rg.griffin.window
    # full-attention pools never get a reclaim window
    assert _pool().window is None


def test_window_blocks_return_to_free_list_mid_sequence():
    pool = _wpool()
    pool.commit(0, MAX_LEN)
    # grow token by token far past the window: live blocks must plateau at
    # O(window/block), never O(length/block)
    max_live = 0
    for n in range(1, MAX_LEN + 1):
        pool.ensure(0, n)
        _conserved(pool)
        max_live = max(max_live, len(pool._owned[0]))
    bound = math.ceil(WINDOW / BLOCK) + 1
    assert max_live <= bound, (max_live, bound)
    # everything before the window horizon is sentinel in the table
    first_live = (MAX_LEN + 1 - WINDOW) // BLOCK
    assert all(pool._table[0, :first_live - 1] == pool.sentinel)
    pool.release(0)
    _conserved(pool)
    assert pool.free_block_count == pool.n_blocks


def test_window_reclaim_basis_is_pre_ensure_length():
    """Spec-decode rollback safety: a verify chunk's `ensure` overshoot must
    not free blocks the post-rollback window still needs — the reclaim basis
    is the committed (pre-ensure) length, so truncate back to it succeeds."""
    pool = _wpool()
    pool.commit(0, MAX_LEN)
    pool.ensure(0, 10)              # committed prefix
    owned_before = list(pool._owned[0])
    pool.ensure(0, 10 + 4)          # verify-chunk overshoot (spec_k+1 = 4)
    pool.truncate(0, 10)            # full rejection: back to the basis
    assert pool.length(0) == 10
    # the overshoot's reclaim must not have freed any committed-window block
    assert set(owned_before) <= set(pool._owned[0])
    _conserved(pool)
    pool.ensure(0, 14)              # regrow: no churn, same blocks
    _conserved(pool)


def test_window_truncate_below_reclaim_floor_raises():
    pool = _wpool()
    pool.commit(0, MAX_LEN)
    pool.ensure(0, 24)              # reclaim horizon well past block 0
    pool.ensure(0, 25)              # trigger reclaim with basis 24
    assert pool._floor[0] > 0
    floor = pool._floor[0]
    pool.truncate(0, floor)         # exactly at the floor: sound
    with pytest.raises(SlotError):
        pool.truncate(0, floor - 1)


def test_window_random_walk_conserves_free_list():
    """Property-style: random grow/truncate/release cycles on a windowed
    pool keep free + owned == n_blocks and never alias a block."""
    rng = random.Random(7)
    pool = _wpool(n_blocks=10)
    lengths = [0] * N_SLOTS
    bound = [False] * N_SLOTS
    for _ in range(300):
        s = rng.randrange(N_SLOTS)
        op = rng.choice(["grow", "grow", "truncate", "release"])
        if not bound[s]:
            pool.commit(s, MAX_LEN)
            bound[s] = True
        if op == "grow":
            n = min(lengths[s] + rng.randint(1, 5), MAX_LEN)
            try:
                pool.ensure(s, n)
                lengths[s] = max(lengths[s], n)
            except OutOfBlocks:
                pass
        elif op == "truncate":
            n = rng.randint(max(0, lengths[s] - 3), lengths[s])
            try:
                pool.truncate(s, n)
                lengths[s] = n
            except SlotError:      # below the reclaim floor: refused
                pass
        else:
            pool.release(s)
            bound[s] = False
            lengths[s] = 0
        _conserved(pool)


def test_window_engine_serves_long_request_in_small_pool():
    """The payoff of reclamation: an engine whose pool holds FAR fewer
    blocks than blocks_for(prompt + max_new) still admits and completes a
    long sliding-window request, because admission reserves the live-block
    bound (window + one growth chunk), not the full length."""
    import jax
    from repro.models import lm as LM
    from repro.serve.engine import EngineConfig, Request, ServeEngine

    cfg = _lattn_cfg()
    params = LM.init(cfg, jax.random.PRNGKey(0))
    # total = 8 prompt + 24 new = 32 tokens = 8 blocks of 4; pool has 5.
    eng = ServeEngine(cfg, params,
                      EngineConfig(n_slots=1, max_len=32, block_size=BLOCK,
                                   n_blocks=5, prefill_chunk=4,
                                   scheme="bf16", prequant=False))
    assert eng.pool.window == WINDOW
    eng.submit(Request(prompt=[1] * 8, max_new=24))
    res = eng.run()
    assert len(res) == 1 and len(res[0].tokens) == 24
    assert eng.pool.free_block_count == 5          # all reclaimed + released


def test_window_max_live_blocks_bound():
    pool = _wpool()
    # windowed + growth-bounded: capped at blocks_for(W + growth) + 2
    assert pool.max_live_blocks(MAX_LEN, 4) == math.ceil((WINDOW + 4) / BLOCK) + 2
    # no growth bound supplied -> conservative full-length reservation
    assert pool.max_live_blocks(MAX_LEN) == MAX_BLOCKS
    # unwindowed pools ignore max_growth entirely
    assert _pool().max_live_blocks(MAX_LEN, 4) == MAX_BLOCKS


# --------------------------------------------------------------------------
# slot-affine sharded allocator (n_shards > 1 — the mesh-"data" split the
# sharded serving engine runs its shard_map decode step over)
# --------------------------------------------------------------------------

S_SLOTS, S_SHARDS = 4, 2


def _spool(n_blocks=12) -> KVPool:
    return KVPool(_tiny_cfg(), S_SLOTS, MAX_LEN, paged=True,
                  block_size=BLOCK, n_blocks=n_blocks, n_shards=S_SHARDS)


def _check_affinity(pool: KVPool):
    """The invariant the shard_map decode path rests on: a slot only ever
    owns blocks homed on its own shard, free lists stay partitioned, and
    the device table's real entries are local indices into the shard."""
    bps = pool.blocks_per_shard
    for s in range(pool.n_slots):
        sh = pool.shard_of_slot(s)
        assert all(b // bps == sh for b in pool._owned[s]), (s, pool._owned[s])
    for sh, free in enumerate(pool._frees):
        assert all(b // bps == sh for b in free), (sh, free)
    # per-shard conservation (global conservation is the existing invariant)
    for sh in range(pool.n_shards):
        owned = sum(len(pool._owned[s])
                    for s in range(pool.n_slots)
                    if pool.shard_of_slot(s) == sh)
        assert owned + pool.free_blocks_in_shard(sh) == bps
    local = pool.table_device()
    if local is not None:
        import numpy as np
        local = np.asarray(local)
        assert local.min() >= 0 and local.max() <= bps  # bps = LOCAL sentinel


def test_shard_divisibility_validated():
    with pytest.raises(ValueError):
        KVPool(_tiny_cfg(), 3, MAX_LEN, block_size=BLOCK, n_shards=2)
    with pytest.raises(ValueError):
        KVPool(_tiny_cfg(), 4, MAX_LEN, block_size=BLOCK, n_blocks=9,
               n_shards=2)


def test_shard_free_lists_partitioned_at_init():
    pool = _spool()
    assert pool.blocks_per_shard == 6
    assert sorted(pool._frees[0]) == list(range(6))
    assert sorted(pool._frees[1]) == list(range(6, 12))
    assert pool.shard_of_slot(0) == pool.shard_of_slot(1) == 0
    assert pool.shard_of_slot(2) == pool.shard_of_slot(3) == 1


def test_shard_affinity_allocation_and_release():
    pool = _spool()
    for s in range(S_SLOTS):
        pool.commit(s, 12)
        pool.ensure(s, 12)  # 3 blocks each
    _check_affinity(pool)
    for s in (1, 2):
        pool.release(s)
    _check_affinity(pool)
    # shard 0 slot regrows only from shard 0's returned blocks
    pool.commit(1, 12)
    pool.ensure(1, 12)
    _check_affinity(pool)


def test_shard_admission_is_per_shard():
    pool = _spool()  # 6 blocks per shard
    pool.commit(0, 24)      # reserves 6 of shard 0
    assert not pool.can_admit(4, slot=1)     # shard 0 fully committed
    assert pool.can_admit(4, slot=2)         # shard 1 untouched
    # a single sequence is bounded by ONE shard, not the whole pool
    assert pool.can_ever_admit(24)           # 6 blocks = blocks_per_shard
    assert not pool.can_ever_admit(28)       # 7 > blocks_per_shard
    # shard exhaustion raises even while the other shard has free blocks
    pool.ensure(0, 24)
    pool.commit(1, 4)
    with pytest.raises(OutOfBlocks):
        pool.ensure(1, 4)
    assert pool.free_blocks_in_shard(1) == 6


def test_shard_local_table_round_trip():
    pool = _spool()
    pool.commit(2, 8)
    pool.ensure(2, 8)       # 2 blocks on shard 1
    local = __import__("numpy").asarray(pool.table_device())
    bps = pool.blocks_per_shard
    assert list(local[2, :2]) == [0, 1]          # shard-local ids
    assert (local[2, 2:] == bps).all()           # local sentinel
    assert (local[[0, 1, 3]] == bps).all()       # unbound rows all sentinel
    # local + shard base == canonical global table entry
    assert list(pool._table[2, :2]) == [bps + 0, bps + 1]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_shard_affinity_random_walk(seed):
    """Random commit/ensure/truncate/release walks never violate slot
    affinity, per-shard conservation, or local-table bounds."""
    rng = random.Random(seed)
    pool = _spool(n_blocks=rng.choice([8, 12, S_SLOTS * MAX_BLOCKS]))
    bound = [False] * S_SLOTS
    length = [0] * S_SLOTS
    for _ in range(60):
        s = rng.randrange(S_SLOTS)
        op = rng.choice(["commit", "ensure", "ensure", "truncate", "release"])
        try:
            if op == "commit" and not bound[s]:
                pool.commit(s, rng.randint(1, MAX_LEN))
                bound[s] = True
            elif op == "ensure" and bound[s]:
                n = rng.randint(1, MAX_LEN)
                pool.ensure(s, n)
                length[s] = max(length[s], n)
            elif op == "truncate" and bound[s]:
                n = rng.randint(0, length[s])
                pool.truncate(s, n)
                length[s] = n
            elif op == "release" and bound[s]:
                pool.release(s)
                bound[s] = False
                length[s] = 0
        except OutOfBlocks:
            pass
        _check_affinity(pool)


# --------------------------------------------------------------------------
# refcounted prefix sharing: adopt_prefix / cow_block / cache-style holds
# (the pool-level laws serve/prefix_cache.py rests on)
# --------------------------------------------------------------------------

import collections

import jax.numpy as jnp
import numpy as np


def _ref_conserved(pool: KVPool):
    """Generalized conservation under sharing: the free list and the
    refcounts partition the pool (free xor referenced), and no slot's table
    references a block beyond its refcount."""
    free = set(pool._free)
    for b in range(pool.n_blocks):
        if b in free:
            assert pool.refcount(b) == 0, b
        else:
            assert pool.refcount(b) > 0, b
    assert len(free) == pool.free_block_count  # free list holds no dupes
    owners = collections.Counter()
    for o in pool._owned:
        owners.update(o)
    for b, k in owners.items():
        assert pool.refcount(b) >= k, (b, k, pool.refcount(b))


def test_adopt_prefix_shares_blocks_and_conserves():
    """fork/free conservation: a cache hold keeps a retired slot's blocks
    out of the free list; adoption aliases them into another slot; each
    release drops exactly one reference; the final cache drop frees."""
    pool = _pool(n_blocks=10)
    pool.commit(0, 8)
    pool.ensure(0, 8)                       # 2 blocks
    blocks = list(pool._owned[0])
    for b in blocks:                        # cache insertion: one hold each
        pool.incref(b)
    pool.release(0)                         # slot ref drops; cache ref holds
    _ref_conserved(pool)
    assert pool.free_block_count == 8       # NOT freed
    assert all(pool.refcount(b) == 1 for b in blocks)

    pool.commit(1, 16)
    pool.adopt_prefix(1, blocks, 8)         # alias read-only into slot 1
    _ref_conserved(pool)
    assert all(pool.refcount(b) == 2 for b in blocks)
    assert pool._owned[1] == blocks
    assert list(pool._table[1, :2]) == blocks
    assert pool.length(1) == 8
    pool.ensure(1, 13)                      # grows PRIVATE blocks after
    _ref_conserved(pool)
    assert pool._shared_upto[1] == 2

    pool.release(1)                         # aliases drop, cache still holds
    _ref_conserved(pool)
    assert all(pool.refcount(b) == 1 for b in blocks)
    assert pool.free_block_count == 8       # only the private block returned
    for b in blocks:                        # cache eviction: last ref frees
        pool._decref(b)
    _ref_conserved(pool)
    assert pool.free_block_count == 10
    with pytest.raises(SlotError):          # no double-free past zero
        pool._decref(blocks[0])


def test_truncate_never_frees_shared_blocks():
    """Spec-rollback safety: truncate on a slot with an adopted prefix is
    logical-only — it can never free a block another owner references."""
    pool = _pool(n_blocks=10)
    pool.commit(0, 8)
    pool.ensure(0, 8)
    blocks = list(pool._owned[0])
    for b in blocks:
        pool.incref(b)                      # cache hold
    pool.release(0)
    pool.commit(1, 20)
    pool.adopt_prefix(1, blocks, 8)
    pool.ensure(1, 8 + 4)                   # spec verify-chunk overshoot
    refs0 = [pool.refcount(b) for b in blocks]
    pool.truncate(1, 8)                     # full rejection
    assert [pool.refcount(b) for b in blocks] == refs0
    _ref_conserved(pool)
    pool.release(1)
    _ref_conserved(pool)
    assert all(pool.refcount(b) == 1 for b in blocks)  # cache survives


def test_adopt_prefix_guards():
    pool = _pool()
    with pytest.raises(SlotError):          # unbound slot
        pool.adopt_prefix(0, [0], 4)
    pool.commit(0, 8)
    pool.ensure(0, 4)
    with pytest.raises(SlotError):          # already allocated
        pool.adopt_prefix(0, [1], 4)
    pool.commit(1, 8)
    with pytest.raises(SlotError):          # too many tokens for the blocks
        pool.adopt_prefix(1, list(pool._owned[0]), MAX_LEN)


def test_windowed_pool_refuses_adoption():
    """Windowed-reclaim exclusion: a sliding-window pool frees out-of-window
    blocks mid-sequence, so a cached prefix is not fully resident — sharing
    must be refused at the pool level, not just skipped by the engine."""
    pool = _wpool()
    pool.commit(0, 16)
    pool.ensure(0, 8)
    blocks = list(pool._owned[0])
    pool.commit(1, 16)
    with pytest.raises(SlotError):
        pool.adopt_prefix(1, blocks, 8)
    from repro.serve.prefix_cache import PrefixCache
    assert not PrefixCache.supported(pool)
    assert PrefixCache.supported(_pool())


def test_sharded_adopt_and_cow_respect_affinity():
    pool = _spool()
    pool.commit(0, 8)
    pool.ensure(0, 8)                       # shard-0 blocks
    blocks = list(pool._owned[0])
    for b in blocks:
        pool.incref(b)
    pool.release(0)
    pool.commit(2, 8)                       # slot 2 homes on shard 1
    with pytest.raises(SlotError):
        pool.adopt_prefix(2, blocks, 8)
    with pytest.raises(SlotError):
        pool.cow_block(2, blocks[0])
    pool.commit(1, 8)                       # slot 1: same shard — fine
    pool.adopt_prefix(1, blocks, 8)
    _check_affinity(pool)
    pool.release(1)
    for b in blocks:
        pool._decref(b)
    _check_affinity(pool)


def test_cow_block_copies_device_contents():
    """cow_block appends a PRIVATE block whose token-kind contents equal the
    source block's, bit for bit."""
    pool = _pool(n_blocks=6)
    pool.commit(0, 8)
    pool.ensure(0, 8)
    src = pool._owned[0][0]
    k, v = pool.caches[0]["l0"]["kv"]
    rng = np.random.RandomState(0)
    kv_val = rng.standard_normal(k.shape[2:]).astype(np.float32)
    k = k.at[:, src].set(jnp.asarray(kv_val, k.dtype))
    pool.caches[0]["l0"]["kv"] = (k, v)
    pool.commit(1, 8)
    dst = pool.cow_block(1, src)
    assert dst != src
    assert pool._owned[1] == [dst]
    assert pool._shared_upto[1] == 0        # a COW block is writable
    k2, _ = pool.caches[0]["l0"]["kv"]
    np.testing.assert_array_equal(np.asarray(k2[:, dst], np.float32),
                                  np.asarray(k2[:, src], np.float32))
    _ref_conserved(pool)


def test_write_table_masks_adopted_prefix_only():
    """tables_device(): read view carries the real ids everywhere; write
    view holds the sentinel exactly over the adopted (read-only) prefix."""
    pool = _pool(n_blocks=10)
    pool.commit(0, 12)
    pool.ensure(0, 12)
    blocks = list(pool._owned[0])
    for b in blocks:
        pool.incref(b)
    pool.release(0)
    pool.commit(1, 20)
    pool.adopt_prefix(1, blocks, 12)
    pool.ensure(1, 17)                      # + 2 private blocks
    t = np.asarray(pool.tables_device())
    assert t.shape == (N_SLOTS, 2, MAX_BLOCKS)
    read, write = t[1, 0], t[1, 1]
    assert list(read[:5]) == list(pool._table[1, :5])
    assert (write[:3] == pool.sentinel).all()      # aliased: write-masked
    assert list(write[3:5]) == list(read[3:5])     # private: writable
    np.testing.assert_array_equal(t[:, 0], np.asarray(pool.table_device()))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_sharing_random_walk_conserves(seed):
    """Property walk over the full sharing lifecycle — normal alloc, cache
    insertion (incref), adoption, COW, truncate, release, eviction
    (decref) — conserving the free/referenced partition at every step."""
    rng = random.Random(seed)
    pool = _pool(n_blocks=12)
    cache: list[list[int]] = []             # simulated cache: held groups
    bound = [False] * N_SLOTS
    shared_len = [0] * N_SLOTS              # adopted tokens per slot
    for _ in range(80):
        op = rng.choice(["commit", "grow", "adopt", "cow", "truncate",
                         "insert_release", "release", "evict"])
        s = rng.randrange(N_SLOTS)
        try:
            if op == "commit" and not bound[s]:
                pool.commit(s, rng.randint(4, MAX_LEN))
                bound[s] = True
                shared_len[s] = 0
            elif op == "grow" and bound[s]:
                pool.ensure(s, min(pool.length(s) + rng.randint(1, 6),
                                   MAX_LEN))
            elif op == "adopt" and bound[s] and not pool._owned[s] and cache:
                grp = rng.choice(cache)
                take = grp[: rng.randint(1, len(grp))]
                pool.adopt_prefix(s, take, len(take) * BLOCK)
                shared_len[s] = len(take) * BLOCK
            elif op == "cow" and bound[s] and cache:
                pool.cow_block(s, rng.choice(rng.choice(cache)))
            elif op == "truncate" and bound[s]:
                pool.truncate(s, rng.randint(shared_len[s], pool.length(s)))
            elif op == "insert_release" and bound[s]:
                grp = [b for b in pool._owned[s]
                       if not any(b in g for g in cache)]
                if grp:
                    for b in grp:
                        pool.incref(b)
                    cache.append(grp)
                pool.release(s)
                bound[s] = False
            elif op == "release" and bound[s]:
                pool.release(s)
                bound[s] = False
            elif op == "evict" and cache:
                grp = cache.pop(rng.randrange(len(cache)))
                for b in grp:
                    pool._decref(b)
        except (OutOfBlocks, SlotError):
            pass
        _ref_conserved(pool)
    # teardown: everything accounted for
    for s in range(N_SLOTS):
        if bound[s]:
            pool.release(s)
    for grp in cache:
        for b in grp:
            pool._decref(b)
    _ref_conserved(pool)
    assert pool.free_block_count == pool.n_blocks


def test_cow_block_on_full_table_leaks_nothing():
    """A COW against a slot whose table is already full must raise WITHOUT
    consuming a free block (pop-then-raise would strand it at refcount 1
    with no owner — unreachable forever)."""
    pool = _pool(n_blocks=12)
    pool.commit(0, MAX_LEN)
    pool.ensure(0, MAX_LEN)                 # table full: MAX_BLOCKS blocks
    src = pool._owned[0][0]
    free0 = pool.free_block_count
    with pytest.raises(OutOfBlocks):
        pool.cow_block(0, src)
    assert pool.free_block_count == free0   # nothing popped
    _ref_conserved(pool)
    pool.release(0)
    assert pool.free_block_count == pool.n_blocks
