"""Per-architecture smoke tests (assignment requirement): reduced same-family
configs, one forward/train step on CPU, shape + no-NaN assertions, and
decode-vs-full-forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.models import lm

SEED = jnp.array([1, 2], jnp.uint32)
ARCHS = registry.names()


def make_batch(cfg, key, b=2, s=16):
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16) * 0.3
    if cfg.enc_dec or cfg.input_mode == "tokens":
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_loads(arch):
    cfg = registry.get(arch)
    assert cfg.d_model % 16 == 0 and cfg.vocab > 0
    specs = lm.layer_specs(cfg)
    n = sum(len(pat) * count for pat, count in specs)
    assert n == cfg.n_layers, (arch, n, cfg.n_layers)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one quantized train step on the reduced config."""
    cfg = registry.get(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    batch = make_batch(cfg, key)

    logits, _, aux = lm.forward(params, cfg, batch, "quartet2", SEED, mode="train")
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    loss, grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, cfg, batch, "quartet2", SEED))(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(not bool(jnp.isnan(g.astype(jnp.float32)).any()) for g in flat)
    # gradient reaches every parameter group (embeddings via labels, mixers, ffs)
    nonzero = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) > 0 for g in flat)
    assert nonzero / len(flat) > 0.9, f"{arch}: only {nonzero}/{len(flat)} grads nonzero"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    """Prefill+decode logits == full-forward logits (bf16 tolerance).

    MoE archs use a generous capacity factor: capacity dropping is batch-
    dependent by construction, exactness only holds when nothing drops.
    """
    cfg = registry.get(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    b, s, extra = 2, 16, 3
    toks = jax.random.randint(key, (b, s + extra), 0, cfg.vocab)
    emb = jax.random.normal(key, (b, s + extra, cfg.d_model), jnp.bfloat16) * 0.3

    if cfg.enc_dec:
        full_in = {"embeds": emb, "tokens": toks}
        pf_in = {"embeds": emb, "tokens": toks[:, :s]}
        cache = lm.init_encdec_cache(cfg, b, s + 8, enc_len=s + extra)
    elif cfg.input_mode == "embeds":
        pytest.skip("vlm decode generates from tokens; prefill checked in smoke")
    else:
        full_in = {"tokens": toks}
        pf_in = {"tokens": toks[:, :s]}
        cache = lm.init_cache(cfg, b, s + 8)

    full, _, _ = lm.forward(params, cfg, full_in, "bf16", SEED, mode="train")
    pf, cache, _ = lm.forward(params, cfg, pf_in, "bf16", SEED, caches=cache, mode="prefill")
    tol = 0.05 * float(jnp.max(jnp.abs(full.astype(jnp.float32))))
    assert float(jnp.max(jnp.abs(pf.astype(jnp.float32) - full[:, :s].astype(jnp.float32)))) < tol
    for step in range(extra):
        dl, cache, _ = lm.forward(params, cfg, {"tokens": toks[:, s + step: s + step + 1]},
                                  "bf16", SEED, caches=cache, mode="decode", pos=s + step)
        err = float(jnp.max(jnp.abs(dl[:, 0].astype(jnp.float32)
                                    - full[:, s + step].astype(jnp.float32))))
        assert err < tol, (arch, step, err)


@pytest.mark.parametrize("arch", ["rwkv6_7b", "recurrentgemma_9b"])
def test_subquadratic_flag(arch):
    assert registry.get(arch).subquadratic


def test_quadratic_archs_skip_long():
    for a in ARCHS:
        cfg = registry.get(a)
        if a in ("rwkv6_7b", "recurrentgemma_9b"):
            continue
        assert not cfg.subquadratic


def test_rwkv_chunked_matches_stepwise():
    """Chunk-parallel WKV == naive per-token recurrence."""
    from repro.models import rwkv6 as W
    b, s, h, d = 2, 24, 2, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, d)) for i in range(3))
    logw = -jnp.abs(jax.random.normal(ks[3], (b, s, h, d))) - 0.05
    logw = jnp.clip(logw, W.LOG_W_MIN, -1e-4)
    u = jax.random.normal(ks[4], (h, d)) * 0.1
    state = jnp.zeros((b, h, d, d))

    out_c, st_c = W.wkv_apply(r, k, v, logw, u, state, chunk=8)

    outs, st = [], state
    for t in range(s):
        o, st = W.wkv_decode(r[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                             logw[:, t:t+1], u, st)
        outs.append(o)
    out_s = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st), rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_stepwise():
    from repro.models import griffin as G
    from repro.configs.base import ArchConfig, GriffinConfig
    cfg = registry.get("recurrentgemma_9b").reduced()
    p = G.rglru_init(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 128), jnp.float32)
    full = G.rglru_scan(p, u)
    h = jnp.zeros((2, 128), jnp.float32)
    outs = []
    for t in range(12):
        o, h = G.rglru_step(p, u[:, t:t+1], h)
        outs.append(o)
    step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(step, np.float32), rtol=3e-3, atol=3e-3)


def test_chunked_sdpa_matches_plain():
    """Online-softmax == plain SDPA (causal + windowed)."""
    from repro.models import attention as A
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    import repro.models.attention as attn
    old_q, old_k = attn.Q_BLOCK, attn.KV_BLOCK
    attn.Q_BLOCK, attn.KV_BLOCK = 16, 16
    try:
        for window in (None, 24):
            ref = A.sdpa(q, k, v, causal=True, window=window)
            out = A.chunked_sdpa(q, k, v, causal=True, window=window)
            np.testing.assert_allclose(np.asarray(out, np.float32),
                                       np.asarray(ref, np.float32), atol=2e-3)
    finally:
        attn.Q_BLOCK, attn.KV_BLOCK = old_q, old_k


def test_moe_capacity_flops_are_sparse():
    """The dispatch buffer is (E, C, D) with C ~ T*k/E — never T x E dense."""
    from repro.models.moe import _capacity
    cfg = registry.get("deepseek_v3_671b")
    c = _capacity(256 * 4096, cfg)
    assert c <= int(256 * 4096 * 8 / 256 * 1.25) + 8


def test_shapes_table():
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524_288
