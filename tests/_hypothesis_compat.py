"""Seeded-random fallback for `hypothesis` when the real package is absent.

The container cannot pip-install offline, so the property tests fall back to
this shim: `given` draws `max_examples` pseudo-random examples from the
declared strategies using a PRNG seeded by the test's qualified name —
deterministic across runs, so failures reproduce. Only the strategy surface
this repo actually uses is implemented (floats / integers / sampled_from /
tuples / booleans); anything fancier should use the real package.

Usage (at the top of a test module):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st
"""

from __future__ import annotations

import functools
import inspect
import random as _random

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: _random.Random):
        return self._sample(rng)


class strategies:  # noqa: N801 — mimics the `hypothesis.strategies` module
    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        edges = [lo, hi, 0.5 * (lo + hi)]

        def draw(rng):
            # occasionally hit the boundaries, like hypothesis does
            if rng.random() < 0.15:
                return rng.choice(edges)
            return rng.uniform(lo, hi)

        return _Strategy(draw)

    @staticmethod
    def integers(min_value=0, max_value=1 << 30, **_kw):
        lo, hi = int(min_value), int(max_value)

        def draw(rng):
            if rng.random() < 0.15:
                return rng.choice([lo, hi])
            return rng.randint(lo, hi)

        return _Strategy(draw)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def tuples(*strats):
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)


st = strategies


def given(*strats, **kw_strats):
    """Decorator: run the test once per drawn example (deterministic seed)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args):  # args is () or (self,)
            n = getattr(wrapper, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = _random.Random(f"hypothesis-compat:{fn.__qualname__}")
            for _ in range(n):
                pos = [s.sample(rng) for s in strats]
                kws = {k: s.sample(rng) for k, s in kw_strats.items()}
                fn(*args, *pos, **kws)

        # pytest must not see the strategy-filled params (it would treat them
        # as fixtures): expose only the leading params (e.g. `self`).
        params = list(inspect.signature(fn).parameters.values())
        keep = params[: len(params) - len(strats) - len(kw_strats)]
        wrapper.__signature__ = inspect.Signature(keep)
        del wrapper.__wrapped__
        wrapper._compat_given = True
        return wrapper

    return deco


def settings(max_examples=None, deadline=None, **_kw):
    """Decorator: records max_examples for the shim `given` (deadline ignored)."""

    def deco(fn):
        if max_examples is not None:
            fn._compat_max_examples = int(max_examples)
        return fn

    return deco
