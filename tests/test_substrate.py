"""Substrate tests: optimizers, schedules, data determinism, checkpointing,
fault-tolerant trainer (bitwise resume), NVFP4 gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import lm
from repro.optim import adamw, muon, schedules
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = registry.get("llama_200m").reduced()
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=4, seed=3))
    init_state, train_step = make_train_step(
        cfg, "quartet2", base_lr=1e-3, total_steps=50, base_seed=1)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, corpus, init_state, jax.jit(train_step), params


class TestOptim:
    def test_adamw_converges_quadratic(self):
        p = {"w": jnp.asarray([5.0, -3.0])}
        st = adamw.init(p)
        for _ in range(300):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)
            p, st = adamw.update(g, st, p, lr=0.05, weight_decay=0.0)
        assert float(jnp.abs(p["w"]).max()) < 0.1

    def test_muon_newton_schulz_orthogonalizes(self):
        """Muon's 5-step NS is deliberately approximate: singular values land
        in a band around 1 (Jordan et al. report ~[0.7, 1.2]), not exactly 1."""
        g = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
        s_in = np.linalg.svd(np.asarray(g), compute_uv=False)
        o = muon.newton_schulz(g)
        s_out = np.linalg.svd(np.asarray(o), compute_uv=False)
        assert s_in.max() / s_in.min() > 3          # input is ill-conditioned
        assert 0.3 < s_out.min() and s_out.max() < 1.4  # output is near-orthogonal

    def test_muon_partition(self):
        params = {"embed": jnp.zeros((8, 4)), "stages": {"w": jnp.zeros((4, 4))},
                  "norm": jnp.zeros((4,))}
        mask = muon.partition_mask(params)
        assert mask["stages"]["w"] and not mask["embed"] and not mask["norm"]

    def test_schedules(self):
        lr = schedules.warmup_cosine(0, base_lr=1.0, total_steps=100)
        assert float(lr) == 0.0
        lr_mid = schedules.warmup_cosine(55, base_lr=1.0, total_steps=100)
        lr_end = schedules.warmup_cosine(99, base_lr=1.0, total_steps=100)
        assert float(lr_mid) > float(lr_end) >= 0
        w = schedules.wsd(50, base_lr=1.0, total_steps=100)
        assert float(w) == 1.0  # stable phase


class TestData:
    def test_deterministic_and_resumable(self):
        c = SyntheticCorpus(DataConfig(vocab=128, seq_len=16, global_batch=4))
        a = c.batch_at(7)
        b = c.batch_at(7)
        assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(c.batch_at(8)["tokens"]))

    def test_sharding_partitions_batch(self):
        c = SyntheticCorpus(DataConfig(vocab=128, seq_len=16, global_batch=8))
        s0 = c.batch_at(3, shard_id=0, num_shards=2)
        s1 = c.batch_at(3, shard_id=1, num_shards=2)
        assert s0["tokens"].shape == (4, 16)
        assert not np.array_equal(np.asarray(s0["tokens"]), np.asarray(s1["tokens"]))

    def test_labels_are_shifted_tokens(self):
        c = SyntheticCorpus(DataConfig(vocab=128, seq_len=16, global_batch=2))
        b = c.batch_at(0)
        assert np.array_equal(np.asarray(b["tokens"][:, 1:]),
                              np.asarray(b["labels"][:, :-1]))

    def test_bigram_structure_learnable(self):
        """Perfect bigram predictions must beat unigram entropy (the corpus
        has signal, so QAT loss gaps are meaningful)."""
        c = SyntheticCorpus(DataConfig(vocab=64, seq_len=128, global_batch=8))
        b = c.batch_at(0)
        toks = np.asarray(b["tokens"]).reshape(-1)
        perm = np.asarray(c._perm)
        hits = (perm[toks[:-1]] == toks[1:]).mean()
        assert hits > 0.3  # ~half the transitions follow the bigram kernel


class TestCheckpointer:
    def test_roundtrip_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                 "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        for s in (1, 2, 3):
            ck.save(s, state, {"tag": s})
        assert ck.all_steps() == [2, 3]  # gc keeps last 2
        like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
        restored, meta = ck.restore(like)
        assert meta["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(state["a"]))

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(5, {"x": jnp.ones((128, 128))}, blocking=False)
        ck.wait()
        assert ck.latest_step() == 5

    def test_atomicity_no_partial_dirs(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"x": jnp.ones((4,))})
        assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


class TestTrainerFaultTolerance:
    def test_bitwise_resume(self, tmp_path, tiny_setup):
        """Crash at step 6, restore, continue — must equal the uninterrupted
        run bitwise (deterministic data + step-seeded quantization)."""
        cfg, corpus, init_state, train_step, params = tiny_setup

        def fresh():
            return init_state(jax.tree.map(jnp.copy, params))

        # uninterrupted 10 steps
        s = fresh()
        for i in range(10):
            s, _ = train_step(s, corpus.batch_at(i))
        ref_leaf = np.asarray(jax.tree.leaves(s.params)[0])

        # interrupted at 6 + resumed
        tcfg = TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path / "ck"),
                             ckpt_every=1000, log_every=1000, async_ckpt=False)
        tr = Trainer(tcfg, train_step, corpus)
        s2 = tr.run(fresh(), resume=False)          # saves final ckpt at 6
        tcfg2 = TrainerConfig(total_steps=10, ckpt_dir=str(tmp_path / "ck"),
                              ckpt_every=1000, log_every=1000, async_ckpt=False)
        tr2 = Trainer(tcfg2, train_step, corpus)
        s3 = tr2.run(fresh(), resume=True)          # restores step 6 -> 10
        out_leaf = np.asarray(jax.tree.leaves(s3.params)[0])
        np.testing.assert_array_equal(ref_leaf, out_leaf)

    def test_emergency_checkpoint_on_exception(self, tmp_path, tiny_setup):
        cfg, corpus, init_state, train_step, params = tiny_setup

        calls = {"n": 0}

        def exploding_step(state, batch):
            calls["n"] += 1
            if calls["n"] == 3:
                raise RuntimeError("simulated node failure")
            return train_step(state, batch)

        tcfg = TrainerConfig(total_steps=10, ckpt_dir=str(tmp_path / "ck"),
                             ckpt_every=1000, log_every=1000, async_ckpt=False)
        tr = Trainer(tcfg, exploding_step, corpus)
        with pytest.raises(RuntimeError):
            tr.run(init_state(params), resume=False)
        assert tr.ckpt.latest_step() is not None  # emergency ckpt exists

    def test_elastic_restore_different_structure_checks(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"a": jnp.ones((4,))})
        with pytest.raises(AssertionError):
            ck.restore({"a": jnp.ones((4,)), "b": jnp.ones((2,))})


class TestGradCompression:
    def test_compressed_mean_is_accurate_and_unbiased(self):
        """shard_map NVFP4 all-reduce ~= exact mean; averaging over seeds
        converges (unbiasedness)."""
        # the container exposes one device; run the 4-way mesh in a
        # subprocess with forced host-platform devices
        import subprocess, sys, textwrap
        code = textwrap.dedent('''
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp, numpy as np
            from repro.dist import shard_map  # version-compat wrapper
            from jax.sharding import Mesh, PartitionSpec as P
            from repro.dist.compression import compressed_psum_mean
            mesh = Mesh(np.asarray(jax.devices()), ("data",))
            x = jax.random.normal(jax.random.PRNGKey(0), (4, 2048), jnp.float32)
            want = jnp.mean(x, axis=0)
            f = jax.jit(shard_map(
                lambda xs, seed: compressed_psum_mean(xs[0], "data", seed),
                mesh=mesh, in_specs=(P("data", None), P()), out_specs=P(),
                check_vma=False))
            outs = jnp.stack([f(x, jnp.asarray([5, i], jnp.uint32)) for i in range(32)])
            one = float(jnp.linalg.norm(outs[0] - want) / jnp.linalg.norm(want))
            avg = jnp.mean(outs, 0)
            many = float(jnp.linalg.norm(avg - want) / jnp.linalg.norm(want))
            assert one < 0.2, one
            assert many < one / 2, (one, many)
            print("OK", one, many)
        ''')
        env = dict(os.environ, PYTHONPATH="src")
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, cwd=os.getcwd())
        assert r.returncode == 0 and "OK" in r.stdout, r.stdout + r.stderr

    def test_wire_bytes_are_4bit(self):
        """The all_to_all payload is packed uint8 nibbles + fp8 scales."""
        from repro.core import formats as F
        codes = jnp.zeros((4, 256), jnp.uint8)
        packed = F.pack_fp4(codes)
        bits_per_elem = (packed.size * 8 + (256 // 16) * 4 * 8) / (4 * 256)
        assert bits_per_elem <= 4.5
