"""Cancellation-race coverage (ISSUE satellite): `cancel()` landing at
every phase boundary of a request's lifecycle.

The engine tick is host-atomic — `cancel()` can only ever land BETWEEN
`_admit` / `_prefill_tick` / `_decode_tick` phases, never inside one — so
the race surface is exactly the phase boundaries. Each test drives the
engine's phases by hand to freeze a request at one boundary, cancels
there, and asserts the two robustness invariants the frontend relies on:

  1. Pool conservation: every block is free or held by the prefix cache
     (refcounts partition the pool; nothing leaks to the dead request).
  2. Prefix reuse: the committed partial prefix hot-hits on resubmission —
     cancelled work is cached, not discarded (cache-insert-then-release).

Driven with a fake clock throughout (clock-discipline satellite): no test
here sleeps or reads the wall clock.
"""

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serve.engine import DECODE, PREFILL, EngineConfig, Request, \
    ServeEngine
from repro.serve.frontend import make_disagg_pair

pytestmark = pytest.mark.serve


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        self.t += 1e-6  # strictly monotonic, deterministic
        return self.t


@pytest.fixture(scope="module")
def cfg():
    return registry.get("yi_9b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init(cfg, jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("clock", FakeClock())
    return ServeEngine(cfg, params, EngineConfig(**kw))


def _prompt(cfg, n=24, seed=1):
    rng = np.random.RandomState(seed)
    return list(map(int, rng.randint(0, cfg.vocab, n)))


def _assert_conserved(eng):
    """Every pool block is free xor referenced, and the referenced ones are
    exactly the prefix cache's holdings once no slot is live."""
    held = eng.cache.cached_blocks() if eng.cache is not None else 0
    assert eng.pool.free_block_count + held == eng.pool.n_blocks
    ref_blocks = sum(1 for b in range(eng.pool.n_blocks)
                     if eng.pool.refcount(b) > 0)
    assert ref_blocks == held


def _slot_of(eng, rid):
    for i, s in enumerate(eng.slots):
        if s.req is not None and s.req.req_id == rid:
            return i
    return None


# --------------------------------------------------------------------------
# race 1: cancel between _admit and the FIRST _prefill_tick
# --------------------------------------------------------------------------


def test_cancel_between_admit_and_first_prefill(cfg, params):
    eng = _engine(cfg, params)
    rid = eng.submit(Request(prompt=_prompt(cfg), max_new=8))
    eng._admit()  # slot placed + blocks committed, zero tokens written
    i = _slot_of(eng, rid)
    assert i is not None and eng.slots[i].state == PREFILL
    assert eng.slots[i].cursor == 0

    assert eng.cancel(rid)
    assert eng.stats["cancelled"] == 1
    assert not eng.has_work()
    # nothing was written, so nothing is cacheable — but the COMMITTED
    # blocks must all return to the free lists
    _assert_conserved(eng)
    assert eng.cache.cached_blocks() == 0

    # the engine is fully usable afterwards: same prompt runs cold
    rid2 = eng.submit(Request(prompt=_prompt(cfg), max_new=4))
    res = {r.req_id: r for r in eng.run()}
    assert len(res[rid2].tokens) == 4
    assert eng.stats["prefix_hits"] == 0  # nothing was cached to hit


def test_cancel_queued_request_never_touches_pool(cfg, params):
    eng = _engine(cfg, params)
    rid = eng.submit(Request(prompt=_prompt(cfg), max_new=8))
    assert eng.cancel(rid)  # still queued: pure bookkeeping
    assert eng.pool.free_block_count == eng.pool.n_blocks
    assert not eng.has_work()
    assert not eng.cancel(rid)  # idempotent: unknown id now


# --------------------------------------------------------------------------
# race 2: cancel DURING a chunked prefill (cursor mid-prompt)
# --------------------------------------------------------------------------


def test_cancel_mid_chunked_prefill_caches_partial_prefix(cfg, params):
    eng = _engine(cfg, params)  # chunk 8, prompt 24 -> 3 chunks
    prompt = _prompt(cfg)
    rid = eng.submit(Request(prompt=list(prompt), max_new=8))
    eng._admit()
    i = _slot_of(eng, rid)
    eng._prefill_tick()  # chunk 1 of 3
    eng._prefill_tick()  # chunk 2 of 3
    slot = eng.slots[i]
    assert slot.state == PREFILL and 0 < slot.cursor < len(prompt)
    written = eng.pool.length(i)
    assert written == 16  # two full chunks committed to the cache

    assert eng.cancel(rid)
    _assert_conserved(eng)
    # the partial prefix was inserted: 16 written tokens = 1 full block
    # (block_size 16); partial blocks are never cached
    assert eng.cache.cached_blocks() == written // eng.pool.block_size

    # resubmission hot-hits the cancelled request's partial prefill
    rid2 = eng.submit(Request(prompt=list(prompt), max_new=8))
    res = {r.req_id: r for r in eng.run()}
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefill_skipped_tokens"] == 16
    assert len(res[rid2].tokens) == 8
    _assert_conserved(eng)


# --------------------------------------------------------------------------
# race 3: cancel MID-DECODE (generated tokens in flight)
# --------------------------------------------------------------------------


def test_cancel_mid_decode_caches_prompt_plus_generated(cfg, params):
    eng = _engine(cfg, params)
    prompt = _prompt(cfg)
    # reference stream for the exactness check below
    ref_rid = eng.submit(Request(prompt=list(prompt), max_new=8))
    ref = {r.req_id: r.tokens for r in eng.run()}[ref_rid]
    eng2 = _engine(cfg, params)

    rid = eng2.submit(Request(prompt=list(prompt), max_new=8))
    while True:  # step into decode with >= 2 generated tokens
        eng2.step()
        i = _slot_of(eng2, rid)
        if i is not None and eng2.slots[i].state == DECODE \
                and len(eng2.slots[i].generated) >= 2:
            break
    gen = list(eng2.slots[i].generated)
    assert eng2.cancel(rid)
    assert eng2.stats["cancelled"] == 1
    _assert_conserved(eng2)
    # prompt + generated tokens were cached up to the written length's
    # block boundary — the decode work survives the cancel
    assert eng2.cache.cached_blocks() >= 1

    # a follow-up over prompt + generated continues BITWISE on the cached
    # prefix: the cancelled stream's tokens were not wasted
    rid2 = eng2.submit(Request(prompt=prompt + gen, max_new=8 - len(gen)))
    res = {r.req_id: r for r in eng2.run()}
    assert eng2.stats["prefix_hits"] == 1
    assert eng2.stats["prefill_skipped_tokens"] > 0
    assert gen + res[rid2].tokens == ref
    _assert_conserved(eng2)


def test_cancel_one_of_many_leaves_neighbors_bitwise_intact(cfg, params):
    """Row-local decode contract under cancellation: killing one slot
    mid-decode must not perturb any other slot's stream.

    Runs under scheme="bf16": the row-local bitwise claim only holds there
    (CONVENTIONS SS3 — quartet2's per-tensor activation absmax is
    batch-coupled by design, so its guarantee is determinism, not
    neighbor-independence)."""
    rng = np.random.RandomState(3)
    prompts = [list(map(int, rng.randint(0, cfg.vocab, n)))
               for n in (9, 13, 11)]
    ref_eng = _engine(cfg, params, n_slots=3, prefix_cache=False,
                      scheme="bf16")
    ids = [ref_eng.submit(Request(prompt=list(p), max_new=8))
           for p in prompts]
    ref = {r.req_id: r.tokens for r in ref_eng.run()}
    ref_tokens = [ref[i] for i in ids]

    eng = _engine(cfg, params, n_slots=3, prefix_cache=False,
                  scheme="bf16")
    ids = [eng.submit(Request(prompt=list(p), max_new=8)) for p in prompts]
    early = []
    while True:  # victim decoding, every live slot decoding
        early.extend(eng.step())
        v = _slot_of(eng, ids[1])
        if v is not None and eng.slots[v].state == DECODE \
                and all(s.state == DECODE for s in eng.slots
                        if s.req is not None):
            break
    eng.cancel(ids[1])
    res = {r.req_id: r for r in early + eng.run()}
    assert res[ids[0]].tokens == ref_tokens[0]
    assert res[ids[2]].tokens == ref_tokens[2]
    assert ids[1] not in res
    assert eng.pool.free_block_count == eng.pool.n_blocks


# --------------------------------------------------------------------------
# cancel vs retirement: the losing side must be a clean no-op
# --------------------------------------------------------------------------


def test_cancel_after_retirement_is_noop(cfg, params):
    eng = _engine(cfg, params)
    rid = eng.submit(Request(prompt=_prompt(cfg, n=9), max_new=4))
    res = eng.run()
    assert len(res) == 1
    assert not eng.cancel(rid)  # already retired: False, no state change
    assert eng.stats["cancelled"] == 0
    _assert_conserved(eng)


def _pair(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("prequant", False)
    kw.setdefault("clock", FakeClock())
    return make_disagg_pair(cfg, params, EngineConfig(**kw))


def _pair_conserved(pair):
    """Both engines' pools fully reclaimed: prefill blocks are free or
    prefix-cached, decode blocks (no cache on the decode worker) all free."""
    pe, de = pair.prefill, pair.decode
    held = pe.cache.cached_blocks() if pe.cache is not None else 0
    assert pe.pool.free_block_count + held == pe.pool.n_blocks
    assert de.pool.free_block_count == de.pool.n_blocks


# --------------------------------------------------------------------------
# disaggregation races: cancel landing around the prefill->decode handoff
# --------------------------------------------------------------------------


def test_cancel_in_transit_handoff_reclaims_both_engines(cfg, params):
    """cancel() landing while the finished prefill sits in the in-transit
    deque — after the prefill worker retired the slot, before the decode
    worker admitted the Handoff. The pair must drop it there: the decode
    worker never sees the request, both pools conserve, and the prompt
    prefix the cancelled request paid for stays cached for the next hit."""
    pair = _pair(cfg, params)
    rid = pair.submit(Request(prompt=_prompt(cfg), max_new=8))
    while not pair.prefill.handoffs:
        pair.prefill.step()     # drive ONLY the prefill worker: the export
    assert pair.cancel(rid)     # ...parks in transit, and dies there
    assert pair.stats["cancelled"] == 1
    assert not pair.has_work()
    assert pair.decode.stats["finished"] == 0
    assert pair.decode.free_slots == pair.decode.pool.n_slots
    _pair_conserved(pair)
    # resubmission hot-hits the cancelled request's exported prompt prefix
    rid2 = pair.submit(Request(prompt=_prompt(cfg), max_new=4))
    res = {r.req_id: r for r in pair.run()}
    assert len(res[rid2].tokens) == 4
    assert pair.prefill.stats["prefix_hits"] >= 1
    assert pair.stats["prefill_skipped_tokens"] > 0
    _pair_conserved(pair)


def test_cancel_mid_decode_on_decode_worker(cfg, params):
    """cancel() after the handoff landed: the pair routes it through the
    DECODE worker (the prefill worker no longer knows the id). Its slot and
    blocks come back, and both engines keep serving."""
    pair = _pair(cfg, params)
    rid = pair.submit(Request(prompt=_prompt(cfg), max_new=8))
    while True:                 # step the PAIR until decode is mid-stream
        pair.step()
        i = _slot_of(pair.decode, rid)
        if i is not None and len(pair.decode.slots[i].generated) >= 2:
            break
    assert pair.cancel(rid)
    assert pair.decode.stats["cancelled"] == 1
    assert pair.prefill.stats["cancelled"] == 0
    assert not pair.has_work()
    _pair_conserved(pair)
    rid2 = pair.submit(Request(prompt=_prompt(cfg, n=9, seed=2), max_new=3))
    res = {r.req_id: r for r in pair.run()}
    assert len(res[rid2].tokens) == 3
    _pair_conserved(pair)


def test_cancel_storm_conserves_pool(cfg, params):
    """Admit/cancel churn at every phase: after any interleaving, blocks
    partition into free + cached and the engine still serves."""
    eng = _engine(cfg, params, n_slots=2)
    rng = np.random.RandomState(7)
    for round_ in range(6):
        prompt = list(map(int, rng.randint(0, cfg.vocab, 17 + round_)))
        rid = eng.submit(Request(prompt=prompt, max_new=6))
        for _ in range(round_):  # cancel later and later each round
            if eng.has_work():
                eng.step()
        eng.cancel(rid)
        while eng.has_work():  # drain any still-running work
            eng.step()
        _assert_conserved(eng)
    final = eng.submit(Request(prompt=_prompt(cfg, n=9, seed=9), max_new=4))
    res = {r.req_id: r for r in eng.run()}
    assert len(res[final].tokens) == 4
    _assert_conserved(eng)
