"""Hierarchical prefix-cache suite: host spill tier, swap-in, replication.

What the tier machinery must preserve (ISSUE acceptance):

  (a) EXTENDED CONSERVATION — at every step of a random
      alloc/evict/spill/swap-in/replicate walk, the device blocks
      partition exactly: free + referenced == n_blocks, every block's
      refcount equals its slot-table references plus its prefix-cache
      copies, the cache's block accounting (cached + in-flight swap-ins)
      matches the tree, and `host_bytes` equals the sum of every node's
      held snapshot;
  (b) BITWISE STREAM PARITY — a request whose matched prefix was evicted
      to the host tier performs ZERO prefill forwards over that prefix
      and emits a greedy bf16 stream bitwise-equal to the cold run
      (cold == device-hot == spill-hot); quantized (kv_quant) pools spill
      and swap the packed bytes verbatim, so their spill-hot stream is
      byte-exact against device-hot too;
  (c) cross-shard replication copies hot prefixes into peer shards
      without ever evicting, and the copies adopt like home-shard ones.

Strategies come from tests/_hypothesis_compat.py when hypothesis is absent
(offline container): examples are seeded by the test's qualified name, so
failures reproduce deterministically.
"""

import random

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded-random fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serve.engine import EngineConfig, Request, ServeEngine, Unservable
from repro.serve.kv_pool import KVPool, OutOfBlocks, PackedKV
from repro.serve.prefix_cache import PrefixCache

pytestmark = pytest.mark.serve

N_SLOTS, MAX_LEN, BLOCK = 4, 32, 4


def _tiny_cfg() -> ArchConfig:
    return ArchConfig(name="tier-test", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      head_dim=16)


def _pool(n_blocks=16, n_shards=2) -> KVPool:
    return KVPool(_tiny_cfg(), N_SLOTS, MAX_LEN, paged=True,
                  block_size=BLOCK, n_blocks=n_blocks, n_shards=n_shards)


def _tier_conserved(pool: KVPool, cache: PrefixCache) -> None:
    """The extended conservation invariant, checked structurally."""
    # every block's refcount == its slot-table references + cache copies
    table_refs = np.zeros(pool.n_blocks, np.int64)
    for s in range(pool.n_slots):
        for b in pool._table[s]:
            if b != pool.sentinel:
                table_refs[b] += 1
    cache_refs = np.zeros(pool.n_blocks, np.int64)
    host_bytes = 0

    def walk(n):
        nonlocal host_bytes
        for c in n.children.values():
            for b in c.blocks.values():
                cache_refs[b] += 1
            if c.host is not None:
                host_bytes += c.host_bytes
            walk(c)

    walk(cache.root)
    np.testing.assert_array_equal(np.asarray(pool._ref, np.int64),
                                  table_refs + cache_refs)
    # device partition: free + referenced == n_blocks (no block in both)
    live = int((np.asarray(pool._ref) > 0).sum())
    assert pool.free_block_count + live == pool.n_blocks
    assert all(pool.refcount(b) == 0 for b in pool._free)
    # cache-side block accounting matches the tree exactly
    assert (cache.cached_blocks() + cache.inflight_swaps
            == int(cache_refs.sum()))
    # host-tier byte accounting matches the held snapshots exactly
    assert host_bytes == cache.host_bytes


def _admit(pool, cache, slot, prompt, total):
    """Engine-shaped hot admission against a bare pool: match, pin,
    materialize on the slot's shard, adopt + COW, prefill-equivalent
    ensure. Returns the pinned adopt path (to release at retirement) or
    None when the admission failed and everything was rolled back."""
    m = cache.match(prompt)
    mtoks, adopt, tail = m.plan(len(prompt) - 1, BLOCK)
    pinned = adopt + ([tail] if tail is not None else [])
    use = mtoks > 0
    if use:
        cache.acquire(pinned)
        try:
            cache.materialize(pinned, pool.shard_of_slot(slot))
        except OutOfBlocks:
            cache.release(pinned)
            use = False
    try:
        pool.commit(slot, total)
    except OutOfBlocks:
        if use:
            cache.release(pinned)
        return None
    pins = []
    if use:
        sh = pool.shard_of_slot(slot)
        try:
            if adopt:
                pool.adopt_prefix(slot, [n.blocks[sh] for n in adopt],
                                  len(adopt) * BLOCK)
            if tail is not None:
                pool.cow_block(slot, tail.blocks[sh])
            pool.ensure(slot, mtoks)
        except OutOfBlocks:
            cache.release(pinned)
            pool.release(slot)
            return None
        if tail is not None:
            cache.release([tail])  # private COW copy made; unpin the tail
        pins = adopt
        cache.record(m)
    try:
        pool.ensure(slot, total)  # the prefill the engine would run
    except OutOfBlocks:
        pass  # partially backed is fine for the walk
    return pins


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_tier_fuzz_extended_conservation(seed):
    """Property walk over admit(hot/cold)/retire/evict-spill/swap-in/
    replicate/trim/complete sequences on a 2-shard pool: the extended
    conservation invariant holds after EVERY operation."""
    rng = random.Random(seed)
    budget = rng.choice([None, 6000, 20000])
    pool = _pool(n_blocks=16, n_shards=2)
    cache = PrefixCache(pool, spill=True, host_budget_bytes=budget,
                        replicate_hits=2)
    bound: dict[int, tuple[list[int], list]] = {}  # slot -> (prompt, pins)
    for _ in range(60):
        op = rng.choice(["admit", "retire", "evict", "replicate",
                         "complete", "admit", "retire"])
        if op == "admit":
            free = [s for s in range(N_SLOTS) if s not in bound]
            if not free:
                continue
            s = rng.choice(free)
            total = rng.randint(5, MAX_LEN)
            # tiny alphabet: prompts collide, the tree actually shares
            prompt = [rng.randrange(4) for _ in range(total)]
            pins = _admit(pool, cache, s, prompt, total)
            if pins is not None:
                bound[s] = (prompt, pins)
        elif op == "retire" and bound:
            s = rng.choice(list(bound))
            prompt, pins = bound.pop(s)
            cache.insert(prompt[:pool.length(s)], s)
            if pins:
                cache.release(pins)
            pool.release(s)
        elif op == "evict":
            cache.evict(rng.choice([None, 0, 1]), rng.randint(1, 4))
        elif op == "replicate":
            cache.replicate_hot(budget=rng.randint(1, 3))
        elif op == "complete":
            cache.complete_swaps()
        _tier_conserved(pool, cache)
        if budget is not None:
            assert cache.host_bytes <= budget
    # teardown: release slots, drop the whole cache — everything comes back
    for s, (prompt, pins) in bound.items():
        if pins:
            cache.release(pins)
        pool.release(s)
    cache.complete_swaps()
    cache.evict(None, pool.n_blocks)
    _tier_conserved(pool, cache)
    assert pool.free_block_count == pool.n_blocks


def test_spill_on_evict_keeps_node_matchable():
    """Eviction under spill snapshots bytes host-side and keeps the path
    matchable; without spill the path vanishes. Swap-in restores device
    copies and the refcount partition."""
    pool = _pool()
    cache = PrefixCache(pool, spill=True)
    prompt = [1] * 12
    pool.commit(0, 12)
    pool.ensure(0, 12)
    cache.insert(prompt, 0)
    pool.release(0)
    assert cache.cached_blocks() == 3
    freed = cache.evict(None, 99)
    assert freed == 3
    assert cache.cached_blocks() == 0
    assert cache.host_nodes() == 3
    assert cache.host_bytes > 0
    assert cache.stats["spilled_blocks"] == 3
    m = cache.match(prompt)
    assert m.tokens == 12                    # still matchable, host-only
    cache.acquire(m.nodes)
    assert cache.materialize(m.nodes, 1) == 3   # swap in on the OTHER shard
    assert cache.inflight_swaps == 3
    assert cache.cached_blocks() == 0        # in-flight until tick boundary
    cache.complete_swaps()
    assert cache.cached_blocks() == 3
    assert all(1 in n.blocks for n in m.nodes)
    cache.release(m.nodes)
    _tier_conserved(pool, cache)


def test_spill_hint_weights_host_only_tokens_half():
    """Scheduler admission hint: resident matched tokens count in full,
    host-only (spilled) ones half — a swap-in is cheaper than prefill but
    not free (serve/scheduler.py cache-aware ordering)."""
    pool = _pool()
    cache = PrefixCache(pool, spill=True)
    prompt = [2] * 8
    pool.commit(0, 8)
    pool.ensure(0, 8)
    cache.insert(prompt, 0)
    pool.release(0)
    assert cache.hint_tokens(cache.match(prompt)) == 8
    cache.evict(None, 99)                     # both blocks to the host tier
    assert cache.hint_tokens(cache.match(prompt)) == 4
    drop = PrefixCache(_pool(), spill=False)
    assert drop.hint_tokens(drop.match(prompt)) == 0  # dropped: no match


def test_replicate_hot_copies_into_peer_shard():
    """Nodes matched past `replicate_hits` get device copies on peer
    shards through the host tier — free blocks only, never evicting —
    and the replicas adopt exactly like home-shard blocks."""
    pool = _pool(n_blocks=16, n_shards=2)
    cache = PrefixCache(pool, spill=True, replicate_hits=2)
    prompt = [3] * 8
    pool.commit(0, 8)                          # slot 0 homes on shard 0
    pool.ensure(0, 8)
    cache.insert(prompt, 0)
    pool.release(0)
    assert cache.replicate_hot(budget=8) == 0  # not hot yet
    for _ in range(2):
        cache.record(cache.match(prompt))
    done = cache.replicate_hot(budget=8)
    assert done == 2
    assert cache.stats["replicated_blocks"] == 2
    cache.complete_swaps()
    m = cache.match(prompt)
    assert all(set(n.blocks) == {0, 1} for n in m.nodes)
    _tier_conserved(pool, cache)
    # the shard-1 replicas adopt for a shard-1 slot with zero swap-ins
    cache.acquire(m.nodes)
    assert cache.materialize(m.nodes, 1) == 0
    pool.commit(2, 10)                         # slot 2 homes on shard 1
    pool.adopt_prefix(2, [n.blocks[1] for n in m.nodes], 8)
    _tier_conserved(pool, cache)
    pool.release(2)
    cache.release(m.nodes)
    _tier_conserved(pool, cache)


def test_replication_never_evicts():
    """replicate_hot on a shard with an empty free list is a no-op — it
    must not trigger the evict hook to make room."""
    pool = _pool(n_blocks=8, n_shards=2)       # 4 blocks per shard
    cache = PrefixCache(pool, spill=True, replicate_hits=1)
    prompt = [1] * 8
    pool.commit(0, 8)
    pool.ensure(0, 8)
    cache.insert(prompt, 0)
    pool.release(0)
    cache.record(cache.match(prompt))
    pool.commit(2, 16)                         # slot 2 drains shard 1 fully
    pool.ensure(2, 16)
    assert pool.free_blocks_in_shard(1) == 0
    evicted0 = cache.stats["evicted_blocks"]
    assert cache.replicate_hot(budget=8) == 0
    assert cache.stats["evicted_blocks"] == evicted0
    pool.release(2)
    _tier_conserved(pool, cache)


def test_host_budget_trims_lru_snapshots():
    """`host_budget_bytes` bounds the tier: LRU snapshots are dropped
    (nodes with device copies keep matchability; a host-only childless
    node leaves the tree, bumping the epoch)."""
    pool = _pool()
    cache = PrefixCache(pool, spill=True)
    one_block = None
    for s, base in ((0, 4), (1, 5)):
        pool.commit(s, 16)
        pool.ensure(s, 16)
        cache.insert([base] * 16, s)
        pool.release(s)
    cache.evict(None, 99)                      # 8 snapshots on the host
    assert cache.host_nodes() == 8
    one_block = cache.host_bytes // 8
    cache.host_budget_bytes = 3 * one_block
    epoch0 = cache.epoch
    cache._trim_host()
    assert cache.host_bytes <= 3 * one_block
    assert cache.epoch > epoch0                # host-only nodes left the tree
    _tier_conserved(pool, cache)


def test_spill_requires_prefix_cache():
    cfg = registry.get("yi_9b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="prefix_spill"):
        ServeEngine(cfg, params, EngineConfig(
            n_slots=2, max_len=64, scheme="bf16", prequant=False,
            prefix_spill=True))


def test_decode_role_rejects_prompt_submissions():
    """A decode-role engine takes Handoffs, not prompts; a both-role
    engine takes prompts, not Handoffs."""
    cfg = registry.get("yi_9b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    de = ServeEngine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, scheme="bf16", prequant=False,
        role="decode"))
    with pytest.raises(Unservable, match="decode-role"):
        de.submit(Request(prompt=[1, 2, 3], max_new=2))
    assert de.stats["rejected"] == 1
    both = ServeEngine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, scheme="bf16", prequant=False))
    with pytest.raises(ValueError, match="non-decode"):
        both.submit_handoff(None)


# --------------------------------------------------------------------------
# bitwise stream parity across tiers (acceptance criterion)
# --------------------------------------------------------------------------

def _serve_cfg():
    return registry.get("yi_9b").reduced()


def _tier_prompts(cfg):
    rng = np.random.RandomState(1)
    shared = list(map(int, rng.randint(0, cfg.vocab, 16)))
    probe = shared + list(map(int, rng.randint(0, cfg.vocab, 7)))
    return shared + list(map(int, rng.randint(0, cfg.vocab, 5))), probe


def _one_stream(eng, prompt, max_new=4):
    eng.submit(Request(prompt=prompt, max_new=max_new))
    return [r.tokens for r in eng.run()][0]


@pytest.mark.parametrize("kv_quant", [False, True],
                         ids=["bf16", "kv_quant"])
def test_spill_hot_stream_parity_and_zero_prefix_prefill(kv_quant):
    """cold == device-hot == spill-hot, token for token (bf16 exact; the
    kv_quant pool spills/swaps its packed bytes verbatim, so its spill-hot
    stream is byte-exact against device-hot AND cold — deterministic RTN
    writes the same packed block either way). The spill-hot request runs
    ZERO prefill forwards over the matched prefix: its prefill step/token
    counts equal the device-hot run's."""
    cfg = _serve_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    warm, probe = _tier_prompts(cfg)

    def engine(**kw):
        return ServeEngine(cfg, params, EngineConfig(
            n_slots=2, max_len=64, prefill_chunk=8, block_size=8,
            scheme="bf16", prequant=False, kv_quant=kv_quant, **kw))

    cold = _one_stream(engine(), probe)

    def hot_run(spill):
        eng = engine(prefix_cache=True, prefix_spill=spill)
        _one_stream(eng, warm)                 # prime the cache
        if spill:
            freed = eng.cache.evict(None, 999)
            assert freed >= 3                  # the whole warm stream left
            assert eng.cache.cached_blocks() == 0
            assert eng.cache.host_nodes() >= 3
        steps0 = eng.stats["prefill_steps"]
        toks0 = eng.stats["prefill_tokens"]
        out = _one_stream(eng, probe)
        return (out, eng.stats["prefill_steps"] - steps0,
                eng.stats["prefill_tokens"] - toks0, eng)

    hot, hot_steps, hot_toks, _ = hot_run(False)
    spill_hot, spill_steps, spill_toks, eng = hot_run(True)
    assert hot == cold
    assert spill_hot == cold
    # zero prefill forwards over the prefix: identical step/token counts
    # to the device-hot run, 16 of the 23 prompt tokens never forwarded
    assert (spill_steps, spill_toks) == (hot_steps, hot_toks)
    assert spill_toks == len(probe) - 16
    assert eng.cache.stats["swapped_in_blocks"] >= 2
    assert eng.stats["prefill_skipped_tokens"] >= 16
    # pool conserved with the swapped-in blocks folded back in
    live = int((np.asarray(eng.pool._ref) > 0).sum())
    assert eng.pool.free_block_count + live == eng.pool.n_blocks


def test_kv_quant_spill_payload_stays_packed():
    """The host tier stores kv_quant blocks as PackedKV uint8 bytes —
    never dequantized — so spill-hot is byte-exact by construction and the
    snapshot costs the packed footprint, not the bf16 one."""
    cfg = _serve_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    warm, _ = _tier_prompts(cfg)
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, prefill_chunk=8, block_size=8,
        scheme="bf16", prequant=False, kv_quant=True,
        prefix_cache=True, prefix_spill=True))
    _one_stream(eng, warm)
    eng.cache.evict(None, 999)
    packed = []

    def walk(n):
        for c in n.children.values():
            if c.host is not None:
                packed.extend(jax.tree_util.tree_leaves(
                    c.host, is_leaf=lambda x: isinstance(x, PackedKV)))
            walk(c)

    walk(eng.cache.root)
    assert packed
    assert all(isinstance(p, PackedKV) for p in packed)
    leaves = [a for p in packed for a in jax.tree_util.tree_leaves(p)]
    assert all(a.dtype.itemsize == 1 for a in leaves)  # packed bytes only


def test_disagg_pair_streams_match_monolithic():
    """Role-split prefill/decode pair: bitwise-equal streams to the
    monolithic engine, zero decode steps on the prefill worker, zero
    prefill forwards on the decode worker, both pools fully reclaimed."""
    from repro.serve.frontend import make_disagg_pair
    cfg = _serve_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [list(map(int, rng.randint(0, cfg.vocab, n)))
               for n in (9, 13)]
    econf = EngineConfig(n_slots=2, max_len=64, prefill_chunk=8,
                         scheme="bf16", prequant=False)
    mono = ServeEngine(cfg, params, econf)
    ids = [mono.submit(Request(prompt=p, max_new=5)) for p in prompts]
    res = {r.req_id: r.tokens for r in mono.run()}
    want = [res[i] for i in ids]

    pair = make_disagg_pair(cfg, params, econf)
    streamed: dict[int, list[int]] = {}
    pair.token_hook = lambda req, new, result: \
        streamed.setdefault(req.req_id, []).extend(new)
    ids2 = [pair.submit(Request(prompt=p, max_new=5)) for p in prompts]
    res2 = {r.req_id: r.tokens for r in pair.run()}
    assert [res2[i] for i in ids2] == want
    # the token hook saw one continuous per-request stream across the
    # handoff (first token from the prefill worker, rest from decode)
    assert [streamed[i] for i in ids2] == want
    assert pair.prefill.stats["decode_steps"] == 0
    assert pair.decode.stats["prefill_steps"] == 0
    assert pair.stats["finished"] == 2         # merged stats view
    for eng in (pair.prefill, pair.decode):
        assert eng.free_slots == 2
        held = eng.cache.cached_blocks() if eng.cache is not None else 0
        assert eng.pool.free_block_count + held == eng.pool.n_blocks
