"""NVFP4-quantized paged KV cache suite (`kvq` marker, wired by path).

Coverage, seam by seam (ISSUE 7):

  codec      — `core/formats.py:nvfp4_cache_encode/decode`: bf16-exact
               dequant, determinism, 0.28125x byte ratio, the no-clip
               guarantee of the 16/17-margin scale chain;
  primitives — `serve/kv_pool.py:scatter_tokens/gather_view` over PackedKV
               pools, plus the negative-position clip-corruption regression
               (the satellite bugfix: positions < 0 must route to the OOB
               sentinel regardless of the caller's `valid` mask);
  allocator  — quantized pool construction guards, atomic (codes+scales)
               copy-on-write, the host-side overflow probe;
  kernels    — `paged_attention_q` / `paged_mla_attention_q` vs the
               dequantize-then-reference oracle, garbage-filled pools,
               ragged lengths, windows, inactive rows (interpret mode);
  engine     — kv_quant gather path vs kernel path token streams, prefix
               cache hot == cold per storage mode, sharded == single-host,
               and the config guards (requires paged; excludes spec_k);
  rounding   — the cache-rounding MSE scoreboard: MS-EDEN strictly below SR
               on pool-shaped blocks (the acceptance bound), with the
               measured ordering MS-EDEN < RTN < SR pinned. NOTE: plain SR
               is ~2.2x WORSE than deterministic RTN here (SR trades MSE
               for unbiasedness — worth it for gradients, not for a decode
               cache read forward-only), so the issue's conjectured
               "MS-EDEN < SR < RTN" ordering does not hold; only the
               MS-EDEN < SR acceptance inequality does, and by a wide
               margin.

The bf16 pool stays the bitwise reference mode everywhere: nothing in this
file compares quantized streams against bf16 streams bit-for-bit (they
legitimately differ); parity within the quantized mode is what's exact.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.core import formats as F
from repro.core import ms_eden as ME
from repro.core import quant as Q
from repro.kernels import ops, ref
from repro.launch.mesh import make_serve_mesh
from repro.models import lm
from repro.models.attention import decode_sdpa
from repro.serve import kv_pool as KV
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.kv_pool import KVPool, PackedKV, gather_view, scatter_tokens

ATOL, RTOL = 5e-6, 1e-5


# --------------------------------------------------------------------------
# codec: encode/decode laws the pool and kernels rest on
# --------------------------------------------------------------------------

def _rel_mse(x, y):
    xf = np.asarray(x, np.float64)
    yf = np.asarray(y, np.float64)
    return float(np.mean((xf - yf) ** 2) / np.mean(xf ** 2))


class TestCacheCodec:
    def test_bytes_ratio_is_0_28125(self, np_rng):
        """codes (0.5 B/elt) + e4m3 scale bits (1 B per 16 elts) must land
        on exactly 0.5625 bytes/element = 0.28125x bf16 — under the 0.3x
        acceptance bound (bf16 scales would be 0.3125x and fail it)."""
        x = jnp.asarray(np_rng.randn(6, 4, 2, 64), jnp.bfloat16)
        codes, scales = F.nvfp4_cache_encode(x)
        assert codes.dtype == jnp.uint8 and scales.dtype == jnp.uint8
        assert codes.shape == (6, 4, 2, 32)
        assert scales.shape == (6, 4, 2, 4)
        packed = codes.size + scales.size
        assert packed / x.nbytes == 0.28125

    def test_decode_exact_in_bf16(self, np_rng):
        """e2m1 x e4m3 products carry <= 6 significand bits and magnitude
        <= 2688, so bf16 holds them EXACTLY: the gather-path bf16 dequant
        and the kernel's f32 dequant are the same numbers."""
        x = jnp.asarray(np_rng.randn(32, 128) * 3.0, jnp.bfloat16)
        codes, scales = F.nvfp4_cache_encode(x)
        d16 = F.nvfp4_cache_decode(codes, scales)             # bf16 default
        d32 = F.nvfp4_cache_decode(codes, scales, jnp.float32)
        assert d16.dtype == jnp.bfloat16 and d32.dtype == jnp.float32
        np.testing.assert_array_equal(
            np.asarray(d16, np.float32), np.asarray(d32))

    def test_round_trip_error_and_determinism(self, np_rng):
        x = jnp.asarray(np_rng.randn(64, 64), jnp.bfloat16)
        c1, s1 = F.nvfp4_cache_encode(x)
        c2, s2 = F.nvfp4_cache_encode(x)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        d = F.nvfp4_cache_decode(c1, s1)
        # NVFP4 RTN on N(0,1): ~1% relative MSE (scoreboard pins it tighter)
        assert _rel_mse(x, d) < 0.05

    def test_zeros_round_trip_to_exact_zeros(self):
        """Zero-initialized packed pools must decode to exactly 0.0 — the
        gather fill convention (unallocated blocks read zeros) depends on
        zero codes x zero scale bits == 0.0, not merely small."""
        x = jnp.zeros((8, 32), jnp.bfloat16)
        codes, scales = F.nvfp4_cache_encode(x)
        assert int(jnp.sum(codes)) == 0 and int(jnp.sum(scales)) == 0
        d = F.nvfp4_cache_decode(codes, scales)
        assert float(jnp.abs(d).max()) == 0.0

    def test_scale_chain_never_clips(self, np_rng):
        """The 16/17 margin guarantees absmax_g / s <= 6 after e4m3
        round-down, so cache RTN never saturates — checked on heavy-tailed
        data where a naive absmax/6 chain WOULD clip, and via the pool's
        replay probe `nvfp4_cache_overflow`. The guarantee's domain is
        |x| <= FP4_MAX * FP8_MAX = 2688 (the cache path runs UNIT gscale, so
        the e4m3 scale itself saturates past that) — comfortably above any
        bf16 KV activation, and the probe's whole job is to flag violations.
        """
        heavy = np_rng.standard_cauchy((64, 128)) * 100.0
        x = jnp.asarray(np.clip(heavy, -2000.0, 2000.0), jnp.bfloat16)
        assert float(F.nvfp4_cache_overflow(x)) == 0.0
        # and decode of the encode reproduces the largest magnitudes to
        # within one FP4 step of their group scale (no silent saturation)
        codes, scales = F.nvfp4_cache_encode(x)
        d = F.nvfp4_cache_decode(codes, scales, jnp.float32)
        xf = np.asarray(x, np.float32)
        df = np.asarray(d)
        gmax = np.abs(xf.reshape(-1, F.GROUP)).max(-1)
        dmax = np.abs(df.reshape(-1, F.GROUP)).max(-1)
        live = gmax > 0
        np.testing.assert_array_less(
            np.abs(dmax - gmax)[live] / gmax[live], 0.28)  # one e2m1 ulp
        # …and the detector actually detects: beyond the unit-gscale domain
        # the chain clips and the probe must report a nonzero fraction
        hot = jnp.full((1, 16), 10_000.0, jnp.bfloat16)
        assert float(F.nvfp4_cache_overflow(hot)) > 0.0


# --------------------------------------------------------------------------
# device primitives: scatter/gather over packed pools + the clip regression
# --------------------------------------------------------------------------

class TestScatterTokens:
    def test_negative_positions_route_to_sentinel_bf16(self):
        """REGRESSION (satellite fix): position -1 with valid=True used to
        clip to 0 and overwrite block 0 / offset 0. The scatter now folds
        `positions >= 0` into `valid`, so the write drops."""
        pool = jnp.ones((2, 4, 8), jnp.bfloat16)
        table = jnp.asarray([[0, 2]], jnp.int32)  # logical 0 -> physical 0
        vals = jnp.full((1, 1, 8), 99.0, jnp.bfloat16)
        out = scatter_tokens(pool, table, jnp.asarray([[-1]], jnp.int32),
                             vals, jnp.asarray([[True]]))
        np.testing.assert_array_equal(np.asarray(out, np.float32),
                                      np.asarray(pool, np.float32))

    def test_negative_positions_route_to_sentinel_packed(self):
        """Same regression through the PackedKV dispatch: neither codes nor
        scales of block 0 may change for a negative position."""
        pool = PackedKV(jnp.zeros((2, 4, 8), jnp.uint8),
                        jnp.zeros((2, 4, 1), jnp.uint8))
        table = jnp.asarray([[0, 2]], jnp.int32)
        vals = jnp.full((1, 1, 16), 3.0, jnp.bfloat16)
        out = scatter_tokens(pool, table, jnp.asarray([[-1]], jnp.int32),
                             vals, jnp.asarray([[True]]))
        assert int(jnp.sum(out.codes)) == 0
        assert int(jnp.sum(out.scales)) == 0

    def test_packed_scatter_then_gather_round_trips(self, np_rng):
        """Writing tokens through a packed pool and gathering them back
        yields exactly decode(encode(vals)) at written positions and exact
        zeros everywhere else (fill convention preserved)."""
        n_blocks, bs, d = 4, 4, 32
        pool = PackedKV(jnp.zeros((n_blocks, bs, d // 2), jnp.uint8),
                        jnp.zeros((n_blocks, bs, d // F.GROUP), jnp.uint8))
        table = jnp.asarray([[2, 0, n_blocks, n_blocks]], jnp.int32)
        positions = jnp.asarray([[4, 5, 6]], jnp.int32)   # logical block 1
        vals = jnp.asarray(np_rng.randn(1, 3, d), jnp.bfloat16)
        valid = jnp.asarray([[True, True, False]])
        out = scatter_tokens(pool, table, positions, vals, valid)
        view = gather_view(out, table)                    # (1, 16, d) bf16
        want = F.nvfp4_cache_decode(*F.nvfp4_cache_encode(vals))
        got = np.asarray(view, np.float32)
        np.testing.assert_array_equal(got[0, 4:6],
                                      np.asarray(want, np.float32)[0, :2])
        got[0, 4:6] = 0.0
        assert np.abs(got).max() == 0.0   # masked write + everything else


# --------------------------------------------------------------------------
# allocator: quantized pool construction, atomic COW, overflow probe
# --------------------------------------------------------------------------

def _tiny_cfg(head_dim=16) -> ArchConfig:
    return ArchConfig(name="kvq-test", family="dense", n_layers=1,
                      d_model=32, n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      head_dim=head_dim)


def _qpool(n_blocks=8) -> KVPool:
    return KVPool(_tiny_cfg(), 3, 32, paged=True, block_size=4,
                  n_blocks=n_blocks, quantized=True)


class TestQuantizedPool:
    def test_token_leaves_are_packed(self):
        pool = _qpool()
        k, v = pool.caches[0]["l0"]["kv"]
        for leaf in (k, v):
            assert isinstance(leaf, PackedKV)
            assert leaf.codes.dtype == jnp.uint8
            assert leaf.scales.dtype == jnp.uint8
            # (layers, n_blocks, block, kv_heads, hd/2) / (..., hd/16)
            assert leaf.codes.shape == (1, 8, 4, 2, 8)
            assert leaf.scales.shape == (1, 8, 4, 2, 1)

    def test_quantized_requires_paged(self):
        with pytest.raises(ValueError, match="paged"):
            KVPool(_tiny_cfg(), 3, 32, paged=False, quantized=True)

    def test_head_dim_must_divide_group(self):
        with pytest.raises(ValueError, match="divisible"):
            KVPool(_tiny_cfg(head_dim=8), 3, 32, paged=True, block_size=4,
                   n_blocks=8, quantized=True)

    def test_cow_copies_codes_and_scales_atomically(self, np_rng):
        """REGRESSION (satellite fix): `cow_block` on a quantized pool must
        copy BOTH leaves of every PackedKV — a codes-only copy would pair
        src codes with dst's stale scales and decode garbage."""
        pool = _qpool()
        # random bytes everywhere: any un-copied leaf WILL mismatch
        pool.caches = KV._map_token_kinds(
            pool.caches,
            lambda a: jnp.asarray(np_rng.randint(0, 256, a.shape), jnp.uint8))
        pool.commit(0, 8)
        pool.ensure(0, 8)
        src = pool._owned[0][0]
        pool.commit(1, 8)
        dst = pool.cow_block(1, src)
        assert dst != src
        k, v = pool.caches[0]["l0"]["kv"]
        for leaf in (k, v):
            np.testing.assert_array_equal(np.asarray(leaf.codes[:, dst]),
                                          np.asarray(leaf.codes[:, src]))
            np.testing.assert_array_equal(np.asarray(leaf.scales[:, dst]),
                                          np.asarray(leaf.scales[:, src]))

    def test_overflow_probe(self, np_rng):
        """The debug-mode detector replays the scale chain host-side
        (CONVENTIONS §6: no callbacks inside jitted serving code) and must
        report 0.0 for the RTN cache path."""
        pool = _qpool()
        vals = jnp.asarray(np_rng.randn(2, 3, 2, 16) * 50.0, jnp.bfloat16)
        assert pool.check_quant_overflow(vals) == 0.0
        bf = KVPool(_tiny_cfg(), 3, 32, paged=True, block_size=4, n_blocks=8)
        assert bf.check_quant_overflow(vals) == 0.0  # no-op on bf16 pools


# --------------------------------------------------------------------------
# kernels: packed-operand flash-decode vs dequantize-then-reference oracle
# --------------------------------------------------------------------------

BS, MAXB, N_BLOCKS = 4, 4, 10


def _mk_table(rng, lens, n_slots):
    table = np.full((n_slots, MAXB), N_BLOCKS, np.int32)
    free = list(rng.permutation(N_BLOCKS))
    for i, n in enumerate(lens):
        for j in range(-(-n // BS)):
            table[i, j] = free.pop()
    return jnp.asarray(table)


def _fill_pool(rng, table, lens, *feat):
    """bf16 pool: real values at backed positions, garbage elsewhere."""
    pool = rng.randn(N_BLOCKS, BS, *feat) * 7.0
    table = np.asarray(table)
    for i, n in enumerate(lens):
        for t in range(n):
            blk = table[i, t // BS]
            if blk < N_BLOCKS:
                pool[blk, t % BS] = rng.randn(*feat) * 0.5
    return jnp.asarray(pool, jnp.bfloat16)


def _packed(pool_bf16):
    return PackedKV(*F.nvfp4_cache_encode(pool_bf16))


class TestQuantKernelParity:
    @pytest.mark.parametrize("sq,window", [(1, None), (1, 6), (3, None),
                                           (3, 6)])
    def test_gqa_q_matches_oracle_and_composition(self, sq, window, np_rng):
        kv, rep, hd = 2, 2, 32
        h = kv * rep
        lens = [5, 11, 16, 0]     # ragged; partial tables; row 3 inactive
        pos = jnp.asarray([max(n - sq, 0) for n in lens], jnp.int32)
        table = _mk_table(np_rng, lens, len(lens))
        kp = _packed(_fill_pool(np_rng, table, lens, kv, hd))
        vp = _packed(_fill_pool(np_rng, table, lens, kv, hd))
        q = jnp.asarray(np_rng.randn(len(lens), sq, h, hd) * 0.5, jnp.float32)

        out = ops.paged_attention_q(q, kp.codes, kp.scales, vp.codes,
                                    vp.scales, table, pos, window=window)
        want = ref.paged_attention_q_ref(q, kp.codes, kp.scales, vp.codes,
                                         vp.scales, table, pos, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL, rtol=RTOL)
        # inline composition: gather_view dequantizes PackedKV to bf16 —
        # literally today's quantized gather serving path
        inline = decode_sdpa(q, gather_view(kp, table), gather_view(vp, table),
                             pos, window=window)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(inline))
        assert float(jnp.abs(out[3]).max()) == 0.0   # inactive row
        assert float(jnp.abs(want[3]).max()) == 0.0

    @pytest.mark.parametrize("sq", [1, 3])
    def test_mla_q_matches_oracle(self, sq, np_rng):
        h, lora, rope, qk_dim = 3, 32, 16, 48
        lens = [6, 14, 0]
        pos = jnp.asarray([max(n - sq, 0) for n in lens], jnp.int32)
        table = _mk_table(np_rng, lens, len(lens))
        cc = _packed(_fill_pool(np_rng, table, lens, lora))
        kc = _packed(_fill_pool(np_rng, table, lens, rope))
        qa = jnp.asarray(np_rng.randn(len(lens), sq, h, lora) * 0.5,
                         jnp.float32)
        qr = jnp.asarray(np_rng.randn(len(lens), sq, h, rope) * 0.5,
                         jnp.float32)
        out = ops.paged_mla_attention_q(qa, qr, cc.codes, cc.scales,
                                        kc.codes, kc.scales, table, pos,
                                        qk_dim=qk_dim)
        want = ref.paged_mla_attention_q_ref(qa, qr, cc.codes, cc.scales,
                                             kc.codes, kc.scales, table, pos,
                                             qk_dim=qk_dim)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL, rtol=RTOL)
        assert float(jnp.abs(out[2]).max()) == 0.0   # inactive row


# --------------------------------------------------------------------------
# engine: kv_quant streams (gather == kernel), prefix cache, sharding, guards
# --------------------------------------------------------------------------

def _cfg(arch):
    cfg = registry.get(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _q_streams(cfg, params, prompts, max_new, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("prequant", False)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("paged", True)
    kw.setdefault("kv_quant", True)
    eng = ServeEngine(cfg, params, EngineConfig(**kw))
    ids = [eng.submit(Request(prompt=p, max_new=max_new)) for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids], eng


@pytest.mark.serve
class TestEngineKVQuant:
    @pytest.mark.parametrize("arch", ["yi_9b", "deepseek_v3_671b"],
                             ids=["gqa", "mla"])
    def test_gather_and_kernel_streams_identical(self, arch, base_key,
                                                 np_rng):
        """Within the quantized mode the two read paths consume the SAME
        attention inputs: gather_view dequantizes in bf16 exactly what the
        kernel dequantizes in f32 (exactness lemma). Outputs then differ
        only by the flash kernel's usual ~1e-7 online-softmax association
        noise — the same caveat as the bf16 engine/kernel suite — so at
        these pinned configs/seeds the greedy streams match bitwise and
        are deterministic run-to-run. (A one-bf16-ulp logit near-tie CAN
        flip under that noise on other inputs, quantized or not; stream
        equality is an operating-point pin, input equality is the law.)"""
        cfg = _cfg(arch)
        params = lm.init(cfg, base_key)
        prompts = [list(map(int, np_rng.randint(0, cfg.vocab, n)))
                   for n in (9, 13)]
        a, _ = _q_streams(cfg, params, prompts, 6, paged_kernel=False)
        b, _ = _q_streams(cfg, params, prompts, 6, paged_kernel=True)
        c, _ = _q_streams(cfg, params, prompts, 6, paged_kernel=True)
        assert a == b == c

    def test_pool_is_quantized_and_bytes_shrink(self, base_key):
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        eng = ServeEngine(cfg, params, EngineConfig(
            n_slots=2, max_len=64, paged=True, kv_quant=True,
            prequant=False, scheme="bf16"))
        assert eng.pool.quantized
        k, v = eng.pool.caches[0]["l0"]["kv"]
        assert isinstance(k, PackedKV) and isinstance(v, PackedKV)
        packed = k.codes.size + k.scales.size
        bf16 = (k.codes.size * 2) * 2       # same elements at 2 B each
        assert packed / bf16 == 0.28125

    def test_prefix_cache_hot_equals_cold(self, base_key, np_rng):
        """Shared packed blocks are immutable bytes (CONVENTIONS §7), so a
        hot quantized run must emit the cold quantized stream bitwise while
        actually skipping the cached prefix."""
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        prompt = list(map(int, np_rng.randint(0, cfg.vocab, 24)))
        kw = dict(block_size=4, paged_kernel=False)
        cold_eng_kw = dict(kw, prefix_cache=False)
        cold1, cold_eng = _q_streams(cfg, params, [prompt], 4, **cold_eng_kw)
        cold2, _ = _q_streams(cfg, params, [prompt], 4, **cold_eng_kw)
        assert cold1 == cold2                       # determinism baseline

        hot_eng = ServeEngine(cfg, params, EngineConfig(
            n_slots=2, max_len=64, prefill_chunk=8, block_size=4,
            prequant=False, scheme="bf16", paged=True, kv_quant=True,
            prefix_cache=True))

        def wave():
            rid = hot_eng.submit(Request(prompt=prompt, max_new=4))
            return [r.tokens for r in hot_eng.run() if r.req_id == rid]

        assert wave() == cold1
        assert wave() == cold1                      # hot == cold, bitwise
        assert hot_eng.stats["prefix_hits"] == 1
        assert hot_eng.stats["prefill_skipped_tokens"] == 23

    def test_sharded_stream_matches_single_host(self, base_key, np_rng):
        """PackedKV leaves ride the same pytree shard specs as bf16 leaves
        (P(None, "data") broadcasts over codes and scales), so the 2-shard
        quantized engine must reproduce the single-host quantized stream."""
        if jax.device_count() < 2:
            pytest.skip("needs 2 XLA host devices")
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        prompts = [list(map(int, np_rng.randint(0, cfg.vocab, n)))
                   for n in (9, 13)]
        single, _ = _q_streams(cfg, params, prompts, 5)
        sharded, eng = _q_streams(cfg, params, prompts, 5,
                                  mesh=make_serve_mesh(2, 1))
        assert sharded == single
        assert eng.data_shards == 2

    def test_kv_quant_requires_paged(self, base_key):
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(cfg, params, EngineConfig(
                n_slots=1, max_len=32, paged=False, kv_quant=True,
                prequant=False, scheme="bf16"))

    def test_kv_quant_excludes_speculation(self, base_key):
        """Exact speculative verification is specified against the bf16
        cache image; the combination must refuse loudly, not drift."""
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        with pytest.raises(ValueError, match="spec"):
            ServeEngine(cfg, params, EngineConfig(
                n_slots=1, max_len=32, paged=True, kv_quant=True,
                spec_k=2, draft_layers=1, prequant=False, scheme="bf16"))


# --------------------------------------------------------------------------
# cache-rounding scoreboard: MS-EDEN < RTN < SR on pool-shaped blocks
# --------------------------------------------------------------------------

class TestCacheRoundingScoreboard:
    def test_ms_eden_strictly_below_sr(self, np_rng):
        """Relative MSE of the three rounding modes on pool-shaped bf16
        N(0,1) blocks. Acceptance bound: MS-EDEN strictly below SR. The
        MEASURED ordering is MS-EDEN < RTN < SR (~0.0095 / 0.0106 / 0.0235)
        — the issue's conjectured SR < RTN does NOT hold: per-group absmax
        RTN is already near-optimal deterministic rounding, while SR's
        variance roughly doubles the MSE (its unbiasedness only pays off
        inside gradient accumulation, not in a read-only decode cache).
        MS-EDEN beats both via the random rotation + EDEN scale correction.
        """
        x = jnp.asarray(np_rng.randn(40 * 16, 128), jnp.bfloat16)

        rtn = _rel_mse(x, F.nvfp4_cache_decode(*F.nvfp4_cache_encode(x),
                                               dtype=jnp.float32))
        sr = _rel_mse(x, Q.dequant(Q.quant_sr(x, jax.random.PRNGKey(1))))
        keys = jax.random.split(jax.random.PRNGKey(2))
        eden = _rel_mse(x, ME.ms_eden_dequant(ME.ms_eden(x, keys[0], keys[1]),
                                              rotated=False))

        assert eden < sr                   # the acceptance inequality
        assert eden < rtn < sr             # measured ordering, pinned
        # loose absolute pins so a silent codec regression can't hide
        assert 0.005 < rtn < 0.02, rtn
        assert 0.012 < sr < 0.05, sr
        assert 0.005 < eden < 0.015, eden
