"""Streaming frontend tests: HTTP/SSE protocol, the engine-thread bridge,
and the fault-tolerant request lifecycles (ISSUE acceptance).

Scenario coverage:
  (a) SSE-streamed token sequences are BITWISE equal to direct-engine
      `run()` output for the same prompts (greedy bf16 determinism);
  (b) a client killed mid-stream has its request cancelled, its blocks
      reclaimed, and its partial prefix hot-hit by a follow-up request;
  (c) queue saturation yields HTTP 429 + Retry-After (structured
      QueueFull info) with no engine-thread exception;
  (d) tenant rate limits and token budgets reject up front;
  (e) drain under load: in-flight requests complete, new submits get 503;
  (f) visibility-timeout requeue: a consumer that stops reading is
      cancelled (prefix cached) and resumes bitwise-exactly — driven by
      a fake clock, no sleeps (the clock-discipline satellite).

HTTP tests bind an ephemeral loopback port; everything is stdlib asyncio
(no client library). The per-test SIGALRM guard (tests/conftest.py) turns
any deadlock into a loud failure.
"""

import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.obs import Instrumentation, MetricsRegistry
from repro.serve.engine import (EngineConfig, QueueFull, Request,
                                ServeEngine, Unservable)
from repro.serve.frontend import (H_REQUEUED, H_RETIRED, CompletionFrontend,
                                  EngineBridge, FrontendConfig, TenantQuota,
                                  _TokenBucket, make_disagg_pair)

pytestmark = pytest.mark.serve


class FakeClock:
    """Manually-advanced monotonic clock (EngineConfig.clock)."""

    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


@pytest.fixture(scope="module")
def cfg():
    return registry.get("yi_9b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return lm.init(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens=(9, 13, 17)):
    rng = np.random.RandomState(1)
    return [list(map(int, rng.randint(0, cfg.vocab, n))) for n in lens]


def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    return ServeEngine(cfg, params, EngineConfig(**kw))


def _reference_tokens(cfg, params, prompts, max_new):
    eng = _engine(cfg, params)
    ids = [eng.submit(Request(prompt=list(p), max_new=max_new))
           for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids]


# --------------------------------------------------------------------------
# HTTP client helpers (stdlib asyncio only)
# --------------------------------------------------------------------------


async def _post(port, path, obj, headers=None):
    """One-shot POST; returns (status, parsed json body, headers dict)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps(obj).encode()
    extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
    writer.write((f"POST {path} HTTP/1.1\r\nHost: t\r\n{extra}"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    status, hdrs, payload = await _read_response(reader)
    writer.close()
    return status, json.loads(payload) if payload else None, hdrs


async def _get(port, path):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: t\r\n\r\n".encode())
    await writer.drain()
    status, hdrs, payload = await _read_response(reader)
    writer.close()
    return status, payload, hdrs


async def _read_response(reader):
    line = await reader.readline()
    status = int(line.split()[1])
    hdrs = {}
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode().partition(":")
        hdrs[k.strip().lower()] = v.strip()
    payload = await reader.read()
    return status, hdrs, payload


async def _sse_client(port, prompt, max_new, kill_after=None, tenant=None):
    """Stream a completion; returns (status, tokens, done_seen). When
    `kill_after` is set, hard-close the socket after that many tokens
    (the mid-stream disconnect scenario)."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps({"prompt": prompt, "max_tokens": max_new,
                       "stream": True,
                       **({"user": tenant} if tenant else {})}).encode()
    writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    toks, done = [], False
    if status != 200:
        writer.close()
        return status, toks, done
    while True:
        line = await reader.readline()
        if not line:
            break
        if not line.startswith(b"data: "):
            continue
        payload = line[6:].strip()
        if payload == b"[DONE]":
            done = True
            break
        ev = json.loads(payload)
        toks.extend(ev["choices"][0]["tokens"])
        if kill_after is not None and len(toks) >= kill_after:
            writer.transport.abort()  # RST: the server sees a dead peer
            return status, toks, done
    writer.close()
    return status, toks, done


class _Serve:
    """Async context manager: engine thread + HTTP frontend on an
    ephemeral port, torn down even when the test body raises."""

    def __init__(self, engine, fconf=None, **bridge_kw):
        self.bridge = EngineBridge(engine, **bridge_kw)
        self.fe = CompletionFrontend(self.bridge, fconf)

    async def __aenter__(self):
        self.bridge.start()
        await self.fe.start()
        return self

    async def __aexit__(self, *exc):
        await self.fe.stop()
        self.bridge.stop()

    @property
    def port(self):
        return self.fe.port

    async def snapshot(self):
        return await asyncio.wrap_future(self.bridge.snapshot())


# --------------------------------------------------------------------------
# (a) SSE streams == direct engine run, bitwise
# --------------------------------------------------------------------------


def test_sse_stream_bitwise_equals_run(cfg, params):
    prompts = _prompts(cfg)
    ref = _reference_tokens(cfg, params, prompts, max_new=8)
    eng = _engine(cfg, params)

    async def scenario():
        async with _Serve(eng) as srv:
            res = await asyncio.gather(
                *[_sse_client(srv.port, p, 8) for p in prompts])
            return res

    res = asyncio.run(scenario())
    assert all(status == 200 and done for status, _, done in res)
    assert [toks for _, toks, _ in res] == ref


def test_nonstream_completion_matches(cfg, params):
    prompts = _prompts(cfg, lens=(9,))
    ref = _reference_tokens(cfg, params, prompts, max_new=6)
    eng = _engine(cfg, params)

    async def scenario():
        async with _Serve(eng) as srv:
            return await _post(srv.port, "/v1/completions",
                               {"prompt": prompts[0], "max_tokens": 6,
                                "stream": False})

    status, body, _ = asyncio.run(scenario())
    assert status == 200
    assert body["choices"][0]["tokens"] == ref[0]
    assert body["usage"] == {"prompt_tokens": 9, "completion_tokens": 6,
                             "requeues": 0}


def test_bad_request_rejected(cfg, params):
    eng = _engine(cfg, params)

    async def scenario():
        async with _Serve(eng) as srv:
            r1 = await _post(srv.port, "/v1/completions",
                             {"prompt": "text prompts unsupported"})
            r2 = await _post(srv.port, "/v1/completions",
                             {"prompt": [1, 2], "max_tokens": 0})
            r3 = await _get(srv.port, "/nope")
            return r1, r2, r3

    r1, r2, r3 = asyncio.run(scenario())
    assert r1[0] == 400 and r1[1]["error"]["reason"] == "bad_request"
    assert r2[0] == 400
    assert r3[0] == 404


# --------------------------------------------------------------------------
# (b) mid-stream disconnect: reclaim + prefix reuse
# --------------------------------------------------------------------------


def test_disconnect_reclaims_and_prefix_hot_hits(cfg, params):
    prompts = _prompts(cfg, lens=(24,))
    ref = _reference_tokens(cfg, params, prompts, max_new=8)
    obs = Instrumentation(registry=MetricsRegistry())
    eng = _engine(cfg, params, prefix_cache=True, obs=obs)

    async def scenario():
        async with _Serve(eng) as srv:
            status, toks, done = await _sse_client(
                srv.port, prompts[0], 8, kill_after=2)
            assert status == 200 and not done and len(toks) >= 2
            # the disconnect-cancel round-trips through the command queue;
            # fence on it by waiting for the cancel to land
            for _ in range(200):
                snap = await srv.snapshot()
                if snap["stats"]["cancelled"] == 1:
                    break
                await asyncio.sleep(0.02)
            snap = await srv.snapshot()
            assert snap["stats"]["cancelled"] == 1
            assert snap["live_handles"] == 0
            # every block is either free or held by the prefix cache —
            # nothing leaked to the dead stream
            held = await asyncio.wrap_future(
                srv.bridge.call(lambda e: e.cache.cached_blocks()))
            assert snap["pool_free_blocks"] + held == snap["pool_total_blocks"]
            # follow-up request over the same prompt: the paid-for prefix
            # (prompt + generated-before-disconnect) hot-hits
            status2, toks2, done2 = await _sse_client(srv.port, prompts[0], 8)
            snap2 = await srv.snapshot()
            return toks2, done2, snap2

    toks2, done2, snap2 = asyncio.run(scenario())
    assert done2 and toks2 == ref[0]  # continuation unaffected by reuse
    assert snap2["stats"]["prefix_hits"] >= 1
    assert snap2["stats"]["prefill_skipped_tokens"] > 0
    # the disconnect landed as its own trace state
    states = [t.state for t in obs.trace_sink.traces]
    assert "disconnected" in states
    assert eng.token_hook is not None and snap2["stats"]["finished"] == 1


# --------------------------------------------------------------------------
# (c) saturation -> 429 + Retry-After, engine thread stays healthy
# --------------------------------------------------------------------------


def test_queue_saturation_429_with_retry_after(cfg, params):
    prompts = _prompts(cfg, lens=(9,) * 12)
    eng = _engine(cfg, params, n_slots=1, max_queue=2)

    async def scenario():
        async with _Serve(eng) as srv:
            res = await asyncio.gather(
                *[_sse_client(srv.port, p, 8) for p in prompts])
            ok = [r for r in res if r[0] == 200]
            rejected = [r for r in res if r[0] == 429]
            assert len(ok) + len(rejected) == len(res)
            # every accepted stream ran to completion with real tokens
            assert all(done and len(toks) == 8 for _, toks, done in ok)
            # saturation must have rejected someone; the engine thread
            # survived the flood (no exception crossed the boundary)
            assert rejected, "queue of 2 absorbed 12 concurrent requests?"
            assert srv.bridge.error is None
            return len(ok), len(rejected)

    n_ok, n_rej = asyncio.run(scenario())
    # how many squeeze in depends on how submissions interleave with ticks
    # (burst arrivals mostly land on a full queue); the invariants are that
    # SOME got served, SOME were turned away, and the books balance
    assert n_ok >= 1 and n_rej >= 1
    assert eng.stats["rejected"] == n_rej


def test_429_body_and_header_carry_retry_hint(cfg, params):
    # max_inflight=0: deterministic frontend-side backpressure rejection
    eng = _engine(cfg, params)

    async def scenario():
        async with _Serve(eng, FrontendConfig(max_inflight=0)) as srv:
            return await _post(srv.port, "/v1/completions",
                               {"prompt": [1, 2, 3], "max_tokens": 4})

    status, body, hdrs = asyncio.run(scenario())
    assert status == 429
    assert body["error"]["reason"] == "backpressure"
    assert float(hdrs["retry-after"]) > 0
    assert body["error"]["retry_after_s"] > 0


# --------------------------------------------------------------------------
# (d) tenant quotas: budgets and rate limits
# --------------------------------------------------------------------------


def test_tenant_budget_exhaustion(cfg, params):
    eng = _engine(cfg, params)
    # prompt 9 + max_new 6 = 15 tokens per request; budget fits exactly two
    fc = FrontendConfig(tenants={"acme": TenantQuota(token_budget=30)})
    prompt = _prompts(cfg, lens=(9,))[0]

    async def scenario():
        async with _Serve(eng, fc) as srv:
            out = []
            for _ in range(3):
                out.append(await _post(
                    srv.port, "/v1/completions",
                    {"prompt": prompt, "max_tokens": 6, "stream": False},
                    headers={"x-tenant": "acme"}))
            # an unrelated tenant is not throttled by acme's budget
            other = await _post(srv.port, "/v1/completions",
                                {"prompt": prompt, "max_tokens": 6,
                                 "stream": False})
            stats = await _get(srv.port, "/v1/stats")
            return out, other, stats

    out, other, (st, payload, _) = asyncio.run(scenario())
    assert [r[0] for r in out] == [200, 200, 429]
    assert out[2][1]["error"]["reason"] == "budget_exhausted"
    assert other[0] == 200
    assert st == 200
    assert json.loads(payload)["tenant_tokens_spent"]["acme"] == 30


def test_tenant_rate_limit_and_bucket_refill():
    clock = FakeClock()
    bucket = _TokenBucket(TenantQuota(rate_rps=2.0, burst=1), clock)
    assert bucket.try_take()
    assert not bucket.try_take()  # burst spent, no time passed
    clock.advance(0.5)            # 2 rps -> one token back after 0.5s
    assert bucket.try_take()
    assert not bucket.try_take()


def test_rate_limited_request_rejected(cfg, params):
    eng = _engine(cfg, params)
    fc = FrontendConfig(default_quota=TenantQuota(rate_rps=1e-9, burst=1))
    prompt = _prompts(cfg, lens=(9,))[0]

    async def scenario():
        async with _Serve(eng, fc) as srv:
            r1 = await _post(srv.port, "/v1/completions",
                             {"prompt": prompt, "max_tokens": 4,
                              "stream": False})
            r2 = await _post(srv.port, "/v1/completions",
                             {"prompt": prompt, "max_tokens": 4,
                              "stream": False})
            return r1, r2

    r1, r2 = asyncio.run(scenario())
    assert r1[0] == 200
    assert r2[0] == 429 and r2[1]["error"]["reason"] == "rate_limited"


# --------------------------------------------------------------------------
# (e) drain under load
# --------------------------------------------------------------------------


def test_drain_under_load(cfg, params):
    prompts = _prompts(cfg, lens=(9, 13))
    ref = _reference_tokens(cfg, params, prompts, max_new=8)
    obs = Instrumentation(registry=MetricsRegistry())
    eng = _engine(cfg, params, obs=obs)

    async def scenario():
        async with _Serve(eng) as srv:
            b = srv.bridge
            handles = [
                await asyncio.wrap_future(b.submit(p, 8,
                                                   track_visibility=False))
                for p in prompts]
            # wait until work is genuinely in flight, then drain
            while not any(h.tokens for h in handles):
                await asyncio.sleep(0.01)
            status, body, hdrs = await _post(srv.port, "/admin/drain", {})
            assert status == 202 and body["draining"] is True
            # new arrivals: 503 + Retry-After while draining
            st2, body2, hdrs2 = await _post(
                srv.port, "/v1/completions",
                {"prompt": prompts[0], "max_tokens": 4, "stream": False})
            assert st2 == 503
            assert body2["error"]["reason"] == "draining"
            assert "retry-after" in hdrs2
            # in-flight requests run to completion; drained event fires
            while not b.drained.is_set():
                await asyncio.sleep(0.01)
            assert all(h.done and h.state == H_RETIRED for h in handles)
            toks = [h.tokens for h in handles]
            health = await _get(srv.port, "/healthz")
            # undrain reopens admission
            await _post(srv.port, "/admin/undrain", {})
            st3, _, _ = await _post(
                srv.port, "/v1/completions",
                {"prompt": prompts[0], "max_tokens": 2, "stream": False})
            return toks, health, st3

    toks, (hst, hbody, _), st3 = asyncio.run(scenario())
    assert toks == ref  # drain never truncated an in-flight stream
    assert hst == 200 and json.loads(hbody)["status"] == "draining"
    assert st3 == 200
    # the drain left a marker trace
    assert "drained" in [t.state for t in obs.trace_sink.traces]


# --------------------------------------------------------------------------
# (f) visibility-timeout requeue + resume (fake clock, no sleeps)
# --------------------------------------------------------------------------


def test_visibility_requeue_and_exact_resume(cfg, params):
    """A consumer that stops reading is requeued (engine request cancelled
    with its prefix cached); on resume the stream continues bitwise-exactly
    with the catch-up prefill served from the cache. The bridge is driven
    UNSTARTED (no engine thread) so the whole scenario is deterministic:
    the test thread plays both roles via the same command-queue seam."""
    prompts = _prompts(cfg, lens=(24,))
    ref = _reference_tokens(cfg, params, prompts, max_new=10)
    clock = FakeClock()
    obs = Instrumentation(registry=MetricsRegistry())
    eng = _engine(cfg, params, prefix_cache=True, obs=obs, clock=clock)
    bridge = EngineBridge(eng, visibility_timeout_s=5.0)
    assert bridge.clock is clock  # the bridge shares the engine's clock

    fut = bridge.submit(prompts[0], 10)
    bridge._drain_commands()
    h = fut.result(timeout=5)
    # generate a few tokens, read once (consumer alive), then go silent
    while len(h.tokens) < 2:
        eng.step()
    first, state, _, _ = h.read_new()
    assert first == ref[0][:len(first)]
    while len(h.tokens) < 4:
        eng.step()
    # consumer silent with unread tokens: past the timeout the reaper
    # cancels the engine request (reason "requeued") and parks the handle
    clock.advance(60.0)
    bridge._check_visibility(clock())
    assert h.state == H_REQUEUED and h.requeues == 1
    assert eng.stats["cancelled"] == 1
    assert not eng.has_work()  # the slot was really freed

    # consumer comes back: resume resubmits prompt + generated-so-far
    rfut = bridge.resume(h)
    bridge._drain_commands()
    assert rfut.result(timeout=5) is h
    skipped_before = eng.stats["prefill_skipped_tokens"]
    while eng.has_work():
        eng.step()
    assert h.state == H_RETIRED
    # bitwise continuation across the requeue (greedy bf16 contract)
    assert h.tokens == ref[0]
    rest, state, result, _ = h.read_new()
    assert first + rest == ref[0] and state == H_RETIRED
    # the second leg's engine result covers exactly the post-requeue tail
    assert result is not None
    assert result.tokens == ref[0][-len(result.tokens):]
    # the catch-up prefill came from the prefix cache, not recompute
    assert eng.stats["prefill_skipped_tokens"] > skipped_before
    assert eng.stats["prefix_hits"] >= 1
    # trace: the first leg ended in the `requeued` terminal state
    assert "requeued" in [t.state for t in obs.trace_sink.traces]


def test_caught_up_consumer_is_never_requeued(cfg, params):
    """Zero unread tokens means the consumer is WAITING, not stalled — an
    idle-but-live stream must survive any amount of wall-clock silence."""
    prompts = _prompts(cfg, lens=(9,))
    clock = FakeClock()
    eng = _engine(cfg, params, clock=clock)
    bridge = EngineBridge(eng, visibility_timeout_s=5.0)
    fut = bridge.submit(prompts[0], 6)
    bridge._drain_commands()
    h = fut.result(timeout=5)
    while len(h.tokens) < 2:
        eng.step()
    h.read_new()  # fully caught up
    clock.advance(1000.0)
    bridge._check_visibility(clock())
    assert h.state != H_REQUEUED
    assert eng.stats["cancelled"] == 0
    while eng.has_work():
        eng.step()
    assert h.state == H_RETIRED


# --------------------------------------------------------------------------
# structured rejections (satellite 1) + clock discipline (satellite 2)
# --------------------------------------------------------------------------


def test_queuefull_carries_structured_info(cfg, params):
    eng = _engine(cfg, params, max_queue=1)
    eng.submit(Request(prompt=[1, 2, 3], max_new=4))
    with pytest.raises(QueueFull) as exc_info:
        eng.submit(Request(prompt=[1, 2, 3], max_new=4))
    e = exc_info.value
    assert e.reason == "queue_full"
    assert e.queue_depth == 1
    assert e.retry_after_s is None or e.retry_after_s > 0
    assert e.info() == {"reason": "queue_full", "queue_depth": 1,
                        "retry_after_s": e.retry_after_s}


def test_unservable_is_both_valueerror_and_queuefull(cfg, params):
    eng = _engine(cfg, params, max_len=32)
    huge = Request(prompt=list(range(10)), max_new=1000)
    with pytest.raises(ValueError) as exc_info:  # legacy contract
        eng.submit(huge)
    e = exc_info.value
    assert isinstance(e, QueueFull) and isinstance(e, Unservable)
    assert e.reason == "unservable"
    assert e.retry_after_s is None  # retrying is pointless by definition


def test_reason_labelled_rejection_metrics(cfg, params):
    obs = Instrumentation(registry=MetricsRegistry())
    eng = _engine(cfg, params, max_queue=1, max_len=32, obs=obs)
    eng.submit(Request(prompt=[1, 2, 3], max_new=4))
    with pytest.raises(QueueFull):
        eng.submit(Request(prompt=[1, 2, 3], max_new=4))
    with pytest.raises(Unservable):
        eng.submit(Request(prompt=list(range(10)), max_new=1000))
    assert eng.stats["rejected"] == 2  # legacy aggregate unchanged
    assert obs.registry.value("serve_rejections_total",
                              engine=obs.engine_label,
                              reason="queue_full") == 1
    assert obs.registry.value("serve_rejections_total",
                              engine=obs.engine_label,
                              reason="unservable") == 1


def test_engine_clock_is_injectable_end_to_end(cfg, params):
    """Every engine timestamp flows through EngineConfig.clock: latencies,
    deadline verdicts, and trace spans move with a fake clock and zero real
    sleeps (the previously-untestable paths the satellite names)."""
    clock = FakeClock(t=1000.0)
    obs = Instrumentation(registry=MetricsRegistry())
    eng = _engine(cfg, params, obs=obs, clock=clock)
    prompt = _prompts(cfg, lens=(9,))[0]
    rid = eng.submit(Request(prompt=prompt, max_new=4, deadline_s=3.0))
    clock.advance(10.0)  # "sleep" 10s in the queue without sleeping
    res = {r.req_id: r for r in eng.run()}[rid]
    assert res.arrival_s == 1000.0
    assert res.latency_s >= 10.0
    assert res.deadline_met is False  # 10s queued >> 3s deadline
    assert res.queue_wait_s >= 10.0  # trace spans on the same clock
    tr = obs.trace_sink.traces[-1]
    assert tr.span("queued").t0 == 1000.0


def test_default_clock_unchanged(cfg, params):
    import time
    eng = _engine(cfg, params)
    assert eng.clock is time.perf_counter


# --------------------------------------------------------------------------
# token hook (the seam the frontend rides)
# --------------------------------------------------------------------------


def test_token_hook_streams_every_token_in_order(cfg, params):
    prompts = _prompts(cfg, lens=(9, 13))
    ref = _reference_tokens(cfg, params, prompts, max_new=6)
    seen: dict[int, list[int]] = {}
    results = {}

    def hook(req, new, result):
        seen.setdefault(req.req_id, []).extend(new)
        if result is not None:
            results[req.req_id] = result

    eng = _engine(cfg, params, token_hook=hook)
    ids = [eng.submit(Request(prompt=list(p), max_new=6)) for p in prompts]
    run_res = {r.req_id: r.tokens for r in eng.run()}
    for i, rid in enumerate(ids):
        assert seen[rid] == ref[i] == run_res[rid]
        assert results[rid].tokens == ref[i]


def test_token_hook_off_by_default(cfg, params):
    eng = _engine(cfg, params)
    assert eng.token_hook is None  # zero-overhead when unused


# --------------------------------------------------------------------------
# disaggregated prefill/decode behind the same bridge (hierarchical-cache PR)
# --------------------------------------------------------------------------


def _disagg(cfg, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("prequant", False)
    return make_disagg_pair(cfg, params, EngineConfig(**kw))


def _bf16_reference(cfg, params, prompts, max_new):
    eng = _engine(cfg, params, scheme="bf16", prequant=False)
    ids = [eng.submit(Request(prompt=list(p), max_new=max_new))
           for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids]


def test_sse_stream_over_disagg_pair_bitwise(cfg, params):
    """An EnginePair rides the SAME bridge seam as a single engine (submit /
    step / cancel / token_hook duck-typing): SSE streams over the role-split
    deployment stay bitwise equal to the monolithic bf16 engine, with the
    roles really split (no decode steps on the prefill worker, no prefill
    forwards on the decode worker)."""
    prompts = _prompts(cfg)
    ref = _bf16_reference(cfg, params, prompts, max_new=8)
    pair = _disagg(cfg, params)

    async def scenario():
        async with _Serve(pair) as srv:
            res = await asyncio.gather(
                *[_sse_client(srv.port, p, 8) for p in prompts])
            snap = await srv.snapshot()
            return res, snap

    res, snap = asyncio.run(scenario())
    assert all(status == 200 and done for status, _, done in res)
    assert [toks for _, toks, _ in res] == ref
    assert snap["stats"]["finished"] == len(prompts)   # merged pair stats
    assert pair.prefill.stats["decode_steps"] == 0
    assert pair.decode.stats["prefill_steps"] == 0
    assert pair.prefill.stats["handoffs"] == len(prompts)


def test_disconnect_on_disagg_pair_reclaims_both_pools(cfg, params):
    """A client killed mid-stream on a role-split deployment: the cancel
    reclaims whichever engine holds the request, BOTH pools conserve, and
    the exported prompt prefix still hot-hits the follow-up."""
    prompts = _prompts(cfg, lens=(24,))
    ref = _bf16_reference(cfg, params, prompts, max_new=8)
    pair = _disagg(cfg, params, prefix_cache=True)

    async def scenario():
        async with _Serve(pair) as srv:
            status, toks, done = await _sse_client(
                srv.port, prompts[0], 8, kill_after=2)
            assert status == 200 and not done and len(toks) >= 2
            for _ in range(200):
                snap = await srv.snapshot()
                if snap["stats"]["cancelled"] == 1:
                    break
                await asyncio.sleep(0.02)
            snap = await srv.snapshot()
            assert snap["stats"]["cancelled"] == 1
            assert snap["live_handles"] == 0
            books = await asyncio.wrap_future(srv.bridge.call(lambda e: (
                e.prefill.pool.free_block_count,
                e.prefill.cache.cached_blocks(),
                e.prefill.pool.n_blocks,
                e.decode.pool.free_block_count,
                e.decode.pool.n_blocks)))
            pf, pheld, ptotal, df, dtotal = books
            assert pf + pheld == ptotal     # prefill worker: free + cached
            assert df == dtotal             # decode worker: all free
            status2, toks2, done2 = await _sse_client(srv.port, prompts[0], 8)
            return toks2, done2

    toks2, done2 = asyncio.run(scenario())
    assert done2 and toks2 == ref[0]
    assert pair.prefill.stats["prefix_hits"] >= 1


def test_drain_covers_both_roles(cfg, params):
    """/admin/drain on a role-split deployment: in-flight requests cross the
    handoff boundary and run to completion on the decode worker (drained
    fires only once BOTH engines and the in-transit deque are empty); new
    arrivals get 503 meanwhile."""
    prompts = _prompts(cfg, lens=(9, 13))
    ref = _bf16_reference(cfg, params, prompts, max_new=8)
    pair = _disagg(cfg, params)

    async def scenario():
        async with _Serve(pair) as srv:
            b = srv.bridge
            handles = [
                await asyncio.wrap_future(b.submit(p, 8,
                                                   track_visibility=False))
                for p in prompts]
            while not any(h.tokens for h in handles):
                await asyncio.sleep(0.01)
            status, body, _ = await _post(srv.port, "/admin/drain", {})
            assert status == 202 and body["draining"] is True
            st2, body2, _ = await _post(
                srv.port, "/v1/completions",
                {"prompt": prompts[0], "max_tokens": 4, "stream": False})
            assert st2 == 503 and body2["error"]["reason"] == "draining"
            while not b.drained.is_set():
                await asyncio.sleep(0.01)
            assert all(h.done and h.state == H_RETIRED for h in handles)
            return [h.tokens for h in handles]

    toks = asyncio.run(scenario())
    assert toks == ref                      # drain never truncated a stream
    assert not pair.has_work()
    assert not pair.prefill.handoffs        # nothing left in transit
    assert pair.decode.pool.free_block_count == pair.decode.pool.n_blocks
