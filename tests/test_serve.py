"""Serving engine tests: continuous batching, paged KV pool, quantize-once
weights.

Invariants (ISSUE acceptance):
  (a) engine greedy decode == straight-line lm.forward greedy on the same
      tokens (bf16 scheme: exact arithmetic up to masked-softmax padding,
      checked token-for-token);
  (b) paged pool == dense cache BIT-identically (same scatter/gather values,
      same masked attention arithmetic);
  (c) quantize-once packed weights == per-step weight quantization
      BIT-identically (deterministic forward quantizers round-trip through
      the packed form exactly);
  (d) slots and pool blocks are reclaimed when sequences finish.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serve.decode import greedy_generate
from repro.serve.engine import (EngineConfig, QueueFull, Request,
                                RequestResult, ServeEngine)
from repro.serve.kv_pool import KVPool
from repro.serve.prequant import prequantize
from repro.serve.sampling import SamplingParams, sample_tokens

pytestmark = pytest.mark.serve

SEED = jnp.array([7, 7], jnp.uint32)


def _cfg(arch):
    cfg = registry.get(arch).reduced()
    if cfg.moe:  # exactness needs no capacity drops (cf. test_archs)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _params(cfg):
    return lm.init(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, lens=(9, 13)):
    rng = np.random.RandomState(1)
    return [list(map(int, rng.randint(0, cfg.vocab, n))) for n in lens]


def _engine_tokens(cfg, params, prompts, max_new, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    eng = ServeEngine(cfg, params, EngineConfig(**kw))
    ids = [eng.submit(Request(prompt=p, max_new=max_new)) for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids], eng


def _straightline_tokens(cfg, params, prompt, max_new):
    """Greedy continuation via repeated full forwards (no cache at all)."""
    seq = list(prompt)
    out = []
    for _ in range(max_new):
        logits, _, _ = lm.forward(params, cfg, {"tokens": jnp.asarray([seq])},
                                  "bf16", SEED, mode="train")
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
        seq.append(tok)
    return out


# --------------------------------------------------------------------------
# (a) engine decode == straight-line forward
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi_9b", "deepseek_v3_671b", "rwkv6_7b",
                                  "recurrentgemma_9b"])
def test_engine_matches_straightline_forward(arch):
    """Chunked prefill + paged continuous-batching decode must reproduce the
    cache-free forward's greedy tokens across mixer families (gqa, mla+moe,
    rwkv, rec+lattn) — ragged prompt lengths in one batch."""
    cfg = _cfg(arch)
    params = _params(cfg)
    prompts = _prompts(cfg)
    got, _ = _engine_tokens(cfg, params, prompts, 5, scheme="bf16",
                            paged=True, prequant=False)
    for p, g in zip(prompts, got):
        assert g == _straightline_tokens(cfg, params, p, 5), arch


def test_engine_quartet2_finite_and_deterministic():
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    prompts = _prompts(cfg)
    a, _ = _engine_tokens(cfg, params, prompts, 6, scheme="quartet2")
    b, _ = _engine_tokens(cfg, params, prompts, 6, scheme="quartet2")
    assert a == b  # deterministic forward quantization + greedy


# --------------------------------------------------------------------------
# (b) paged pool == dense cache, bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi_9b", "deepseek_v3_671b"])
def test_paged_pool_matches_dense_bitwise(arch):
    cfg = _cfg(arch)
    params = _params(cfg)
    prompts = _prompts(cfg)

    logits_by_mode = {}
    for paged in (False, True):
        eng = ServeEngine(cfg, params,
                          EngineConfig(n_slots=2, max_len=64, prefill_chunk=8,
                                       paged=paged, prequant=False,
                                       scheme="quartet2"))
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=4))
        trace = []
        orig = eng._forward

        def spy(size, tokens, pos, active, _orig=orig, _trace=trace):
            logits = _orig(size, tokens, pos, active)
            _trace.append(np.asarray(logits, np.float32))
            return logits

        eng._forward = spy
        eng.run()
        logits_by_mode[paged] = trace

    dense, paged = logits_by_mode[False], logits_by_mode[True]
    assert len(dense) == len(paged)
    for a, b in zip(dense, paged):
        np.testing.assert_array_equal(a, b)  # BIT-identical logits


# --------------------------------------------------------------------------
# (c) quantize-once == per-step quantization, bit-identical
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi_9b", "deepseek_v3_671b", "rwkv6_7b"])
def test_prequant_matches_per_step_bitwise(arch):
    cfg = _cfg(arch)
    params = _params(cfg)
    prompts = _prompts(cfg)

    traces = {}
    for prequant in (False, True):
        eng = ServeEngine(cfg, params,
                          EngineConfig(n_slots=2, max_len=64, prefill_chunk=8,
                                       paged=True, prequant=prequant,
                                       scheme="quartet2"))
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=4))
        trace = []
        orig = eng._forward

        def spy(size, tokens, pos, active, _orig=orig, _trace=trace):
            logits = _orig(size, tokens, pos, active)
            _trace.append(np.asarray(logits, np.float32))
            return logits

        eng._forward = spy
        eng.run()
        traces[prequant] = trace

    assert len(traces[False]) == len(traces[True])
    for a, b in zip(traces[False], traces[True]):
        np.testing.assert_array_equal(a, b)  # BIT-identical logits


def test_prequant_packs_expected_leaves():
    from repro.core.linear import PackedQWeight
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    pq = prequantize(params, cfg, "quartet2")
    mix = pq["stages"][0]["l0"]["mix"]
    assert isinstance(mix["wq"], PackedQWeight)
    assert mix["wq"].packed.dtype == jnp.uint8
    # 4-bit codes: half the bytes of the (N, K) matrix
    assert mix["wq"].packed.shape[-1] == params["stages"][0]["l0"]["mix"]["wq"].shape[-1] // 2
    # embeddings/norms stay raw
    assert not isinstance(pq["embed"], PackedQWeight)
    assert not isinstance(pq["stages"][0]["l0"]["n1"]["g"], PackedQWeight)


def test_prequant_mla_keeps_wkv_b_raw():
    """Absorbed-form decode consumes wkv_b as a raw matrix — must not pack."""
    from repro.core.linear import PackedQWeight
    cfg = _cfg("deepseek_v3_671b")
    params = _params(cfg)
    pq = prequantize(params, cfg, "quartet2")
    mix = pq["stages"][0]["l0"]["mix"]
    assert isinstance(mix["wq_a"], PackedQWeight)
    assert not isinstance(mix["wkv_b"], PackedQWeight)


def test_prequant_bf16_noop():
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    assert prequantize(params, cfg, "bf16") is params


# --------------------------------------------------------------------------
# (d) slot + block reclamation, admission control
# --------------------------------------------------------------------------

def test_slots_and_blocks_reclaimed():
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    eng = ServeEngine(cfg, params,
                      EngineConfig(n_slots=2, max_len=64, prefill_chunk=8,
                                   paged=True, scheme="bf16", prequant=False))
    assert eng.free_slots == 2
    total_blocks = eng.pool.free_block_count
    # 5 requests through 2 slots: continuous batching must cycle slots
    prompts = _prompts(cfg, lens=(9, 13, 7, 11, 5))
    for p in prompts:
        eng.submit(Request(prompt=p, max_new=3))
    results = eng.run()
    assert len(results) == 5
    assert all(len(r.tokens) == 3 for r in results)
    assert eng.free_slots == 2                       # all slots reclaimed
    assert eng.pool.free_block_count == total_blocks  # all blocks reclaimed
    assert eng.stats["finished"] == 5


def test_ring_window_cache_matches_dense_window():
    """Legacy dense decode with cap == window is a true ring buffer: prefill
    roll + ring_abs_pos must reproduce a full-capacity windowed cache EXACTLY
    — including a prompt length NOT divisible by the window (the misaligned
    case: S=13, window=8)."""
    from repro.models import attention as A

    cfg = _cfg("recurrentgemma_9b")
    cfg = dataclasses.replace(
        cfg, griffin=dataclasses.replace(cfg.griffin, window=8))
    key = jax.random.PRNGKey(0)
    p = A.gqa_init(key, cfg)
    s, w = 13, 8
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (1, s, cfg.d_model), jnp.bfloat16) * 0.3

    _, kv = A.gqa_apply(p, x, cfg, "bf16", SEED, 0, causal=True, window=w)
    k, v = kv
    # reference: full-capacity cache, window enforced by masking only
    full = jnp.zeros((1, 32, cfg.n_kv_heads, cfg.hd), jnp.bfloat16)
    ref_cache = (full.at[:, :s].set(k.astype(jnp.bfloat16)),
                 full.at[:, :s].set(v.astype(jnp.bfloat16)))
    # ring: capacity == window, filled through the prefill roll
    ring = (jnp.zeros((1, w, cfg.n_kv_heads, cfg.hd), jnp.bfloat16),) * 2
    ring_cache = lm._fill_cache(ring, kv, w)

    for i in range(4):  # decode across several wrap points
        step = jax.random.normal(jax.random.fold_in(key, 10 + i),
                                 (1, 1, cfg.d_model), jnp.bfloat16) * 0.3
        o_ref, ref_cache = A.gqa_decode(p, step, cfg, "bf16", SEED, 0,
                                        ref_cache, s + i, window=w)
        o_ring, ring_cache = A.gqa_decode(p, step, cfg, "bf16", SEED, 0,
                                          ring_cache, s + i, window=w)
        np.testing.assert_array_equal(np.asarray(o_ref, np.float32),
                                      np.asarray(o_ring, np.float32))


def test_admission_defers_until_reserved_blocks_free():
    """Admission must account for blocks already COMMITTED to admitted
    sequences (allocation is lazy): with a pool of 6 blocks and two requests
    needing 4 each, the second waits — and both still complete."""
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    eng = ServeEngine(cfg, params,
                      EngineConfig(n_slots=2, max_len=64, block_size=16,
                                   n_blocks=6, prefill_chunk=8,
                                   scheme="bf16", prequant=False))
    for _ in range(2):
        eng.submit(Request(prompt=[1] * 16, max_new=47))  # 63 tok = 4 blocks
    results = eng.run()  # would raise OutOfBlocks without reservations
    assert len(results) == 2
    assert all(len(r.tokens) == 47 for r in results)
    assert eng.pool.free_block_count == 6


def test_unservable_request_rejected_at_submit():
    """A request needing more blocks than the pool has must be rejected at
    submit() — otherwise it head-of-line blocks the FIFO forever."""
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    eng = ServeEngine(cfg, params,
                      EngineConfig(n_slots=1, max_len=64, block_size=16,
                                   n_blocks=2, scheme="bf16", prequant=False))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=[1] * 40, max_new=10))  # 50 tok = 4 blocks


def test_greedy_generate_ragged_rejects_recurrent_archs():
    """Full-width prefill would feed pads into recurrent state; the loop
    must refuse (ServeEngine is the ragged path for ssm/hybrid)."""
    cfg = _cfg("rwkv6_7b")
    params = _params(cfg)
    with pytest.raises(NotImplementedError):
        greedy_generate(params, cfg, "bf16", jnp.zeros((2, 8), jnp.int32), 2,
                        prompt_lens=jnp.asarray([4, 8]))


def test_admission_control_queue_full():
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    eng = ServeEngine(cfg, params,
                      EngineConfig(n_slots=1, max_len=64, max_queue=2,
                                   scheme="bf16", prequant=False))
    eng.submit(Request(prompt=[1, 2, 3], max_new=2))
    eng.submit(Request(prompt=[1, 2, 3], max_new=2))
    with pytest.raises(QueueFull):
        eng.submit(Request(prompt=[1, 2, 3], max_new=2))
    with pytest.raises(ValueError):  # request longer than max_len
        ok = ServeEngine(cfg, params, EngineConfig(n_slots=1, max_len=16,
                                                   scheme="bf16",
                                                   prequant=False))
        ok.submit(Request(prompt=list(range(15)), max_new=8))


def test_pool_oob_sentinel_drops_writes():
    """Device-side masking convention: writes through unallocated block-table
    entries vanish; gathers of unallocated blocks read zeros."""
    from repro.serve.kv_pool import gather_view, scatter_tokens
    pool = jnp.zeros((4, 4, 2), jnp.bfloat16)          # (P, BS, feat)
    table = jnp.full((2, 2), 4, jnp.int32)             # all OOB sentinel
    table = table.at[0, 0].set(1)                      # row 0 owns block 1
    positions = jnp.array([[0], [0]], jnp.int32)
    vals = jnp.ones((2, 1, 2), jnp.bfloat16)
    valid = jnp.array([[True], [True]])
    pool = scatter_tokens(pool, table, positions, vals, valid)
    view = np.asarray(gather_view(pool, table), np.float32)
    assert view[0, 0].sum() == 2.0                     # row 0 wrote via block 1
    assert view[1].sum() == 0.0                        # row 1 dropped (OOB)
    assert np.asarray(pool, np.float32)[0].sum() == 0  # block 0 untouched


# --------------------------------------------------------------------------
# satellite: ragged prompts through the legacy greedy loop
# --------------------------------------------------------------------------

def test_greedy_generate_ragged_prompts():
    """greedy_generate(prompt_lens=...) must equal per-row generation —
    the old shared-scalar `pos` produced wrong logits for short rows."""
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    rng = np.random.RandomState(3)
    lens = [6, 10]
    s = max(lens)
    rows = [rng.randint(0, cfg.vocab, n) for n in lens]
    padded = np.zeros((2, s), np.int32)
    for i, r in enumerate(rows):
        padded[i, : len(r)] = r
    out = greedy_generate(params, cfg, "bf16", jnp.asarray(padded), 4,
                          prompt_lens=jnp.asarray(lens))
    for i, r in enumerate(rows):
        solo = greedy_generate(params, cfg, "bf16",
                               jnp.asarray(r[None, :]), 4)
        assert out[i].tolist() == solo[0].tolist(), f"row {i}"


def _lattn_cfg():
    """A pure sliding-window-attention stack: recurrentgemma's hybrid family
    with every pattern slot set to 'attn' (window=8 so the window binds)."""
    base = registry.get("recurrentgemma_9b").reduced()
    return dataclasses.replace(
        base, griffin=dataclasses.replace(base.griffin, window=8,
                                          pattern=("attn", "attn")))


@pytest.mark.parametrize("make_cfg", [lambda: _cfg("yi_9b"),
                                      lambda: _cfg("deepseek_v3_671b"),
                                      _lattn_cfg],
                         ids=["attention", "mla", "lattn"])
def test_ragged_prompts_engine_matches_greedy_generate(make_cfg, base_key,
                                                       np_rng):
    """Cross-arch ragged-prompt regression (locks in the PR-1 position-vector
    fix): mixed-length prompts through the legacy greedy loop and through
    the engine must produce identical tokens for attention / mla / lattn.
    The lattn case also pins the full-capacity (non-ring) ragged cache."""
    cfg = make_cfg()
    params = lm.init(cfg, base_key)
    lens = [6, 10, 13]
    rows = [np_rng.randint(0, cfg.vocab, n) for n in lens]
    padded = np.zeros((3, max(lens)), np.int32)
    for i, r in enumerate(rows):
        padded[i, : len(r)] = r
    legacy = greedy_generate(params, cfg, "bf16", jnp.asarray(padded), 4,
                             prompt_lens=jnp.asarray(lens))
    eng = ServeEngine(cfg, params,
                      EngineConfig(n_slots=3, max_len=64, prefill_chunk=8,
                                   scheme="bf16", prequant=False))
    ids = [eng.submit(Request(prompt=list(map(int, r)), max_new=4))
           for r in rows]
    res = {r.req_id: r.tokens for r in eng.run()}
    for i, rid in enumerate(ids):
        assert res[rid] == legacy[i].tolist(), f"row {i}"


def test_sampler_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray([[0.0, 5.0, 1.0, 2.0]] * 3)
    temps = jnp.asarray([0.0, 1.0, 1.0], jnp.float32)
    topk = jnp.asarray([0, 1, 0], jnp.int32)
    toks = sample_tokens(logits, temps, topk, key)
    assert int(toks[0]) == 1          # greedy row -> argmax
    assert int(toks[1]) == 1          # top-1 row -> argmax regardless of noise
    assert 0 <= int(toks[2]) < 4
    # temperature sampling covers multiple tokens over draws
    seen = {int(sample_tokens(logits, temps, topk,
                              jax.random.PRNGKey(i))[2]) for i in range(64)}
    assert len(seen) > 1


# --------------------------------------------------------------------------
# radix-tree prefix cache (serve/prefix_cache.py): zero prefill over the
# shared prefix, bitwise stream parity hot vs cold (ISSUE 5 acceptance)
# --------------------------------------------------------------------------

def _cache_engine(cfg, params, prefix_cache, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("prequant", False)
    return ServeEngine(cfg, params, EngineConfig(prefix_cache=prefix_cache,
                                                 **kw))


def _wave(eng, prompts, max_new=4):
    ids = [eng.submit(Request(prompt=p, max_new=max_new)) for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids]


@pytest.mark.parametrize("arch", ["yi_9b", "deepseek_v3_671b"],
                         ids=["gqa", "mla"])
def test_prefix_cache_skips_prefill_bitwise(arch):
    """A second request sharing an L-token prefix performs ZERO prefill
    forward passes over those L tokens (step-count instrumentation) and its
    greedy stream is BITWISE identical to a cold-cache run — gqa and mla,
    paged pools."""
    cfg = _cfg(arch)
    params = _params(cfg)
    rng = np.random.RandomState(2)
    prompt = list(map(int, rng.randint(0, cfg.vocab, 24)))

    cold_eng = _cache_engine(cfg, params, False)
    cold1 = _wave(cold_eng, [prompt])
    cold2 = _wave(cold_eng, [prompt])          # same engine, cache off

    hot_eng = _cache_engine(cfg, params, True)
    hot1 = _wave(hot_eng, [prompt])
    assert hot1 == cold1                        # empty cache: identical
    steps0 = hot_eng.stats["prefill_steps"]
    tokens0 = hot_eng.stats["prefill_tokens"]
    hot2 = _wave(hot_eng, [prompt])
    assert hot2 == cold2                        # BITWISE parity, hot
    # the full 24-token prompt caps at 23 matched tokens (the last prompt
    # token is always computed for its logits): exactly ONE prefill forward
    # over exactly ONE token — zero forward passes over the L=23 prefix
    assert hot_eng.stats["prefill_steps"] - steps0 == 1
    assert hot_eng.stats["prefill_tokens"] - tokens0 == 1
    assert hot_eng.stats["prefill_skipped_tokens"] == 23
    assert hot_eng.stats["prefix_hits"] == 1


def test_prefix_cache_cow_at_divergence():
    """A prompt diverging INSIDE a cached block reuses the in-block common
    prefix via copy-on-write and only prefills from the divergence on."""
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    rng = np.random.RandomState(3)
    base = list(map(int, rng.randint(0, cfg.vocab, 24)))
    fork = base[:10] + list(map(int, rng.randint(0, cfg.vocab, 14)))

    cold = _wave(_cache_engine(cfg, params, False), [fork])
    hot_eng = _cache_engine(cfg, params, True)
    _wave(hot_eng, [base])                      # prime the cache
    t0 = hot_eng.stats["prefill_tokens"]
    hot = _wave(hot_eng, [fork])
    assert hot == cold                          # bitwise despite COW
    # 10 matched = 2 full aliased blocks (bs=4) + 2 tokens COW'd: prefill
    # covers exactly the 14 unmatched tokens
    assert hot_eng.stats["prefill_tokens"] - t0 == 14
    assert hot_eng.stats["prefill_skipped_tokens"] == 10


def test_prefix_cache_excluded_on_windowed_lattn():
    """Sliding-window stacks reclaim blocks mid-sequence, so their prefixes
    are unshareable: the engine must run with cache=None and emit exactly
    the cache-off streams."""
    cfg = _lattn_cfg()
    params = _params(cfg)
    rng = np.random.RandomState(4)
    prompt = list(map(int, rng.randint(0, cfg.vocab, 12)))
    hot_eng = _cache_engine(cfg, params, True, max_len=32)
    assert hot_eng.cache is None                # excluded, not an error
    cold_eng = _cache_engine(cfg, params, False, max_len=32)
    assert _wave(hot_eng, [prompt]) == _wave(cold_eng, [prompt])
    assert _wave(hot_eng, [prompt]) == _wave(cold_eng, [prompt])
    assert hot_eng.stats["prefill_skipped_tokens"] == 0


def test_prefix_cache_excluded_on_recurrent_state():
    """wkv/lru state integrates the whole prefix into O(1) slot state that
    blocks cannot reconstruct — recurrent archs must be excluded too."""
    cfg = _cfg("rwkv6_7b")
    params = _params(cfg)
    eng = _cache_engine(cfg, params, True)
    assert eng.cache is None


def test_prefix_cache_eviction_under_pressure():
    """When the pool runs dry, unpinned cached prefixes are evicted LRU and
    their blocks reused; every request still completes and the pool fully
    reclaims afterwards."""
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    rng = np.random.RandomState(5)
    prompts = [list(map(int, rng.randint(0, cfg.vocab, 16)))
               for _ in range(4)]
    # 16 blocks of 4 = 64 positions total; each request needs ~5-6 blocks,
    # so caching all four retired streams MUST evict earlier entries
    eng = _cache_engine(cfg, params, True, n_slots=2, max_len=32,
                        n_blocks=16)
    for p in prompts:
        got = _wave(eng, [p])
        assert len(got[0]) == 4
    assert eng.cache.stats["evicted_blocks"] > 0
    # conservation: cached + free == all (no slot is live)
    assert (eng.pool.free_block_count + eng.cache.cached_blocks()
            == eng.pool.n_blocks)
    # hot reuse still correct after the evictions
    cold = _wave(_cache_engine(cfg, params, False, n_slots=2, max_len=32,
                               n_blocks=16), [prompts[-1]])
    assert _wave(eng, [prompts[-1]]) == cold


def test_prefix_cache_quartet2_deterministic():
    """Quantizing schemes are chunk-coupled (shared activation absmax), so
    hot runs are not bit-compared to cold — but they must be deterministic
    run-to-run (docs/CONVENTIONS.md §3)."""
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    rng = np.random.RandomState(6)
    prompt = list(map(int, rng.randint(0, cfg.vocab, 20)))

    def run_twice():
        eng = _cache_engine(cfg, params, True, scheme="quartet2",
                            prequant=True)
        return _wave(eng, [prompt]) + _wave(eng, [prompt])

    assert run_twice() == run_twice()


def test_prefix_cache_spec_decode_composes():
    """Speculative decoding + prefix cache: the draft pool never aliases
    (it catches up over the skipped prefix), and the emitted stream stays
    bitwise equal to the plain engine."""
    cfg = _cfg("yi_9b")
    params = _params(cfg)
    rng = np.random.RandomState(7)
    prompt = list(map(int, rng.randint(0, cfg.vocab, 24)))
    plain = _cache_engine(cfg, params, False)
    ref1, ref2 = _wave(plain, [prompt]), _wave(plain, [prompt])
    eng = _cache_engine(cfg, params, True, spec_k=2, draft_layers=1)
    assert _wave(eng, [prompt]) == ref1
    assert _wave(eng, [prompt]) == ref2
    assert eng.stats["prefill_skipped_tokens"] == 23
    assert eng.stats["spec_rounds"] > 0
