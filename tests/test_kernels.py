"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps +
allclose, per the kernel contract in src/repro/kernels/EXAMPLE.md."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: seeded-random fallback shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import formats as F
from repro.core import quant as Q
from repro.kernels import ref
from repro.kernels.fp4_matmul import fp4_matmul
from repro.kernels.ms_eden_requant import ms_eden_requant
from repro.kernels.nvfp4_quant import nvfp4_fos_quant


class TestNVFP4QuantKernel:
    @pytest.mark.parametrize("shape,blocks", [
        ((128, 512), (128, 512)),   # single tile
        ((256, 1024), (128, 256)),  # multi-tile grid
        ((64, 64), (32, 32)),       # small tiles
        ((128, 1408), (64, 176)),   # deepseek-moe expert width
    ])
    def test_matches_oracle(self, shape, blocks):
        x = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
        deq_k, codes_k, scales_k, g_k = nvfp4_fos_quant(
            x, bm=blocks[0], bk=blocks[1])
        deq_r, codes_r, scales_r, g_r = ref.nvfp4_fos_quant_ref(x)
        assert np.isclose(float(g_k), float(g_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(scales_k), np.asarray(scales_r),
                                   rtol=1e-6)
        # codes may disagree on exact rounding-boundary ties (fp association
        # order differs between kernel and oracle): allow <0.01% one-step
        # grid-neighbour mismatches, none elsewhere
        ck, cr = np.asarray(codes_k, np.int32), np.asarray(codes_r, np.int32)
        diff = ck != cr
        assert diff.mean() < 1e-4, diff.mean()
        assert (np.abs(ck[diff] - cr[diff]) <= 1).all()
        dk = np.asarray(deq_k, np.float32)
        dr = np.asarray(deq_r, np.float32)
        ok = np.isclose(dk, dr, rtol=1e-2, atol=1e-6)
        assert (~ok).mean() < 1e-4

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        x = (jax.random.normal(jax.random.PRNGKey(1), (64, 128)) * 3).astype(dtype)
        deq, codes, scales, g = nvfp4_fos_quant(x, bm=64, bk=128)
        assert not bool(jnp.isnan(deq.astype(jnp.float32)).any())
        # MSE close to the 4/6 oracle's on the same data
        m_k = float(jnp.mean((deq.astype(jnp.float32) - x.astype(jnp.float32)) ** 2))
        m_r = float(Q.mse(x.astype(jnp.float32), Q.quant_four_over_six(x)))
        assert m_k <= m_r * 1.2 + 1e-6

    def test_zero_input(self):
        deq, codes, scales, g = nvfp4_fos_quant(jnp.zeros((32, 64)), bm=32, bk=64)
        assert float(jnp.abs(deq.astype(jnp.float32)).max()) == 0.0


class TestMSEdenRequantKernel:
    @pytest.mark.parametrize("shape,bm", [
        ((128, 256), 128),
        ((256, 128), 64),
        ((64, 1024), 32),
        ((96, 48), 32),     # non-128 inner dim -> smaller hadamard block
    ])
    def test_matches_oracle(self, shape, bm):
        x = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32)
        rk = jnp.asarray([3, 5], jnp.uint32)
        sk = jnp.asarray([7, 9], jnp.uint32)
        codes_k, scales_k, g_k = ms_eden_requant(x, rk, sk, bm=bm)
        codes_r, scales_r, g_r = ref.ms_eden_requant_ref(x, rk, sk)
        assert np.isclose(float(g_k), float(g_r), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
        # scales: SR draws differ between kernel (uniform operand) and oracle
        # (threefry inside fp8_sr_pos) -> compare the deterministic pre-SR
        # target within one ulp (scales land on adjacent e4m3 points)
        sk_f = np.asarray(scales_k)
        sr_f = np.asarray(scales_r)
        rel = np.abs(sk_f - sr_f) / np.maximum(np.abs(sr_f), 1e-9)
        assert (rel < 0.14).all()  # one e4m3 ulp is ~1/8 relative

    def test_unbiasedness_through_kernel(self):
        """Averaging kernel outputs over SR seeds converges to the RTN+EDEN
        target (the kernel preserves MS-EDEN's unbiasedness contract)."""
        from repro.core import rht as R
        x = jax.random.normal(jax.random.PRNGKey(3), (32, 128), jnp.float32)
        rk = jnp.asarray([1, 2], jnp.uint32)

        def draw(i):
            codes, scales, g = ms_eden_requant(
                x, rk, jnp.asarray([11, i], jnp.uint32), bm=32)
            vals = F.fp4_decode(codes) * jnp.repeat(scales, F.GROUP, -1) * g
            return R.rht_inv(vals, jax.random.wrap_key_data(rk))

        avg = jnp.mean(jnp.stack([draw(i) for i in range(64)]), 0)
        rel = float(jnp.linalg.norm(avg - x) / jnp.linalg.norm(x))
        # 64 draws of ~0.5 per-draw rel error -> ~0.065 expected, MC slack
        assert rel < 0.12, rel

    def test_phase2_touches_only_scales(self):
        """Post-hoc property: phase-2's data volume is 1/16 of phase 1."""
        x = jnp.ones((64, 256))
        codes, scales, g = ms_eden_requant(
            x, jnp.asarray([1, 2], jnp.uint32), jnp.asarray([3, 4], jnp.uint32), bm=64)
        assert scales.size * F.GROUP == codes.size


class TestFP4MatmulKernel:
    def _mk(self, key, m, k):
        x = jax.random.normal(key, (m, k), jnp.float32)
        qt = Q.quant_rtn(x, s=Q.S_EDEN)
        return F.pack_fp4(qt.codes), qt.scales, qt.gscale

    @pytest.mark.parametrize("mnk,blocks", [
        ((128, 128, 512), (128, 128, 512)),
        ((256, 128, 1024), (128, 64, 256)),
        ((64, 96, 256), (32, 32, 128)),
        ((128, 128, 64), (128, 128, 64)),
    ])
    def test_matches_oracle(self, mnk, blocks):
        m, n, k = mnk
        ap, asc, ag = self._mk(jax.random.PRNGKey(0), m, k)
        bp, bsc, bg = self._mk(jax.random.PRNGKey(1), n, k)
        out = fp4_matmul(ap, asc, bp, bsc, ag, bg,
                         bm=blocks[0], bn=blocks[1], bk=blocks[2])
        want = ref.fp4_matmul_ref(ap, asc, bp, bsc, ag, bg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-2, atol=2e-2 * float(jnp.abs(want).max()))

    def test_wire_format_is_4bit(self):
        ap, asc, ag = self._mk(jax.random.PRNGKey(0), 32, 128)
        assert ap.dtype == jnp.uint8 and ap.shape == (32, 64)  # 2 codes/byte

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_property_random_inputs(self, seed):
        ap, asc, ag = self._mk(jax.random.PRNGKey(seed), 32, 128)
        bp, bsc, bg = self._mk(jax.random.PRNGKey(seed + 1), 32, 128)
        out = fp4_matmul(ap, asc, bp, bsc, ag, bg, bm=32, bn=32, bk=128)
        want = ref.fp4_matmul_ref(ap, asc, bp, bsc, ag, bg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-2, atol=1e-2 * float(jnp.abs(want).max() + 1e-9))

    def test_e2m1_arithmetic_decode(self):
        """The gather-free decode covers all 16 codes exactly."""
        from repro.kernels.fp4_matmul import _decode_vec
        codes = jnp.arange(16, dtype=jnp.uint8)
        want = F.fp4_decode(codes)
        np.testing.assert_allclose(np.asarray(_decode_vec(codes)),
                                   np.asarray(want))


class TestFusedBackwardGemm:
    def test_quartet2_backward_gemm_matches_sim_path(self):
        """ops.quartet2_backward_gemm (kernel path) ~= the simulated MS-EDEN
        GEMM in core/linear (same rotation seed; SR draws differ, so compare
        against the exact product within quantization noise)."""
        import jax
        import jax.numpy as jnp
        import numpy as np
        from repro.kernels.ops import quartet2_backward_gemm

        a = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (32, 256), jnp.float32)
        out = quartet2_backward_gemm(
            a, b, jnp.asarray([1, 2], jnp.uint32),
            jnp.asarray([3, 4], jnp.uint32), jnp.asarray([5, 6], jnp.uint32))
        exact = a @ b.T
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        assert out.shape == (64, 32) and rel < 0.25, rel
