"""Paged-attention flash-decode kernel parity suite (interpret mode).

Three layers of agreement, per the kernel contract:

  1. kernel vs `kernels.ref` oracle — the oracle IS the serving reference
     path (gather_view + decode_sdpa / the absorbed-form MLA einsums), so
     numeric agreement means the kernel can replace it;
  2. kernel vs an INLINE gather_view + decode_sdpa composition — guards the
     oracle itself against drift;
  3. engine level: `paged_kernel=True` (Pallas, interpret on CPU) must emit
     a greedy token stream BITWISE-identical to the reference path for
     gqa / mla / sliding-window configs, including the speculative
     (n_slots, spec_k+1) verify chunks.

Cases sweep ragged per-row lengths, partially-allocated block tables
(trailing OOB-sentinel entries), fully-unallocated rows (inactive slots),
reclaimed sentinel PREFIXES (sliding-window mid-sequence frees), windowed
masks, and multi-token chunks. Numeric tolerance is fp32 online-softmax
association noise (~1e-7); token streams are compared exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import ops, ref
from repro.models import lm
from repro.models.attention import decode_sdpa
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.kv_pool import gather_view

ATOL, RTOL = 5e-6, 1e-5


# --------------------------------------------------------------------------
# pool/table builders
# --------------------------------------------------------------------------

BS, MAXB, N_BLOCKS = 4, 4, 10


def _mk_table(rng, lens, n_slots, sentinel_prefix=0):
    """Block table backing `lens[i]` tokens per row with RANDOM physical
    blocks (logical order != physical order), trailing entries OOB sentinel.
    `sentinel_prefix` marks leading logical blocks reclaimed (sliding-window
    frees): their entries revert to the sentinel."""
    table = np.full((n_slots, MAXB), N_BLOCKS, np.int32)
    free = list(rng.permutation(N_BLOCKS))
    for i, n in enumerate(lens):
        for j in range(-(-n // BS)):
            table[i, j] = free.pop()
    table[:, :sentinel_prefix] = N_BLOCKS
    return jnp.asarray(table)


def _fill_pool(rng, table, lens, *feat):
    """bf16 pool with real values at every backed (block, offset) position
    and garbage (not zeros!) elsewhere — masked lanes must not leak."""
    pool = rng.randn(N_BLOCKS, BS, *feat) * 7.0  # stale garbage everywhere
    table = np.asarray(table)
    for i, n in enumerate(lens):
        for t in range(n):
            blk = table[i, t // BS]
            if blk < N_BLOCKS:
                pool[blk, t % BS] = rng.randn(*feat) * 0.5
    return jnp.asarray(pool, jnp.bfloat16)


# --------------------------------------------------------------------------
# kernel vs oracle vs inline composition
# --------------------------------------------------------------------------

class TestGQAKernel:
    @pytest.mark.parametrize("sq,window", [(1, None), (1, 6), (3, None),
                                           (3, 6), (4, 11)])
    def test_matches_oracle_and_composition(self, sq, window, np_rng):
        kv, rep, hd, vd = 2, 2, 8, 8
        h = kv * rep
        lens = [5, 11, 16, 0]     # ragged; partial tables; row 3 inactive
        pos = jnp.asarray([max(n - sq, 0) for n in lens], jnp.int32)
        table = _mk_table(np_rng, lens, len(lens))
        kp = _fill_pool(np_rng, table, lens, kv, hd)
        vp = _fill_pool(np_rng, table, lens, kv, vd)
        q = jnp.asarray(np_rng.randn(len(lens), sq, h, hd) * 0.5, jnp.float32)

        out = ops.paged_attention(q, kp, vp, table, pos, window=window)
        want = ref.paged_attention_ref(q, kp, vp, table, pos, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL, rtol=RTOL)
        # inline composition — today's serving reference path, literally
        inline = decode_sdpa(q, gather_view(kp, table), gather_view(vp, table),
                             pos, window=window)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(inline))
        # the fully-unallocated row is exact zeros on both paths
        assert float(jnp.abs(out[3]).max()) == 0.0
        assert float(jnp.abs(want[3]).max()) == 0.0

    def test_window_reclaimed_sentinel_prefix(self, np_rng):
        """Sliding-window reclamation frees LEADING logical blocks (their
        table entries revert to the sentinel). Those keys sit outside every
        query's window, so kernel and reference agree with the prefix gone."""
        kv, rep, hd = 2, 1, 8
        window, n = 6, 15
        pos = jnp.asarray([n - 1], jnp.int32)
        state = np_rng.get_state()
        full = _mk_table(np_rng, [n], 1)
        kp = _fill_pool(np_rng, full, [n], kv, hd)
        vp = _fill_pool(np_rng, full, [n], kv, hd)
        q = jnp.asarray(np_rng.randn(1, 1, kv * rep, hd) * 0.5, jnp.float32)
        # reclaim horizon: blocks with newest key <= (n-1) - window
        first_live = (n - window) // BS
        np_rng.set_state(state)  # same physical layout, prefix reclaimed
        reclaimed = _mk_table(np_rng, [n], 1, sentinel_prefix=first_live)
        out = ops.paged_attention(q, kp, vp, reclaimed, pos, window=window)
        want_full = ref.paged_attention_ref(q, kp, vp, full, pos, window=window)
        want_recl = ref.paged_attention_ref(q, kp, vp, reclaimed, pos,
                                            window=window)
        np.testing.assert_array_equal(np.asarray(want_full),
                                      np.asarray(want_recl))
        np.testing.assert_allclose(np.asarray(out), np.asarray(want_recl),
                                   atol=ATOL, rtol=RTOL)

    def test_grouped_heads_vs_mha(self, np_rng):
        """rep > 1 must equal running each duplicated KV head as MHA."""
        kv, rep, hd = 2, 3, 8
        lens = [9, 13]
        pos = jnp.asarray([n - 1 for n in lens], jnp.int32)
        table = _mk_table(np_rng, lens, 2)
        kp = _fill_pool(np_rng, table, lens, kv, hd)
        vp = _fill_pool(np_rng, table, lens, kv, hd)
        q = jnp.asarray(np_rng.randn(2, 1, kv * rep, hd) * 0.5, jnp.float32)
        out = ops.paged_attention(q, kp, vp, table, pos)
        kp_m = jnp.repeat(kp, rep, axis=2)
        vp_m = jnp.repeat(vp, rep, axis=2)
        want = ops.paged_attention(q, kp_m, vp_m, table, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL, rtol=RTOL)


class TestMLAKernel:
    @pytest.mark.parametrize("sq", [1, 3])
    def test_matches_oracle(self, sq, np_rng):
        h, lora, rope, qk_dim = 3, 8, 4, 48
        lens = [6, 14, 0]
        pos = jnp.asarray([max(n - sq, 0) for n in lens], jnp.int32)
        table = _mk_table(np_rng, lens, len(lens))
        cc = _fill_pool(np_rng, table, lens, lora)
        kc = _fill_pool(np_rng, table, lens, rope)
        qa = jnp.asarray(np_rng.randn(len(lens), sq, h, lora) * 0.5,
                         jnp.float32)
        qr = jnp.asarray(np_rng.randn(len(lens), sq, h, rope) * 0.5,
                         jnp.float32)
        out = ops.paged_mla_attention(qa, qr, cc, kc, table, pos,
                                      qk_dim=qk_dim)
        want = ref.paged_mla_attention_ref(qa, qr, cc, kc, table, pos,
                                           qk_dim=qk_dim)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=ATOL, rtol=RTOL)
        assert float(jnp.abs(out[2]).max()) == 0.0  # inactive row


# --------------------------------------------------------------------------
# engine level: kernel path == reference path, bitwise token streams
# --------------------------------------------------------------------------

def _cfg(arch):
    cfg = registry.get(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _lattn_cfg():
    base = registry.get("recurrentgemma_9b").reduced()
    return dataclasses.replace(
        base, griffin=dataclasses.replace(base.griffin, window=8,
                                          pattern=("attn", "attn")))


def _streams(cfg, params, prompts, max_new, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("prequant", False)
    eng = ServeEngine(cfg, params, EngineConfig(**kw))
    ids = [eng.submit(Request(prompt=p, max_new=max_new)) for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids]


@pytest.mark.serve
class TestEngineKernelPath:
    @pytest.mark.parametrize("make_cfg", [lambda: _cfg("yi_9b"),
                                          lambda: _cfg("deepseek_v3_671b"),
                                          _lattn_cfg],
                             ids=["gqa", "mla", "lattn"])
    def test_greedy_stream_bitwise(self, make_cfg, base_key, np_rng):
        """paged_kernel=True (interpret) emits the SAME tokens as the
        gather_view reference engine — gqa, mla, and lattn (the windowed
        engine also exercises mid-sequence block reclamation: block_size=4
        frees out-of-window blocks while decoding)."""
        cfg = make_cfg()
        params = lm.init(cfg, base_key)
        prompts = [list(map(int, np_rng.randint(0, cfg.vocab, n)))
                   for n in (9, 13)]
        kw = dict(scheme="bf16", paged=True)
        if cfg.griffin is not None:
            kw["block_size"] = 4  # reclamation kicks in mid-stream
        a = _streams(cfg, params, prompts, 6, paged_kernel=False, **kw)
        b = _streams(cfg, params, prompts, 6, paged_kernel=True, **kw)
        assert a == b

    def test_quartet2_stream_bitwise_and_deterministic(self, base_key,
                                                       np_rng):
        """The NVFP4 serving scheme stays greedy-stable under the kernel:
        same stream as the reference path, and deterministic run-to-run."""
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        prompts = [list(map(int, np_rng.randint(0, cfg.vocab, n)))
                   for n in (9, 13)]
        a = _streams(cfg, params, prompts, 5, scheme="quartet2",
                     paged_kernel=False)
        b = _streams(cfg, params, prompts, 5, scheme="quartet2",
                     paged_kernel=True)
        c = _streams(cfg, params, prompts, 5, scheme="quartet2",
                     paged_kernel=True)
        assert a == b == c

    def test_spec_decode_verify_chunk_through_kernel(self, base_key, np_rng):
        """The (n_slots, spec_k+1) verify chunk runs through the kernel's
        multi-token path; the emitted stream must still equal the
        non-speculative kernel engine bitwise (bf16 chunk invariance)."""
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        prompts = [list(map(int, np_rng.randint(0, cfg.vocab, n)))
                   for n in (9, 13)]
        plain = _streams(cfg, params, prompts, 6, scheme="bf16",
                         paged_kernel=True)
        spec = _streams(cfg, params, prompts, 6, scheme="bf16",
                        paged_kernel=True, spec_k=2, draft_layers=1)
        assert plain == spec

    def test_paged_kernel_requires_paged(self, base_key):
        cfg = _cfg("yi_9b")
        params = lm.init(cfg, base_key)
        with pytest.raises(ValueError):
            ServeEngine(cfg, params,
                        EngineConfig(n_slots=1, max_len=32, paged=False,
                                     paged_kernel=True, prequant=False,
                                     scheme="bf16"))

    def test_default_resolves_reference_path_on_cpu(self):
        """The knob's default is backend-resolved: reference path on CPU
        (kernel would only run interpreted), kernel path on TPU."""
        e = EngineConfig()
        assert e.resolved_paged_kernel() == (jax.default_backend() == "tpu")
        assert EngineConfig(paged_kernel=True).resolved_paged_kernel()
