"""Mesh-sharded serving engine: bitwise stream parity + slot affinity.

A simulated (data=2, model=1) mesh over two host-platform CPU devices
(forced by tests/conftest.py BEFORE jax initializes) drives the engine's
manual-"data" shard_map decode path:

  - the sharded engine's emitted greedy streams must be BITWISE identical
    to the single-host engine for gqa / mla / lattn, in both the paged-pool
    and dense-cache layouts (the decode forward is row-local per slot, so
    splitting the slot batch across shards must not change a single bit —
    the contract docs/CONVENTIONS.md records);
  - the slot-affine allocator must never hand a slot a block homed on
    another shard, and the device table must carry shard-LOCAL indices;
  - speculative decoding must compose with sharding (draft pool + propose
    scan + verify chunk all run under the same shard_map specs).

Parity runs under the `bf16` scheme: quantizing schemes share one
activation absmax across the slot batch, so a data split changes the
quantization grid (the same chunk-coupling already documented for
spec_decode) — sharded quartet2 is still deterministic, just not
bit-comparable to the single-host batch. serve/README.md "Multi-host
serving" spells this out.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.launch.mesh import make_serve_mesh
from repro.models import lm
from repro.serve.engine import EngineConfig, Request, ServeEngine

pytestmark = pytest.mark.serve

needs_two_devices = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="simulated mesh needs >= 2 host-platform devices "
           "(tests/conftest.py forces 2; something overrode XLA_FLAGS)")


def _gqa_cfg():
    return registry.get("yi_9b").reduced()


def _mla_cfg():
    cfg = registry.get("deepseek_v3_671b").reduced()
    # exactness needs no capacity drops (cf. test_serve._cfg)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))


def _lattn_cfg():
    base = registry.get("recurrentgemma_9b").reduced()
    return dataclasses.replace(
        base, griffin=dataclasses.replace(base.griffin, window=8,
                                          pattern=("attn", "attn")))


_CFGS = {"gqa": _gqa_cfg, "mla": _mla_cfg, "lattn": _lattn_cfg}


def _prompts(cfg, lens=(9, 13)):
    rng = np.random.RandomState(1)
    return [list(map(int, rng.randint(0, cfg.vocab, n))) for n in lens]


def _streams(cfg, params, prompts, max_new=5, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("scheme", "bf16")
    kw.setdefault("prequant", False)
    eng = ServeEngine(cfg, params, EngineConfig(**kw))
    ids = [eng.submit(Request(prompt=p, max_new=max_new)) for p in prompts]
    res = {r.req_id: r.tokens for r in eng.run()}
    return [res[i] for i in ids], eng


@needs_two_devices
@pytest.mark.parametrize("arch", ["gqa", "mla", "lattn"])
def test_sharded_streams_bitwise_identical(arch):
    """data=2 mesh split of the slot batch reproduces the single-host greedy
    streams bit-for-bit — paged AND dense layouts (acceptance criterion)."""
    cfg = _CFGS[arch]()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg)
    single, _ = _streams(cfg, params, prompts, paged=True)
    mesh = make_serve_mesh(2, 1)
    for paged in (True, False):
        sharded, eng = _streams(cfg, params, prompts, paged=paged, mesh=mesh)
        assert sharded == single, (arch, paged)
        assert eng.data_shards == 2


@needs_two_devices
def test_sharded_spec_stream_bitwise_identical():
    """Speculative decoding under the mesh: sharded draft propose + verify
    chunk emit exactly the single-host non-speculative greedy stream."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg)
    single, _ = _streams(cfg, params, prompts)
    mesh = make_serve_mesh(2, 1)
    sharded, eng = _streams(cfg, params, prompts, mesh=mesh,
                            spec_k=2, draft_layers=1)
    assert sharded == single
    assert eng.stats["spec_rounds"] > 0


@needs_two_devices
def test_sharded_slot_affinity_and_reclamation():
    """Slots cycle through more requests than slots; afterwards every shard's
    free list is fully restored (per-shard conservation), and while bound no
    slot ever referenced a block outside its shard (checked via the table
    history the device step consumed)."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    mesh = make_serve_mesh(2, 1)
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, prefill_chunk=8, scheme="bf16",
        prequant=False, mesh=mesh))
    pool = eng.pool
    per_shard0 = [pool.free_blocks_in_shard(s) for s in range(2)]

    tables = []
    orig = eng._forward

    def spy(size, tokens, pos, active):
        tables.append(np.array(pool._table))
        return orig(size, tokens, pos, active)

    eng._forward = spy
    prompts = _prompts(cfg, lens=(9, 13, 7, 11, 5))
    for p in prompts:
        eng.submit(Request(prompt=p, max_new=3))
    results = eng.run()
    assert len(results) == 5
    bps = pool.blocks_per_shard
    for table in tables:
        for slot in range(pool.n_slots):
            sh = pool.shard_of_slot(slot)
            real = table[slot][table[slot] != pool.sentinel]
            assert np.all(real // bps == sh), (slot, sh, real)
    assert [pool.free_blocks_in_shard(s) for s in range(2)] == per_shard0


@needs_two_devices
def test_sharded_local_table_indices():
    """table_device() under n_shards=2 carries shard-local physical indices
    with the LOCAL sentinel blocks_per_shard — never a global id."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    mesh = make_serve_mesh(2, 1)
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, prefill_chunk=8, scheme="bf16",
        prequant=False, mesh=mesh))
    pool = eng.pool
    for slot in range(2):
        pool.reset_slot(slot)
        pool.commit(slot, 20)
        pool.ensure(slot, 20)
    local = np.asarray(pool.table_device())
    bps = pool.blocks_per_shard
    assert local.max() <= bps
    for slot in range(2):
        n = pool.blocks_for(20)
        assert np.all(local[slot, :n] < bps)          # real: local range
        assert np.all(local[slot, n:] == bps)         # rest: LOCAL sentinel
        # local + shard base reproduces the canonical global table
        base = pool.shard_of_slot(slot) * bps
        np.testing.assert_array_equal(local[slot, :n] + base,
                                      pool._table[slot, :n])


@needs_two_devices
def test_sharded_engine_validates_divisibility():
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    mesh = make_serve_mesh(2, 1)
    with pytest.raises(ValueError, match="n_slots"):
        ServeEngine(cfg, params, EngineConfig(n_slots=3, mesh=mesh,
                                              scheme="bf16", prequant=False))
    with pytest.raises(ValueError, match="n_shards"):
        ServeEngine(cfg, params, EngineConfig(n_slots=2, max_len=64,
                                              n_blocks=7, mesh=mesh,
                                              scheme="bf16", prequant=False))


@needs_two_devices
def test_sharded_quartet2_deterministic():
    """Quantizing schemes are NOT bit-comparable across the data split (the
    activation absmax is shared per shard-batch, not per global batch), but
    the sharded engine must still be deterministic run-to-run."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg)
    mesh = make_serve_mesh(2, 1)
    a, _ = _streams(cfg, params, prompts, mesh=mesh, scheme="quartet2",
                    prequant=True)
    b, _ = _streams(cfg, params, prompts, mesh=mesh, scheme="quartet2",
                    prequant=True)
    assert a == b


# --------------------------------------------------------------------------
# slot-affine prefix cache + shard-occupancy placement (ISSUE 5)
# --------------------------------------------------------------------------

@needs_two_devices
def test_sharded_prefix_cache_bitwise_and_affine():
    """Prefix reuse on the sharded engine: the hot wave skips the shared
    prefix's prefill, streams stay BITWISE equal to the cache-off sharded
    engine, and the slot-affinity invariant holds throughout (adopted
    blocks home on the adopting slot's shard)."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(2)
    prompt = list(map(int, rng.randint(0, cfg.vocab, 24)))
    mesh = make_serve_mesh(2, 1)

    def waves(prefix_cache):
        eng = ServeEngine(cfg, params, EngineConfig(
            n_slots=2, max_len=64, block_size=4, prefill_chunk=8,
            scheme="bf16", prequant=False, mesh=mesh,
            prefix_cache=prefix_cache))
        out = []
        for _ in range(2):
            eng.submit(Request(prompt=prompt, max_new=4))
            out.append([r.tokens for r in eng.run()][0])
        return out, eng

    cold, _ = waves(False)
    hot, eng = waves(True)
    assert hot == cold
    assert eng.stats["prefill_skipped_tokens"] == 23
    pool = eng.pool
    bps = pool.blocks_per_shard
    for slot in range(pool.n_slots):
        sh = pool.shard_of_slot(slot)
        assert all(b // bps == sh for b in pool._owned[slot])
    # cached nodes record their home shard; all holds conserve
    assert (pool.free_block_count
            + sum(1 for b in range(pool.n_blocks) if pool.refcount(b) > 0)
            == pool.n_blocks)


@needs_two_devices
def test_sharded_prefix_unreachable_from_other_shard():
    """A prefix cached on shard 0 is NOT reusable by a slot homed on shard
    1: when shard 0 has no free slot the request admits cold elsewhere —
    correct stream, zero additional skip."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    prompt = list(map(int, rng.randint(0, cfg.vocab, 20)))
    mesh = make_serve_mesh(2, 1)
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, block_size=4, prefill_chunk=8,
        scheme="bf16", prequant=False, mesh=mesh, prefix_cache=True))
    eng.submit(Request(prompt=prompt, max_new=3))
    ref = [r.tokens for r in eng.run()][0]      # cached on shard 0
    skipped0 = eng.stats["prefill_skipped_tokens"]
    # occupy shard 0's only slot with a long request, then resubmit the
    # shared prompt: it must land on shard 1 WITHOUT the cached prefix
    blocker = eng.submit(Request(prompt=prompt, max_new=12))
    shared = eng.submit(Request(prompt=prompt, max_new=3))
    eng.step()  # blocker admitted to slot 0 (shard 0, prefix reuse)...
    res = {r.req_id: r.tokens for r in eng.run()}
    assert res[shared] == ref                   # bitwise despite cold admit
    # only the BLOCKER reused the shard-0 prefix; the cross-shard request
    # re-prefilled everything
    assert eng.stats["prefill_skipped_tokens"] == skipped0 + 19
    pool = eng.pool
    bps = pool.blocks_per_shard
    for slot in range(pool.n_slots):
        assert all(b // bps == pool.shard_of_slot(slot)
                   for b in pool._owned[slot])


@needs_two_devices
def test_sharded_prefix_spill_hot_across_shards():
    """The spill tier lifts the slot-affinity reuse limit the test above
    pins: with `prefix_spill=True` the same blocked resubmission admits HOT
    on shard 1 — the matched path is sideloaded through the host tier
    (snapshot of the shard-0 copies, dispatch-written into shard-1 blocks),
    the prefix prefill is skipped AGAIN, and the stream stays bitwise equal.
    Slot affinity still holds for every owned block."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(3)
    prompt = list(map(int, rng.randint(0, cfg.vocab, 20)))
    mesh = make_serve_mesh(2, 1)
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=2, max_len=64, block_size=4, prefill_chunk=8,
        scheme="bf16", prequant=False, mesh=mesh, prefix_cache=True,
        prefix_spill=True))
    eng.submit(Request(prompt=prompt, max_new=3))
    ref = [r.tokens for r in eng.run()][0]      # cached on shard 0
    skipped0 = eng.stats["prefill_skipped_tokens"]
    blocker = eng.submit(Request(prompt=prompt, max_new=12))
    shared = eng.submit(Request(prompt=prompt, max_new=3))
    eng.step()  # blocker admitted to slot 0 (shard 0, prefix reuse)...
    res = {r.req_id: r.tokens for r in eng.run()}
    assert res[shared] == ref                   # bitwise, now HOT cross-shard
    # BOTH the blocker and the cross-shard request skipped the 19-token
    # prefix (contrast: +19 once without spill, test above)
    assert eng.stats["prefill_skipped_tokens"] == skipped0 + 19 + 19
    assert eng.cache.stats["swapped_in_blocks"] >= 4   # sideloaded path
    pool = eng.pool
    bps = pool.blocks_per_shard
    for slot in range(pool.n_slots):
        assert all(b // bps == pool.shard_of_slot(slot)
                   for b in pool._owned[slot])
    assert (pool.free_block_count
            + sum(1 for b in range(pool.n_blocks) if pool.refcount(b) > 0)
            == pool.n_blocks)


@needs_two_devices
def test_shard_occupancy_aware_placement():
    """_admit places a new request on the shard with the most EFFECTIVE free
    blocks (free minus outstanding commitments), not the first free slot:
    after one admission reserves most of shard 0, the next request homes on
    shard 1 even though a shard-0 slot is still free."""
    cfg = _gqa_cfg()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    mesh = make_serve_mesh(2, 1)
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=4, max_len=64, block_size=4, prefill_chunk=8,
        scheme="bf16", prequant=False, mesh=mesh))
    # slots 0-1 home on shard 0, slots 2-3 on shard 1
    eng.submit(Request(prompt=[1] * 16, max_new=31))   # 47 tok ~ 12 blocks
    eng._admit()
    assert eng.slots[0].state != "free"                # ties break low: shard 0
    eng.submit(Request(prompt=[1] * 8, max_new=4))
    eng._admit()
    # shard 0 still has a free SLOT, but shard 1 has more effective free
    # blocks — occupancy-aware placement picks slot 2
    assert eng.slots[2].state != "free"
    assert eng.slots[1].state == "free"
    eng.run()
