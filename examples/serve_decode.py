"""Serve a small model with batched requests: NVFP4 forward (4/6), KV-cache
prefill + greedy decode.

    PYTHONPATH=src python examples/serve_decode.py [--arch yi_9b] [--tokens 32]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import lm
from repro.serve.decode import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--scheme", default="quartet2")
    args = ap.parse_args()

    cfg = registry.get(args.arch).reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = args.batch, args.prompt_len
    max_len = s + args.tokens + 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)

    cache = lm.init_cache(cfg, b, max_len)
    prefill = jax.jit(make_prefill_step(cfg, args.scheme))
    step = jax.jit(make_serve_step(cfg, args.scheme))

    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1:], -1)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out, t0 = [tok], time.perf_counter()
    for i in range(args.tokens - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits[:, -1:], -1)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, 1)
    print(f"arch={cfg.name} scheme={args.scheme}")
    print(f"prefill: {b}x{s} tokens in {t_prefill*1e3:.0f}ms")
    print(f"decode:  {args.tokens-1} steps x {b} seqs "
          f"= {(args.tokens-1)*b/dt:.1f} tok/s (CPU)")
    print("sample token ids:", gen[0, :12].tolist())


if __name__ == "__main__":
    main()
