"""Serve a small model through the continuous-batching engine: NVFP4 forward
(4/6), quantize-once packed weights, paged KV pool, interleaved chunked
prefill + batched decode.

    PYTHONPATH=src python examples/serve_decode.py [--arch yi_9b] [--tokens 32]

`--legacy` runs the old fixed-batch greedy loop instead (the baseline the
benchmark compares against). `--data-shards N` serves through the
mesh-sharded engine (slot-affine pool over a (data=N, model=1) mesh),
simulating N host-platform devices on CPU.
"""

import argparse
import os
import sys
import time

def _early_data_shards(argv):
    """--data-shards value, read BEFORE the first jax import (jax locks the
    device count at init). Handles both '--data-shards N' and
    '--data-shards=N'; malformed values fall through to argparse's error."""
    for i, a in enumerate(argv):
        try:
            if a == "--data-shards" and i + 1 < len(argv):
                return int(argv[i + 1])
            if a.startswith("--data-shards="):
                return int(a.split("=", 1)[1])
        except ValueError:
            return 1
    return 1


_n = _early_data_shards(sys.argv)
if _n > 1 and "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve.decode import greedy_generate
from repro.serve.engine import EngineConfig, Request, ServeEngine
from repro.serve.sampling import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--scheme", default="quartet2")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--legacy", action="store_true",
                    help="seed fixed-batch greedy loop (baseline)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative tokens per round (0 = off); the "
                         "emitted greedy stream is bitwise unchanged")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="truncated-stack draft depth (default: half the "
                         "stack when --spec-k > 0)")
    ap.add_argument("--no-prequant", action="store_true")
    ap.add_argument("--dense", action="store_true",
                    help="dense per-slot caches instead of the paged pool")
    ap.add_argument("--paged-kernel", default=None,
                    choices=["on", "off"],
                    help="block-table flash-decode Pallas kernel "
                         "(default: on for TPU, off for CPU where it would "
                         "run interpreted; 'on' forces interpret mode)")
    ap.add_argument("--kv-quant", action="store_true",
                    help="store the paged KV pool as packed NVFP4 "
                         "(PackedKV: e2m1 codes + e4m3 group scales, "
                         "0.28125x bf16 bytes; dequantized in-kernel or "
                         "exactly on the gather path — see serve/README "
                         "'Quantized KV cache')")
    ap.add_argument("--data-shards", type=int, default=1,
                    help="serve through the mesh-sharded engine: slots + "
                         "slot-affine KV pool over a (data=N, model=1) mesh "
                         "(greedy streams stay bitwise identical in bf16)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="serve N requests over ONE shared system prompt "
                         "through the radix prefix cache "
                         "(serve/prefix_cache.py): a warmup request primes "
                         "the cache, then the N requests alias its blocks "
                         "read-only and skip that prefill — reports prefill "
                         "tokens skipped and the hit rate")
    ap.add_argument("--spill-tier", type=int, default=0, metavar="N",
                    help="hierarchical-cache demo: prime a shared system "
                         "prompt, squeeze it out of the pool with filler "
                         "traffic, then serve N requests over it — with "
                         "the host spill tier the eviction snapshots the "
                         "blocks to host RAM and the N requests swap them "
                         "back in (zero prefill forwards over the prefix); "
                         "reports spill/swap-in/replication stats next to "
                         "the drop-on-evict baseline (compose with "
                         "--data-shards 2 to see cross-shard replication)")
    ap.add_argument("--metrics", action="store_true",
                    help="run with the observability layer enabled "
                         "(obs/instrumentation.py): report TTFT/queue-wait "
                         "percentiles and print the Prometheus-text metrics "
                         "snapshot at exit")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="dump per-request trace spans as JSONL "
                         "(implies --metrics)")
    args = ap.parse_args()

    backend = jax.default_backend().upper()
    cfg = registry.get(args.arch).reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = args.batch, args.prompt_len
    rng = np.random.RandomState(1)
    if args.shared_prefix > 0:
        return shared_prefix_demo(cfg, params, args, rng, backend)
    if args.spill_tier > 0:
        return spill_tier_demo(cfg, params, args, rng, backend)
    prompts = [list(map(int, rng.randint(0, cfg.vocab, s))) for _ in range(b)]

    if args.legacy:
        t0 = time.perf_counter()
        gen = greedy_generate(params, cfg, args.scheme, jnp.asarray(prompts),
                              args.tokens)
        jax.block_until_ready(gen)
        dt = time.perf_counter() - t0
        print(f"arch={cfg.name} scheme={args.scheme} legacy loop")
        print(f"generate: {b}x{args.tokens} tokens in {dt*1e3:.0f}ms "
              f"= {b*args.tokens/dt:.1f} tok/s ({backend})")
        print("sample token ids:", gen[0, :12].tolist())
        return

    draft_layers = args.draft_layers
    if args.spec_k > 0 and draft_layers == 0:
        from repro.models.lm import total_layers
        draft_layers = max(1, total_layers(cfg) // 2)
    max_len = ((s + args.tokens + args.spec_k) // 16 + 2) * 16
    mesh = None
    if args.data_shards > 1:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.data_shards, 1)
    obs = None
    if args.metrics or args.trace_out:
        from repro.obs import Instrumentation, MetricsRegistry
        obs = Instrumentation(registry=MetricsRegistry())
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=b, max_len=max_len, prefill_chunk=16,
        paged=not args.dense, prequant=not args.no_prequant,
        kv_quant=args.kv_quant,
        scheme=args.scheme, spec_k=args.spec_k, draft_layers=draft_layers,
        paged_kernel=(None if args.paged_kernel is None
                      else args.paged_kernel == "on"), mesh=mesh, obs=obs))
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k)
    ids = [eng.submit(Request(prompt=p, max_new=args.tokens, sampling=sp))
           for p in prompts]
    t0 = time.perf_counter()
    results = {r.req_id: r for r in eng.run()}
    wall = time.perf_counter() - t0
    st = eng.stats

    print(f"arch={cfg.name} scheme={args.scheme} engine "
          f"(paged={not args.dense}, prequant={not args.no_prequant}, "
          f"paged_kernel={eng.paged_kernel}"
          + (", kv_quant=True" if args.kv_quant else "")
          + (f", data_shards={eng.data_shards}" if mesh is not None else "")
          + ")")
    print(f"prefill: {st['prefill_tokens']} tokens in {st['prefill_s']*1e3:.0f}ms")
    print(f"decode:  {st['decode_tokens']} tokens over {st['decode_steps']} "
          f"steps = {st['decode_tokens']/max(st['decode_s'],1e-9):.1f} tok/s "
          f"({backend})")
    if args.spec_k > 0:
        acc = st["accepted_tokens"] / max(st["draft_tokens"], 1)
        print(f"spec:    {st['spec_rounds']} rounds, spec_k={args.spec_k}, "
              f"draft_layers={draft_layers}, "
              f"accepted {st['accepted_tokens']}/{st['draft_tokens']} "
              f"drafts (rate {acc:.2f})")
    print(f"end-to-end: {wall*1e3:.0f}ms, slots={b}, "
          f"pool blocks free {eng.pool.free_block_count}/{eng.pool.n_blocks}")
    print("sample token ids:", results[ids[0]].tokens[:12])

    if obs is not None:
        agg = obs.trace_sink.aggregates()
        for name, label in (("queue_wait_s", "queue wait"),
                            ("ttft_s", "TTFT"),
                            ("decode_tok_s", "decode/token")):
            p = agg[name]
            if p.get("count"):
                print(f"{label}: p50 {p['p50']*1e3:.1f}ms "
                      f"p95 {p['p95']*1e3:.1f}ms p99 {p['p99']*1e3:.1f}ms "
                      f"(n={p['count']})")
        if args.trace_out:
            n = obs.trace_sink.write_jsonl(args.trace_out)
            print(f"wrote {n} trace events "
                  f"({len(obs.trace_sink.traces)} requests) to "
                  f"{args.trace_out}")
        print("--- metrics snapshot (Prometheus text) ---")
        print(obs.prometheus(), end="")


def shared_prefix_demo(cfg, params, args, rng, backend):
    """--shared-prefix N: N requests over one system prompt.

    One warmup request primes the radix cache with the shared prompt's
    blocks; the N follow-ups each append a short unique suffix, alias the
    cached prefix read-only (skipping its prefill entirely), and COW at the
    divergence. Reported: prefill tokens skipped, cache hit rate, and the
    prefill-time delta vs a cold (cache-off) engine on the same workload."""
    n, s = args.shared_prefix, args.prompt_len
    system = list(map(int, rng.randint(0, cfg.vocab, s)))
    suffix = 4
    prompts = [system + list(map(int, rng.randint(0, cfg.vocab, suffix)))
               for _ in range(n)]
    # unrelated warmup prompt: triggers every step-shape jit compile in
    # BOTH engines before the timed region (otherwise the cold engine pays
    # compile time inside its wall and the "speedup" is mostly XLA)
    warm = list(map(int, rng.randint(0, cfg.vocab, 17)))
    max_len = ((s + suffix + args.tokens) // 16 + 2) * 16

    def serve(prefix_cache):
        eng = ServeEngine(cfg, params, EngineConfig(
            n_slots=min(4, n), max_len=max_len, prefill_chunk=16,
            prequant=not args.no_prequant, scheme=args.scheme,
            prefix_cache=prefix_cache))
        eng.submit(Request(prompt=list(warm), max_new=2))
        eng.run()
        if prefix_cache:
            eng.submit(Request(prompt=list(system), max_new=1))  # prime
            eng.run()
        for k in eng.stats:
            eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0
        if eng.cache is not None:  # hit rate measures the N requests only
            for k in eng.cache.stats:
                eng.cache.stats[k] = 0
        t0 = time.perf_counter()
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=args.tokens))
        results = eng.run()
        return eng, time.perf_counter() - t0, results

    cold_eng, cold_wall, _ = serve(False)
    hot_eng, hot_wall, results = serve(True)
    st = hot_eng.stats
    cst = hot_eng.cache.stats if hot_eng.cache else {}
    print(f"arch={cfg.name} scheme={args.scheme} shared-prefix demo "
          f"({n} requests x [{s} shared + {suffix} unique] tokens, "
          f"{backend})")
    print(f"cold engine: prefill {cold_eng.stats['prefill_tokens']} tokens, "
          f"wall {cold_wall*1e3:.0f}ms")
    print(f"hot engine:  prefill {st['prefill_tokens']} tokens "
          f"({st['prefill_skipped_tokens']} skipped via "
          f"{st['prefix_hits']} prefix hits), wall {hot_wall*1e3:.0f}ms")
    if cst:
        hit_rate = cst["hits"] / max(cst["lookups"], 1)
        print(f"cache: {cst['hits']}/{cst['lookups']} lookups hit "
              f"(rate {hit_rate:.2f}), {cst['hit_tokens']} tokens matched, "
              f"{cst['inserted_blocks']} blocks newly cached this wave, "
              f"{cst['evicted_blocks']} evicted")
    print("sample token ids:", results[0].tokens[:12])


def spill_tier_demo(cfg, params, args, rng, backend):
    """--spill-tier N: a hot prefix is evicted under pool pressure, then
    reused N times.

    A prime request caches the shared system prompt; filler traffic then
    squeezes the pool until the prefix's blocks are evicted. In drop mode
    (prefix_spill=False, the baseline) the N follow-ups recompute the
    prefix from scratch; with the host tier ON the eviction spilled the
    bytes to host RAM, the nodes stayed matchable, and the follow-ups swap
    them back in — zero prefill forwards over the matched prefix. With
    --data-shards >= 2 the repeated hits also replicate the prefix into
    peer shards (replicate_hits=2). Reported per mode: prefill tokens and
    skips, plus the spill / swap-in / replication counters and the
    host-tier byte count (serve/README 'Hierarchical cache &
    disaggregation')."""
    n, s = args.spill_tier, args.prompt_len
    system = list(map(int, rng.randint(0, cfg.vocab, s)))
    suffix, new = 4, min(args.tokens, 12)
    prompts = [system + list(map(int, rng.randint(0, cfg.vocab, suffix)))
               for _ in range(n)]
    # enough distinct retired streams to overflow the 2*max_len/8-block
    # pool and force the primed prefix out (LRU: it is the oldest node)
    fillers = [list(map(int, rng.randint(0, cfg.vocab, s + 8)))
               for _ in range(4)]
    warm = list(map(int, rng.randint(0, cfg.vocab, 17)))
    max_len = ((s + suffix + new) // 16 + 2) * 16
    mesh = None
    if args.data_shards > 1:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.data_shards, 1)

    def serve(spill):
        eng = ServeEngine(cfg, params, EngineConfig(
            n_slots=2, max_len=max_len, prefill_chunk=16, block_size=8,
            prequant=not args.no_prequant, scheme=args.scheme,
            prefix_cache=True, prefix_spill=spill,
            replicate_hits=2 if spill else None, mesh=mesh))
        eng.submit(Request(prompt=list(warm), max_new=2))  # jit warmup
        eng.run()
        eng.submit(Request(prompt=list(system), max_new=1))  # prime
        eng.run()
        for _ in range(2):  # two hot hits arm cross-shard replication
            eng.submit(Request(prompt=list(system), max_new=1))
            eng.run()
        for f in fillers:  # pool pressure: evicts (or spills) the prefix
            eng.submit(Request(prompt=list(f), max_new=4))
            eng.run()
        spilled = eng.cache.stats["spilled_blocks"]
        replicated = eng.cache.stats["replicated_blocks"]
        for st in (eng.stats, eng.cache.stats):
            for k in st:
                st[k] = 0 if isinstance(st[k], int) else 0.0
        t0 = time.perf_counter()
        for p in prompts:
            eng.submit(Request(prompt=list(p), max_new=new))
        results = eng.run()
        return eng, spilled, replicated, time.perf_counter() - t0, results

    drop_eng, _, _, drop_wall, _ = serve(False)
    hot_eng, spilled, replicated, hot_wall, results = serve(True)
    st, cst = hot_eng.stats, hot_eng.cache.stats
    dst, dcst = drop_eng.stats, drop_eng.cache.stats
    print(f"arch={cfg.name} scheme={args.scheme} spill-tier demo "
          f"({n} requests x [{s} shared + {suffix} unique] tokens over an "
          f"evicted prefix, {backend}"
          + (f", data_shards={hot_eng.data_shards}" if mesh else "") + ")")
    print(f"drop mode:  prefill {dst['prefill_tokens']} tokens "
          f"({dst['prefill_skipped_tokens']} skipped, "
          f"{dcst['hits']}/{dcst['lookups']} lookups hit), "
          f"wall {drop_wall*1e3:.0f}ms — the evicted prefix recomputes")
    print(f"spill mode: prefill {st['prefill_tokens']} tokens "
          f"({st['prefill_skipped_tokens']} skipped, "
          f"{cst['hits']}/{cst['lookups']} lookups hit), "
          f"wall {hot_wall*1e3:.0f}ms")
    print(f"host tier:  {spilled} blocks spilled under pressure, "
          f"{cst['swapped_in_blocks']} swapped back in "
          f"({cst['swapin_s']*1e3:.1f}ms dispatch, overlapped with decode), "
          f"{replicated + cst['replicated_blocks']} replicated to peer "
          f"shards, {hot_eng.cache.host_bytes} bytes resident on host")
    print("sample token ids:", results[0].tokens[:12])


if __name__ == "__main__":
    main()
