"""Quickstart: pre-train a tiny Llama-family model in fully-quantized NVFP4
(Quartet II) on the synthetic corpus and watch the loss fall.

    PYTHONPATH=src python examples/quickstart.py [--scheme quartet2] [--steps 200]
"""

import argparse

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import lm
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheme", default="quartet2")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    cfg = registry.get("llama_200m").reduced()
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=64,
                                        global_batch=8))
    init_state, train_step = make_train_step(
        cfg, args.scheme, base_lr=2e-3, total_steps=args.steps)
    state = init_state(lm.init(cfg, jax.random.PRNGKey(0)))
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=100, log_every=20),
        jax.jit(train_step), corpus)
    state = trainer.run(state, resume=False)
    print(f"final loss: {trainer.history[-1]['loss']:.4f} "
          f"(first: {trainer.history[0]['loss']:.4f}) scheme={args.scheme}")


if __name__ == "__main__":
    main()
