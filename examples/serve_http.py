"""Serve over HTTP: the asyncio streaming frontend end-to-end.

    PYTHONPATH=src python examples/serve_http.py [--arch yi_9b] [--tokens 24]

Spawns the engine on its bridge thread behind `CompletionFrontend`
(serve/frontend.py), then from stdlib-asyncio clients on localhost:

  1. streams several completions concurrently over SSE;
  2. hard-kills one client mid-stream (socket RST) — the frontend cancels
     the request, the engine caches its partial prefix and reclaims its
     pool blocks;
  3. resubmits the killed prompt and shows the prefix-cache hot hit:
     the resumed stream picks up the cancelled work instead of redoing it;
  4. prints the reclaim/lifecycle stats from `GET /v1/stats`.

Everything is stdlib — no HTTP client library, no server framework.
"""

import argparse
import asyncio
import json
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.frontend import CompletionFrontend, EngineBridge, \
    FrontendConfig


async def stream(port, prompt, max_new, kill_after=None):
    """SSE client; returns (tokens, done). `kill_after` aborts the socket
    after that many tokens — the mid-stream disconnect scenario."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    body = json.dumps({"prompt": prompt, "max_tokens": max_new,
                       "stream": True}).encode()
    writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: x\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    assert status == 200, f"HTTP {status}"
    toks, done = [], False
    while True:
        line = await reader.readline()
        if not line:
            break
        if not line.startswith(b"data: "):
            continue
        payload = line[6:].strip()
        if payload == b"[DONE]":
            done = True
            break
        toks.extend(json.loads(payload)["choices"][0]["tokens"])
        if kill_after is not None and len(toks) >= kill_after:
            writer.transport.abort()  # RST, not FIN: a crashed client
            return toks, done
    writer.close()
    return toks, done


async def get_json(port, path):
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: x\r\n\r\n".encode())
    await writer.drain()
    await reader.readline()  # status line
    while (await reader.readline()) not in (b"\r\n", b"\n", b""):
        pass
    body = await reader.read()
    writer.close()
    return json.loads(body)


async def scenario(port, prompts, max_new):
    t0 = time.perf_counter()
    results = await asyncio.gather(
        *[stream(port, p, max_new) for p in prompts[:-1]],
        stream(port, prompts[-1], max_new, kill_after=3))
    wall = time.perf_counter() - t0
    *alive, (killed_toks, _) = results
    print(f"{len(prompts)} concurrent SSE streams, one killed after "
          f"{len(killed_toks)} tokens ({wall*1e3:.0f}ms wall)")
    for i, (toks, done) in enumerate(alive):
        print(f"  stream {i}: {len(toks)} tokens, done={done}, "
              f"head={toks[:8]}")
    print(f"  stream {len(alive)} (killed): got {killed_toks}")

    # give the frontend's disconnect watcher a beat to cancel + reclaim
    for _ in range(50):
        st = await get_json(port, "/v1/stats")
        if st["stats"]["cancelled"] >= 1:
            break
        await asyncio.sleep(0.05)
    print(f"after disconnect: cancelled={st['stats']['cancelled']}, "
          f"pool free {st['pool_free_blocks']}/{st['pool_total_blocks']} "
          f"blocks, live handles={st['live_handles']}")

    # the killed stream's work survives in the prefix cache: resubmitting
    # prompt + received tokens hot-hits and decodes only the remainder
    resumed, done = await stream(port, prompts[-1] + killed_toks,
                                 max_new - len(killed_toks))
    st = await get_json(port, "/v1/stats")
    print(f"resubmit of killed prompt: +{len(resumed)} tokens (done={done}), "
          f"prefix hits={st['stats']['prefix_hits']}, "
          f"prefill skipped={st['stats']['prefill_skipped_tokens']} tokens")
    full = killed_toks + resumed
    print(f"  killed stream completed: {full}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_9b")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--scheme", default="quartet2")
    args = ap.parse_args()

    cfg = registry.get(args.arch).reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [list(map(int, rng.randint(0, cfg.vocab, args.prompt_len)))
               for _ in range(args.clients)]
    max_len = ((args.prompt_len + args.tokens) // 16 + 2) * 16
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=min(4, args.clients), max_len=max_len, prefill_chunk=16,
        scheme=args.scheme, prefix_cache=True))

    bridge = EngineBridge(eng)
    fe = CompletionFrontend(bridge, FrontendConfig())

    async def run():
        await fe.start()
        print(f"arch={cfg.name} scheme={args.scheme} serving on "
              f"127.0.0.1:{fe.port}")
        try:
            await scenario(fe.port, prompts, args.tokens)
        finally:
            await fe.stop()

    with bridge:
        asyncio.run(run())


if __name__ == "__main__":
    main()
