"""End-to-end recipe comparison (paper Fig. 4 in miniature): same data, same
init, different quantization schemes; prints the loss-gap leaderboard.

    PYTHONPATH=src python examples/compare_schemes.py [--steps 150]
"""

import argparse

from benchmarks.common import train_curve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    losses = {}
    for scheme in ("bf16", "nvidia", "tetrajet_v2", "four_over_six", "quartet2"):
        losses[scheme] = train_curve(scheme, steps=args.steps)
        gap = losses[scheme] - losses["bf16"]
        print(f"{scheme:16s} val_loss={losses[scheme]:.4f} gap={gap:+.4f}")
    ranked = sorted(losses, key=losses.get)
    print("\nleaderboard:", " < ".join(ranked))


if __name__ == "__main__":
    main()
