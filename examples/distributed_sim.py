"""Distributed training simulation on 8 virtual devices: DP x TP mesh with
pjit + MS-EDEN NVFP4 gradient compression on the DP axis (the beyond-paper
feature: unbiased 4.5-bit gradient traffic).

    python examples/distributed_sim.py [--steps 20] [--compress]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from repro.dist import shard_map  # version-compat wrapper
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.dist import sharding as SH
from repro.dist.compression import compressed_grad_mean
from repro.models import lm
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--compress", action="store_true",
                    help="NVFP4 MS-EDEN gradient all-reduce on the DP axis")
    args = ap.parse_args()

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = registry.get("llama_200m").reduced()
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=64,
                                        global_batch=8))

    grad_transform = None
    if args.compress:
        def grad_transform(grads, seed):
            # per-DP-shard quantized mean (wire: packed 4-bit + e4m3 scales)
            return shard_map(
                lambda g, s: compressed_grad_mean(g, "data", s),
                mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                check_vma=False)(grads, seed)

    init_state, train_step = make_train_step(
        cfg, "quartet2", base_lr=2e-3, total_steps=args.steps,
        grad_transform=grad_transform)
    state = init_state(lm.init(cfg, jax.random.PRNGKey(0)))

    with mesh:
        state_sh = SH.state_shardings(jax.eval_shape(lambda: state), mesh,
                                      fsdp=False)
        state = jax.device_put(state, state_sh)
        stepj = jax.jit(train_step, in_shardings=(state_sh, None),
                        out_shardings=(state_sh, None))
        for i in range(args.steps):
            batch = corpus.batch_at(i)
            state, m = stepj(state, batch)
            if i % 5 == 0:
                print(f"step {i} loss {float(m['loss']):.4f} "
                      f"(devices={mesh.devices.size}, "
                      f"compressed_dp={bool(args.compress)})")
    print("done — final loss", float(m["loss"]))


if __name__ == "__main__":
    main()
