"""Paper Fig. 4: fully-quantized (forward + backward) recipes vs BF16.
Expected: Quartet II has the smallest loss gap, >=20% below the baselines
(NVIDIA / TetraJet-v2 / FourOverSix)."""

from __future__ import annotations

from benchmarks.common import train_curve

SCHEMES = ["bf16", "nvidia", "tetrajet_v2", "four_over_six", "quartet2"]


def run(quick: bool = True):
    from benchmarks import common
    from benchmarks.common import smoke_steps
    steps = smoke_steps(150 if quick else 800)
    # --smoke: quartet2 vs one baseline (compiles dominate CPU wall time)
    schemes = ["bf16", "quartet2"] if common.SMOKE else SCHEMES
    rows, base = [], None
    gaps = {}
    for scheme in schemes:
        loss = train_curve(scheme, steps=steps)
        if scheme == "bf16":
            base = loss
        gaps[scheme] = loss - base
        rows.append((f"fig4/{scheme}", 0.0,
                     f"val_loss={loss:.4f} gap_vs_bf16={loss - base:+.4f}"))
    others = [v for k, v in gaps.items() if k not in ("bf16", "quartet2")]
    if others:
        rel = (min(others) - gaps["quartet2"]) / max(min(others), 1e-9)
        rows.append(("fig4/quartet2_improvement_vs_best_baseline", 0.0,
                     f"gap_reduction={rel:+.1%} (paper: >=20%)"))
    return rows
