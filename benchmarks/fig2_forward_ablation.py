"""Paper Fig. 2: forward-pass-only quantization — native 1x16 scales vs
square 16x16 blocks, each with/without 4/6. Expected (paper Sec. 6.1):
4/6 helps native scales ~2x more than square blocks; square blocks trail."""

from __future__ import annotations

from benchmarks.common import train_curve

SCHEMES = ["bf16", "fwd_rtn_1x16", "fwd_rtn_1x16_fos", "fwd_square",
           "fwd_square_fos"]


def run(quick: bool = True):
    from benchmarks import common
    from benchmarks.common import smoke_steps
    steps = smoke_steps(120 if quick else 600)
    # --smoke: headline comparison only (compiles dominate CPU wall time)
    schemes = (["bf16", "fwd_rtn_1x16_fos"] if common.SMOKE else SCHEMES)
    rows, base = [], None
    for scheme in schemes:
        loss = train_curve(scheme, steps=steps)
        if scheme == "bf16":
            base = loss
        rows.append((f"fig2/{scheme}", 0.0,
                     f"val_loss={loss:.4f} gap_vs_bf16={loss - base:+.4f}"))
    return rows
