"""Paper Sec. 6.2 / Table 5 analogue: the nanochat-style recipe — Muon
optimizer, WSD schedule, QK-norm, ReLU^2 MLP — at CPU scale, comparing
BF16 / NVIDIA / 4:6 / TetraJet-v2 / Quartet II pre-training loss gaps."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import lm
from repro.train.train_step import make_train_step

SCHEMES = ["bf16", "nvidia", "four_over_six", "tetrajet_v2", "quartet2"]


def run(quick: bool = True):
    from benchmarks import common
    from benchmarks.common import smoke_steps
    steps = smoke_steps(120 if quick else 600)
    schemes = (["bf16", "quartet2"] if common.SMOKE else SCHEMES)
    base_cfg = common.smoke_bench_cfg() if common.SMOKE else bench_cfg()
    cfg = dataclasses.replace(base_cfg, qk_norm=True, mlp="relu2",
                              name="nanochat-bench")
    rows, base = [], None
    for scheme in schemes:
        corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=64,
                                            global_batch=8, seed=11))
        init_state, train_step = make_train_step(
            cfg, scheme, optimizer="muon", schedule="wsd", base_lr=2e-3,
            total_steps=steps, base_seed=11)
        stepj = jax.jit(train_step)
        state = init_state(lm.init(cfg, jax.random.PRNGKey(11)))
        for i in range(steps):
            state, m = stepj(state, corpus.batch_at(i))
        evals = [float(lm.lm_loss(state.params, cfg, corpus.batch_at(10**6 + j),
                                  scheme, jnp.array([9, 9], jnp.uint32)))
                 for j in range(4)]
        loss = float(np.mean(evals))
        if scheme == "bf16":
            base = loss
        rows.append((f"nanochat/{scheme}", 0.0,
                     f"val_loss={loss:.4f} gap={loss - base:+.4f}"))
    return rows
