"""Paper Fig. 6 / Table 7 analogue. No TPU wall-clock exists in this
container, so we report (a) interpret-mode relative cost of quantization vs
matmul on identical tiles (the paper's hollow-vs-filled gap), and (b) the
analytic HBM-traffic ratio NVFP4/bf16 that governs the TPU speedup —
activations and gradients move 4.5 bits instead of 16."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core import quant as Q
from repro.core import ms_eden as ME


def run(quick: bool = True):
    m = 512 if quick else 2048
    k, n = 1024, 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, k), jnp.bfloat16)

    mm = jax.jit(lambda a, b: (a @ b.T).astype(jnp.bfloat16))
    t_mm = timeit(mm, x, w, iters=3)
    qf = jax.jit(lambda a: Q.dequant(Q.quant_four_over_six(a), jnp.bfloat16))
    t_q = timeit(qf, x, iters=3)
    me = jax.jit(lambda a: ME.ms_eden(a.astype(jnp.float32),
                                      jax.random.PRNGKey(2),
                                      jax.random.PRNGKey(3)).qt.codes)
    t_me = timeit(me, x, iters=3)

    bits_bf16 = 16.0
    bits_nvfp4 = 4 + 8 / 16 + 32 / (m * k)
    return [
        ("kernel/matmul_us", t_mm, f"tile={m}x{k}x{n}"),
        ("kernel/fos_quant_us", t_q, f"overhead_vs_mm={t_q / t_mm:.2f}x (CPU proxy)"),
        ("kernel/ms_eden_us", t_me, f"overhead_vs_mm={t_me / t_mm:.2f}x (CPU proxy)"),
        ("kernel/hbm_bits_per_elem", 0.0,
         f"bf16={bits_bf16} nvfp4={bits_nvfp4:.2f} traffic_ratio={bits_nvfp4 / bits_bf16:.3f}"),
    ]
