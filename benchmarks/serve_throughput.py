"""Serving throughput: ServeEngine (continuous batching + paged KV pool +
quantize-once NVFP4 weights) vs the seed fixed-batch greedy loop.

Rows (tok/s = generated tokens per wall-second of decode):

  serve/seed_loop          — serve/decode.py greedy_generate: fixed batch,
                             dense cache, re-quantizes every weight per step
  serve/engine_requant     — engine, per-step weight quantization (isolates
                             the scheduler/pool overhead)
  serve/engine_prequant    — engine with the quantize-once weight cache
                             (the acceptance row: must beat seed_loop)
  serve/engine_spec_base   — NON-speculative engine on the spec bench model
                             (the baseline the speculative row must match)
  serve/engine_spec        — self-speculative decoding (spec_k drafts from a
                             truncated-stack prefix, one-chunk exact verify);
                             reports the accepted-token rate
  serve/engine_poisson     — engine under Poisson request arrival (open-loop
                             traffic; includes prefill interleaving)
  serve/decode_dense       — decode-path comparison: dense per-slot caches
  serve/decode_gather      — paged pool through gather_view + decode_sdpa
                             (materializes a capacity-sized copy per layer)
  serve/decode_kernel      — paged pool through the block-table flash-decode
                             Pallas kernel (kernels/paged_attention.py;
                             interpret mode on CPU, so wall time here is NOT
                             the story — the modeled bytes/token column is)
  serve/decode_sharded     — the mesh-sharded engine (EngineConfig.mesh):
                             slot-affine pool + shard_map decode over a
                             simulated (data=2, model=1) host-platform mesh
                             (benchmarks/run.py forces 2 CPU devices; falls
                             back to data=1 when unavailable). Wall time on
                             simulated CPU shards measures DISPATCH overhead
                             only — the point of the row is exercising the
                             sharded path in CI and regressing its delta vs
                             decode_gather in BENCH_serve.json

The decode_* rows also land in BENCH_serve.json with a modeled
bytes-moved-per-token estimate: dense and gather traffic scale with POOL
CAPACITY (max_len), the kernel path with the ACTUAL mean sequence length —
the bandwidth win the kernel exists for.

Speculation pays in proportion to draft/full agreement, which is a MODEL
property: random-init weights produce near-tie logits that 4-bit activation
noise flips, so the spec rows shape the bench model like a trained one —
post-draft residual branches damped, head tied to the embedding — giving
confident logits and a high (reported) acceptance rate. Both spec rows run
the same shaped model, so the comparison isolates the machinery.

CPU numbers are relative, like every bench in this harness.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import bench_cfg
from repro.models import lm
from repro.serve.decode import greedy_generate
from repro.serve.engine import EngineConfig, Request, ServeEngine


def _workload(cfg, n_requests, prompt_len, seed=0):
    rng = np.random.RandomState(seed)
    return [list(map(int, rng.randint(0, cfg.vocab, prompt_len)))
            for _ in range(n_requests)]


def _seed_loop_toks(cfg, params, prompts, max_new, scheme):
    """Seed baseline: one fixed batch, greedy loop; decode-phase tok/s."""
    batch = jnp.asarray(prompts)
    b = batch.shape[0]
    # warm compile + measure: greedy_generate jits internally per call shape
    greedy_generate(params, cfg, scheme, batch, 2)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, scheme, batch, max_new)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return b * max_new / dt, dt


def _engine_toks(cfg, params, prompts, max_new, scheme, prequant,
                 arrivals=None):
    econf = EngineConfig(n_slots=len(prompts) if arrivals is None else 4,
                         max_len=128, prefill_chunk=16, paged=True,
                         prequant=prequant, scheme=scheme)
    eng = ServeEngine(cfg, params, econf)
    if arrivals is None:
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=max_new))
        # decode-phase tok/s: stats time only the decode-step device calls,
        # so one-time jit compiles (prefill/decode shapes) are excluded the
        # same way they are for the seed baseline's warmup call
        eng.run()
        st = eng.stats
        return st["decode_tokens"] / max(st["decode_s"], 1e-9), st
    # open-loop Poisson traffic: submit requests as wall-clock time passes
    t0 = time.perf_counter()
    pending = list(zip(arrivals, prompts))
    done = 0
    while pending or eng.has_work():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(Request(prompt=pending.pop(0)[1], max_new=max_new))
        if not eng.has_work():
            time.sleep(min(0.005, max(pending[0][0] - now, 0.0)))
            continue
        done += len(eng.step())
    wall = time.perf_counter() - t0
    st = eng.stats
    total = st["decode_tokens"] + st["prefill_tokens"]
    return total / wall, st


def _warm_and_reset(eng, prompt, max_new):
    """Trigger every step-shape compile with one short request, then zero
    the stats so measurements exclude first-call jit time."""
    eng.submit(Request(prompt=prompt, max_new=max_new))
    eng.run()
    for k in eng.stats:
        eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0


def _kv_bytes_per_position(cfg):
    """K/V (or latent) cache bytes one token position occupies, summed over
    layers — the unit of decode-attention HBM traffic."""
    per = 0
    for pattern, count in lm.layer_specs(cfg):
        for mixer, _ in pattern:
            if mixer in ("gqa", "lattn"):
                per += count * 2 * cfg.n_kv_heads * cfg.hd * 2   # K+V bf16
            elif mixer == "mla":
                per += count * (cfg.mla.kv_lora_rank
                                + cfg.mla.qk_rope_head_dim) * 2  # cc+kc bf16
    return per


def _modeled_bytes_per_token(cfg, path, mean_len, max_len):
    """Decode-attention bytes moved per emitted token under each data path.

    dense  — scores run over the full (n_slots, max_len) cache: capacity.
    gather — gather_view materializes a capacity-sized copy (pool read +
             copy write) that the attention then reads again: 3x capacity.
    kernel — the block table admits only backed, in-causal-range blocks:
             the row's ACTUAL length, independent of pool capacity.
    """
    per = _kv_bytes_per_position(cfg)
    return per * {"dense": max_len, "gather": 3 * max_len,
                  "kernel": mean_len}[path]


def _decode_path_rows(cfg, params, prompts, max_new, scheme, max_len=64):
    """dense vs gather-view vs kernel decode rows + the BENCH_serve payload."""
    rows, detail = [], {}
    prompt_len = len(prompts[0])
    mean_len = prompt_len + (max_new + 1) / 2  # average backed length
    for path in ("dense", "gather", "kernel"):
        econf = EngineConfig(n_slots=len(prompts), max_len=max_len,
                             prefill_chunk=16, paged=path != "dense",
                             prequant=True, scheme=scheme,
                             paged_kernel=path == "kernel")
        eng = ServeEngine(cfg, params, econf)
        _warm_and_reset(eng, prompts[0], 2)
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=max_new))
        eng.run()
        st = eng.stats
        tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
        bpt = _modeled_bytes_per_token(cfg, path, mean_len, max_len)
        rows.append((f"serve/decode_{path}", 1e6 / tps,
                     f"tok_s={tps:.1f} modeled_bytes_per_tok={bpt:.0f}"))
        detail[path] = {
            "tok_s": round(tps, 2),
            "modeled_bytes_per_token": int(bpt),
            "kv_positions_touched": (mean_len if path == "kernel"
                                     else max_len),
            "pool_capacity": max_len,
            "mean_seq_len": mean_len,
        }
    rows.append(_sharded_decode_row(cfg, params, prompts, max_new, scheme,
                                    detail, max_len=max_len))
    return rows, detail


def _sharded_decode_row(cfg, params, prompts, max_new, scheme, detail,
                        max_len=64):
    """serve/decode_sharded: the mesh-sharded engine on a simulated
    (data=S, model=1) mesh, S = 2 when the process has two devices
    (benchmarks/run.py forces them via XLA_FLAGS). Appends its detail next
    to the dense/gather/kernel paths so BENCH_serve.json tracks the
    sharded-vs-gather delta across PRs."""
    from repro.launch.mesh import make_serve_mesh
    shards = 2 if jax.device_count() >= 2 else 1
    mesh = make_serve_mesh(shards, 1)
    econf = EngineConfig(n_slots=len(prompts), max_len=max_len,
                         prefill_chunk=16, paged=True, prequant=True,
                         scheme=scheme, mesh=mesh)
    eng = ServeEngine(cfg, params, econf)
    _warm_and_reset(eng, prompts[0], 2)
    for p in prompts:
        eng.submit(Request(prompt=p, max_new=max_new))
    eng.run()
    st = eng.stats
    tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    base = detail.get("gather", {}).get("tok_s", 0.0)
    detail["sharded"] = {
        "tok_s": round(tps, 2),
        "data_shards": shards,
        "delta_vs_gather": round(tps / base, 3) if base else None,
        "pool_capacity": max_len,
    }
    return ("serve/decode_sharded", 1e6 / tps,
            f"tok_s={tps:.1f} data_shards={shards}"
            + (f" delta_vs_gather={tps / base:.2f}x" if base else ""))


def _emit_bench_json(decode_paths, rows, smoke):
    """BENCH_serve.json at the repo root: the serving bench trajectory
    artifact future PRs regress against."""
    payload = {
        "bench": "serve_throughput",
        "smoke": bool(smoke),
        "backend": jax.default_backend(),
        "decode_paths": decode_paths,
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "BENCH_serve.json")
    with open(os.path.normpath(path), "w") as f:
        json.dump(payload, f, indent=1)


def _spec_model(cfg, params):
    """Shape random-init params like a trained model for the spec rows:
    damp every residual output projection and tie the head to the embedding
    (self-similar -> confident logits), so draft/full agreement — and thus
    the reported acceptance rate — is in the regime speculation targets."""
    import jax.tree_util as tu

    def damp(path, x):
        key = getattr(path[-1], "key", None)
        return x * 0.05 if key == "wo" else x

    shaped = dict(params)
    shaped["stages"] = [tu.tree_map_with_path(damp, st)
                        for st in params["stages"]]
    shaped["head"] = params["embed"]
    return shaped


def _spec_engine_toks(cfg, params, prompts, max_new, scheme, spec_k,
                      draft_layers):
    """Decode tok/s + acceptance for one engine config, COMPILE-EXCLUDED:
    a short warm request triggers every step shape (prefill chunk, decode,
    draft propose, verify), then stats reset before the measured batch."""
    econf = EngineConfig(n_slots=len(prompts), max_len=128, prefill_chunk=16,
                         paged=True, prequant=True, scheme=scheme,
                         spec_k=spec_k, draft_layers=draft_layers)
    eng = ServeEngine(cfg, params, econf)
    # a full prefill_chunk-sized warm prompt hits the chunked prefill shape
    # (shorter prompts take the token-by-token path instead), and max_new
    # spans TWO spec rounds so the draft catch-up step — which a first round
    # never needs — also compiles before measurement
    _warm_and_reset(eng, prompts[0], max(2 * (spec_k + 1), 3))
    for p in prompts:
        eng.submit(Request(prompt=p, max_new=max_new))
    eng.run()
    st = eng.stats
    tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    acc = st["accepted_tokens"] / max(st["draft_tokens"], 1)
    return tps, acc, st


def run(quick: bool = True):
    smoke = getattr(common, "SMOKE", False)
    cfg = (common.smoke_bench_cfg() if smoke
           else bench_cfg(d_model=256, n_layers=2, vocab=512, d_ff=512))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    scheme = "quartet2"
    batch = 4
    max_new = 8 if smoke else (24 if quick else 64)
    prompts = _workload(cfg, batch, prompt_len=16)

    rows = []
    seed_tps, _ = _seed_loop_toks(cfg, params, prompts, max_new, scheme)
    rows.append(("serve/seed_loop", 1e6 / seed_tps,
                 f"tok_s={seed_tps:.1f} batch={batch}"))

    if not smoke:  # isolates scheduler overhead; skipped on the CI path
        rq_tps, _ = _engine_toks(cfg, params, prompts, max_new, scheme,
                                 prequant=False)
        rows.append(("serve/engine_requant", 1e6 / rq_tps,
                     f"tok_s={rq_tps:.1f} batch={batch}"))

    pq_tps, _ = _engine_toks(cfg, params, prompts, max_new, scheme,
                             prequant=True)
    rows.append(("serve/engine_prequant", 1e6 / pq_tps,
                 f"tok_s={pq_tps:.1f} batch={batch} "
                 f"speedup_vs_seed={pq_tps / seed_tps:.2f}x"))

    # --- decode data-path comparison (dense / gather-view / Pallas kernel);
    # runs under --smoke too, so CI exercises the kernel wrapper. max_new is
    # capped so prompt+new stays well under the 64-position pool: the
    # capacity/actual-length GAP is the thing the bytes model measures ------
    dp_new = 4 if smoke else min(max_new, 24)
    dp_rows, dp_detail = _decode_path_rows(cfg, params, prompts, dp_new,
                                           scheme)
    rows.extend(dp_rows)

    # --- self-speculative decoding (needs >= 2 layers for a prefix draft) ---
    spec_cfg = (bench_cfg(d_model=128, n_layers=2, vocab=256, d_ff=256)
                if smoke else cfg)
    spec_params = _spec_model(
        spec_cfg, params if spec_cfg is cfg
        else lm.init(spec_cfg, jax.random.PRNGKey(0)))
    spec_prompts = _workload(spec_cfg, batch, prompt_len=16)
    spec_new = 30 if smoke else (35 if quick else 65)
    base_tps, _, _ = _spec_engine_toks(spec_cfg, spec_params, spec_prompts,
                                       spec_new, scheme, 0, 0)
    rows.append(("serve/engine_spec_base", 1e6 / base_tps,
                 f"tok_s={base_tps:.1f} batch={batch}"))
    sp_tps, acc, _ = _spec_engine_toks(spec_cfg, spec_params, spec_prompts,
                                       spec_new, scheme, 4, 1)
    rows.append(("serve/engine_spec", 1e6 / sp_tps,
                 f"tok_s={sp_tps:.1f} accept_rate={acc:.2f} spec_k=4 "
                 f"draft_layers=1 speedup_vs_base={sp_tps / base_tps:.2f}x"))

    if not smoke:
        n_req = 8 if quick else 32
        rng = np.random.RandomState(7)
        # Poisson arrivals: mean inter-arrival tuned to keep the pipe busy
        arrivals = np.cumsum(rng.exponential(0.05, n_req)).tolist()
        po_prompts = _workload(cfg, n_req, prompt_len=16, seed=7)
        po_tps, st = _engine_toks(cfg, params, po_prompts, max_new, scheme,
                                  prequant=True, arrivals=arrivals)
        rows.append(("serve/engine_poisson", 1e6 / max(po_tps, 1e-9),
                     f"tok_s={po_tps:.1f} requests={n_req} "
                     f"slots=4 finished={st['finished']}"))
    _emit_bench_json(dp_detail, rows, smoke)
    return rows
