"""Serving throughput: ServeEngine (continuous batching + paged KV pool +
quantize-once NVFP4 weights) vs the seed fixed-batch greedy loop.

Rows (tok/s = generated tokens per wall-second of decode):

  serve/seed_loop          — serve/decode.py greedy_generate: fixed batch,
                             dense cache, re-quantizes every weight per step
  serve/engine_requant     — engine, per-step weight quantization (isolates
                             the scheduler/pool overhead)
  serve/engine_prequant    — engine with the quantize-once weight cache
                             (the acceptance row: must beat seed_loop)
  serve/engine_spec_base   — NON-speculative engine on the spec bench model
                             (the baseline the speculative row must match)
  serve/engine_spec        — self-speculative decoding (spec_k drafts from a
                             truncated-stack prefix, one-chunk exact verify);
                             reports the accepted-token rate
  serve/engine_poisson     — engine under Poisson request arrival (open-loop
                             traffic; includes prefill interleaving)
  serve/decode_dense       — decode-path comparison: dense per-slot caches
  serve/decode_gather      — paged pool through gather_view + decode_sdpa
                             (materializes a capacity-sized copy per layer)
  serve/decode_kernel      — paged pool through the block-table flash-decode
                             Pallas kernel (kernels/paged_attention.py;
                             interpret mode on CPU, so wall time here is NOT
                             the story — the modeled bytes/token column is)
  serve/decode_kernel_q    — NVFP4-quantized pool (EngineConfig.kv_quant)
                             through the packed-operand kernel twins: blocks
                             stream as e2m1 codes + e4m3 scale bits (0.5625
                             bytes/element vs 2 for bf16) and dequantize in
                             VMEM, so modeled bytes/token drops to 0.28125x
                             the bf16 kernel row (the acceptance bound is
                             <= ~0.3x)
  serve/decode_prefix_cold — shared-system-prompt workload, prefix cache ON
                             but EMPTY (first wave): prices the cache's
                             bookkeeping overhead on a miss-only run
  serve/decode_prefix_hot  — same workload, cache PRIMED: every request
                             aliases the cached prompt blocks read-only and
                             skips that prefill (reports tokens skipped and
                             hit rate) — the prefix-sharing win
  serve/prefix_zipf_drop   — Zipf multi-tenant workload (shared per-tenant
                             system prompts, Zipf(1.1) tenant popularity,
                             deterministic seed) on a DELIBERATELY small
                             pool: prefix cache ON, spill tier OFF, so
                             eviction under pressure discards prefixes
  serve/prefix_zipf_spill  — the same workload, byte for byte, with the
                             host-RAM spill tier ON: eviction snapshots to
                             host and a later tenant recurrence swaps the
                             prefix back in instead of re-prefilling.
                             Reports hit-rate, swap-in stall fraction and
                             p50/p99 latency; BENCH_serve.json's
                             `prefix_tiers` section pins spill > drop on
                             hit-rate (the hierarchical-cache win)
  serve/frontend_stream    — the asyncio HTTP frontend end-to-end: SSE
                             streaming clients over localhost with the
                             engine on its bridge thread; one client is
                             killed mid-stream to price the disconnect ->
                             cancel -> reclaim path (streamed tok/s, TTFB,
                             lifecycle accounting in BENCH_serve.json)
  serve/latency_deadline   — mixed-priority Poisson-less batch under
                             scheduler.LatencyPolicy with per-request
                             deadlines: reports p50/p99 request latency and
                             the deadline-met fraction (BENCH_serve.json
                             carries the distribution for regression)
  serve/decode_sharded     — the mesh-sharded engine (EngineConfig.mesh):
                             slot-affine pool + shard_map decode over a
                             simulated (data=2, model=1) host-platform mesh
                             (benchmarks/run.py forces 2 CPU devices; falls
                             back to data=1 when unavailable). Wall time on
                             simulated CPU shards measures DISPATCH overhead
                             only — the point of the row is exercising the
                             sharded path in CI and regressing its delta vs
                             decode_gather in BENCH_serve.json

The decode_* rows also land in BENCH_serve.json with a modeled
bytes-moved-per-token estimate: dense and gather traffic scale with POOL
CAPACITY (max_len), the kernel path with the ACTUAL mean sequence length —
the bandwidth win the kernel exists for.

Speculation pays in proportion to draft/full agreement, which is a MODEL
property: random-init weights produce near-tie logits that 4-bit activation
noise flips, so the spec rows shape the bench model like a trained one —
post-draft residual branches damped, head tied to the embedding — giving
confident logits and a high (reported) acceptance rate. Both spec rows run
the same shaped model, so the comparison isolates the machinery.

CPU numbers are relative, like every bench in this harness.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from benchmarks.common import bench_cfg
from repro.configs import registry
from repro.models import lm
from repro.serve.decode import greedy_generate
from repro.serve.engine import EngineConfig, Request, ServeEngine


def _workload(cfg, n_requests, prompt_len, seed=0):
    rng = np.random.RandomState(seed)
    return [list(map(int, rng.randint(0, cfg.vocab, prompt_len)))
            for _ in range(n_requests)]


def _seed_loop_toks(cfg, params, prompts, max_new, scheme):
    """Seed baseline: one fixed batch, greedy loop; decode-phase tok/s."""
    batch = jnp.asarray(prompts)
    b = batch.shape[0]
    # warm compile + measure: greedy_generate jits internally per call shape
    greedy_generate(params, cfg, scheme, batch, 2)
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, scheme, batch, max_new)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return b * max_new / dt, dt


def _engine_toks(cfg, params, prompts, max_new, scheme, prequant,
                 arrivals=None, obs=None):
    econf = EngineConfig(n_slots=len(prompts) if arrivals is None else 4,
                         max_len=128, prefill_chunk=16, paged=True,
                         prequant=prequant, scheme=scheme, obs=obs)
    eng = ServeEngine(cfg, params, econf)
    if arrivals is None:
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=max_new))
        # decode-phase tok/s: stats time only the decode-step device calls,
        # so one-time jit compiles (prefill/decode shapes) are excluded the
        # same way they are for the seed baseline's warmup call
        eng.run()
        st = eng.stats
        return st["decode_tokens"] / max(st["decode_s"], 1e-9), st
    # open-loop Poisson traffic: submit requests as wall-clock time passes
    t0 = time.perf_counter()
    pending = list(zip(arrivals, prompts))
    done = 0
    while pending or eng.has_work():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            eng.submit(Request(prompt=pending.pop(0)[1], max_new=max_new))
        if not eng.has_work():
            time.sleep(min(0.005, max(pending[0][0] - now, 0.0)))
            continue
        done += len(eng.step())
    wall = time.perf_counter() - t0
    st = eng.stats
    total = st["decode_tokens"] + st["prefill_tokens"]
    return total / wall, st


def _warm_and_reset(eng, prompt, max_new):
    """Trigger every step-shape compile with one short request, then zero
    the stats so measurements exclude first-call jit time."""
    eng.submit(Request(prompt=prompt, max_new=max_new))
    eng.run()
    for k in eng.stats:
        eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0


# NVFP4 cache storage: packed e2m1 codes (0.5 B/elt) + one e4m3 scale byte
# per 16-group = 0.5625 B/element, vs 2 for bf16 (core/formats.py codec)
_KVQ_BYTES_PER_ELT = 0.5625


def _kv_bytes_per_position(cfg, *, quantized=False):
    """K/V (or latent) cache bytes one token position occupies, summed over
    layers — the unit of decode-attention HBM traffic."""
    elt = _KVQ_BYTES_PER_ELT if quantized else 2  # bf16
    per = 0
    for pattern, count in lm.layer_specs(cfg):
        for mixer, _ in pattern:
            if mixer in ("gqa", "lattn"):
                per += count * 2 * cfg.n_kv_heads * cfg.hd * elt   # K+V
            elif mixer == "mla":
                per += count * (cfg.mla.kv_lora_rank
                                + cfg.mla.qk_rope_head_dim) * elt  # cc+kc
    return per


def _modeled_bytes_per_token(cfg, path, mean_len, max_len):
    """Decode-attention bytes moved per emitted token under each data path.

    dense    — scores run over the full (n_slots, max_len) cache: capacity.
    gather   — gather_view materializes a capacity-sized copy (pool read +
               copy write) that the attention then reads again: 3x capacity.
    kernel   — the block table admits only backed, in-causal-range blocks:
               the row's ACTUAL length, independent of pool capacity.
    kernel_q — same block admission, but blocks stream as packed NVFP4
               bytes: 0.28125x the bf16 kernel row's traffic.
    """
    per = _kv_bytes_per_position(cfg, quantized=path == "kernel_q")
    return per * {"dense": max_len, "gather": 3 * max_len,
                  "kernel": mean_len, "kernel_q": mean_len}[path]


def _decode_path_rows(cfg, params, prompts, max_new, scheme, max_len=64):
    """dense vs gather-view vs kernel decode rows + the BENCH_serve payload."""
    rows, detail = [], {}
    prompt_len = len(prompts[0])
    mean_len = prompt_len + (max_new + 1) / 2  # average backed length
    for path in ("dense", "gather", "kernel", "kernel_q"):
        econf = EngineConfig(n_slots=len(prompts), max_len=max_len,
                             prefill_chunk=16, paged=path != "dense",
                             prequant=True, scheme=scheme,
                             paged_kernel=path in ("kernel", "kernel_q"),
                             kv_quant=path == "kernel_q")
        eng = ServeEngine(cfg, params, econf)
        _warm_and_reset(eng, prompts[0], 2)
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=max_new))
        eng.run()
        st = eng.stats
        tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
        bpt = _modeled_bytes_per_token(cfg, path, mean_len, max_len)
        rows.append((f"serve/decode_{path}", 1e6 / tps,
                     f"tok_s={tps:.1f} modeled_bytes_per_tok={bpt:.0f}"))
        detail[path] = {
            "tok_s": round(tps, 2),
            "modeled_bytes_per_token": int(bpt),
            "kv_positions_touched": (mean_len if path in
                                     ("kernel", "kernel_q") else max_len),
            "pool_capacity": max_len,
            "mean_seq_len": mean_len,
        }
    # the tentpole bandwidth claim, regressed in BENCH_serve.json: packed
    # blocks move <= ~0.3x the bf16 kernel row's bytes per emitted token
    detail["kernel_q"]["bytes_ratio_vs_kernel"] = round(
        detail["kernel_q"]["modeled_bytes_per_token"]
        / detail["kernel"]["modeled_bytes_per_token"], 5)
    rows.append(_sharded_decode_row(cfg, params, prompts, max_new, scheme,
                                    detail, max_len=max_len))
    return rows, detail


def _sharded_decode_row(cfg, params, prompts, max_new, scheme, detail,
                        max_len=64):
    """serve/decode_sharded: the mesh-sharded engine on a simulated
    (data=S, model=1) mesh, S = 2 when the process has two devices
    (benchmarks/run.py forces them via XLA_FLAGS). Appends its detail next
    to the dense/gather/kernel paths so BENCH_serve.json tracks the
    sharded-vs-gather delta across PRs."""
    from repro.launch.mesh import make_serve_mesh
    shards = 2 if jax.device_count() >= 2 else 1
    mesh = make_serve_mesh(shards, 1)
    econf = EngineConfig(n_slots=len(prompts), max_len=max_len,
                         prefill_chunk=16, paged=True, prequant=True,
                         scheme=scheme, mesh=mesh)
    eng = ServeEngine(cfg, params, econf)
    _warm_and_reset(eng, prompts[0], 2)
    for p in prompts:
        eng.submit(Request(prompt=p, max_new=max_new))
    eng.run()
    st = eng.stats
    tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    base = detail.get("gather", {}).get("tok_s", 0.0)
    detail["sharded"] = {
        "tok_s": round(tps, 2),
        "data_shards": shards,
        "delta_vs_gather": round(tps / base, 3) if base else None,
        "pool_capacity": max_len,
    }
    return ("serve/decode_sharded", 1e6 / tps,
            f"tok_s={tps:.1f} data_shards={shards}"
            + (f" delta_vs_gather={tps / base:.2f}x" if base else ""))


def _prefix_cache_rows(cfg, params, scheme, detail, smoke):
    """serve/decode_prefix_{cold,hot}: a shared-system-prompt fleet through
    the radix prefix cache. Cold = cache on but empty (miss-only overhead);
    hot = cache primed by the cold wave on the SAME engine, so every
    request aliases the cached prompt and skips its prefill. The prefill
    seconds-per-request delta is the headline; BENCH_serve.json keeps the
    skip/hit accounting."""
    n_req = 4 if smoke else 8
    prompt_len, suffix, max_new = (32, 4, 4) if smoke else (48, 4, 8)
    rng = np.random.RandomState(11)
    system = list(map(int, rng.randint(0, cfg.vocab, prompt_len)))
    prompts = [system + list(map(int, rng.randint(0, cfg.vocab, suffix)))
               for _ in range(n_req)]
    econf = EngineConfig(n_slots=4, max_len=128, prefill_chunk=16,
                         paged=True, prequant=True, scheme=scheme,
                         prefix_cache=True)
    eng = ServeEngine(cfg, params, econf)
    _warm_and_reset(eng, prompts[0][:16], 2)
    if eng.cache is not None:  # drop warmup entries: a true cold wave
        eng.cache.evict(None, eng.cache.cached_blocks())
        for k in eng.cache.stats:
            eng.cache.stats[k] = 0
        for k in eng.stats:
            eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0
    rows = []
    for phase in ("cold", "hot"):
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=max_new))
        eng.run()
        st = eng.stats
        prefill_us = st["prefill_s"] * 1e6 / n_req
        rows.append((f"serve/decode_prefix_{phase}", prefill_us,
                     f"prefill_tokens={st['prefill_tokens']} "
                     f"skipped={st['prefill_skipped_tokens']} "
                     f"hits={st['prefix_hits']}"))
        detail[f"prefix_{phase}"] = {
            "prefill_us_per_req": round(prefill_us, 1),
            "prefill_tokens": st["prefill_tokens"],
            "skipped_tokens": st["prefill_skipped_tokens"],
            "prefix_hits": st["prefix_hits"],
            "cache": dict(eng.cache.stats) if eng.cache else None,
        }
        for k in eng.stats:  # hot wave measured from zero
            eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0
        if eng.cache is not None:  # per-phase hit rates, not cumulative
            for k in eng.cache.stats:
                eng.cache.stats[k] = 0
    return rows


def _zipf_tenant_workload(cfg, n_req, n_tenants, smoke, seed=23,
                          exponent=1.1):
    """Multi-tenant request mix: each tenant owns one shared system prompt;
    tenant popularity is Zipf(`exponent`) (a few hot tenants dominate, a
    long tail recurs rarely — the regime a hierarchical cache exists for).
    Deterministic: everything derives from `seed`."""
    rng = np.random.RandomState(seed)
    sys_len, suffix = (24, 4) if smoke else (32, 6)
    systems = [list(map(int, rng.randint(0, cfg.vocab, sys_len)))
               for _ in range(n_tenants)]
    w = np.arange(1, n_tenants + 1, dtype=np.float64) ** -exponent
    w /= w.sum()
    tenants = rng.choice(n_tenants, size=n_req, p=w)
    prompts = [systems[t]
               + list(map(int, rng.randint(0, cfg.vocab, suffix)))
               for t in tenants]
    return prompts, tenants.tolist()


def _reset_cache_cold(eng):
    """True cold start: free every cache-held device block, drop host-tier
    husks, zero cache + engine stats (warmup must not count as a hit)."""
    eng.cache.evict(None, eng.pool.n_blocks)
    eng.cache.root.children.clear()   # host-only husks would still match
    eng.cache.host_bytes = 0
    eng.cache.epoch += 1
    for k in eng.cache.stats:
        eng.cache.stats[k] = 0.0 if isinstance(eng.cache.stats[k],
                                               float) else 0
    for k in eng.stats:
        eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0


def _prefix_tiers_rows(cfg, params, scheme, smoke):
    """serve/prefix_zipf_{drop,spill}: the SAME Zipf multi-tenant closed-loop
    batch through a small pool (constant eviction pressure), cache-drop vs
    host-spill eviction. The comparison the spill tier exists for: with drop,
    an evicted tenant prefix re-prefills on recurrence; with spill it swaps
    back in from host RAM. Returns the two rows + the BENCH_serve.json
    `prefix_tiers` section (hit-rate, stall fraction, p50/p99)."""
    n_tenants = 6
    n_req = 12 if smoke else 24
    max_new = 4 if smoke else 6
    prompts, tenants = _zipf_tenant_workload(cfg, n_req, n_tenants, smoke)
    rows = []
    section = {"tenants": n_tenants, "requests": n_req,
               "zipf_exponent": 1.1, "hot_tenant_share":
               round(tenants.count(0) / n_req, 3), "modes": {}}
    for mode in ("drop", "spill"):
        econf = EngineConfig(
            # 2 slots x 64 positions / block 8 = a 16-block pool: two live
            # ~32-token requests pin ~8, leaving room for ~2 tenants' worth
            # of cached prefix — the other 4 keep getting evicted
            n_slots=2, max_len=64, prefill_chunk=16, block_size=8,
            paged=True, prequant=True, scheme=scheme, prefix_cache=True,
            prefix_spill=mode == "spill")
        eng = ServeEngine(cfg, params, econf)
        _warm_and_reset(eng, prompts[0][:16], 2)
        _reset_cache_cold(eng)
        t0 = time.perf_counter()
        for p in prompts:
            eng.submit(Request(prompt=p, max_new=max_new))
        results = eng.run()
        wall = time.perf_counter() - t0
        st, cs = eng.stats, dict(eng.cache.stats)
        lats = sorted(r.latency_s for r in results)
        p50 = lats[len(lats) // 2]
        p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
        hit_rate = cs["hits"] / max(cs["lookups"], 1)
        busy = st["prefill_s"] + st["decode_s"]
        stall = cs["swapin_s"] / max(busy, 1e-9)  # swap-ins are dispatched
        rows.append((f"serve/prefix_zipf_{mode}", 1e6 * wall / n_req,
                     f"hit_rate={hit_rate:.2f} "
                     f"skipped={st['prefill_skipped_tokens']} "
                     f"swap_stall={stall:.3f} p99_ms={p99 * 1e3:.1f}"))
        section["modes"][mode] = {
            "hit_rate": round(hit_rate, 4),
            "hits": cs["hits"], "lookups": cs["lookups"],
            "hit_tokens": cs["hit_tokens"],
            "skipped_tokens": st["prefill_skipped_tokens"],
            "evicted_blocks": cs["evicted_blocks"],
            "spilled_blocks": cs["spilled_blocks"],
            "swapped_in_blocks": cs["swapped_in_blocks"],
            "swap_in_stall_frac": round(stall, 5),
            "host_bytes_after": eng.cache.host_bytes,
            "p50_ms": round(p50 * 1e3, 2),
            "p99_ms": round(p99 * 1e3, 2),
        }
    d, s = section["modes"]["drop"], section["modes"]["spill"]
    # the acceptance claim the JSON regresses: spill strictly beats drop
    section["spill_hit_rate_gain"] = round(s["hit_rate"] - d["hit_rate"], 4)
    section["spill_beats_drop"] = s["hit_rate"] > d["hit_rate"]
    return rows, section


def _latency_policy_row(cfg, params, scheme, detail, smoke):
    """serve/latency_deadline: a saturated mixed-priority batch under
    LatencyPolicy — p50/p99 completion latency and the fraction of
    deadline-carrying requests that met their deadline."""
    from repro.serve.scheduler import LatencyPolicy
    n_req = 6 if smoke else 16
    max_new = 4 if smoke else 8
    prompts = _workload(cfg, n_req, prompt_len=16, seed=13)
    econf = EngineConfig(n_slots=2, max_len=64, prefill_chunk=16,
                         paged=True, prequant=True, scheme=scheme,
                         scheduler=LatencyPolicy(aging_ticks=8))
    eng = ServeEngine(cfg, params, econf)
    _warm_and_reset(eng, prompts[0], 2)
    for i, p in enumerate(prompts):
        # every 3rd request is latency-critical with a deadline
        crit = i % 3 == 0
        eng.submit(Request(prompt=p, max_new=max_new,
                           priority=5 if crit else 0,
                           deadline_s=2.0 if crit else None))
    results = eng.run()
    lats = sorted(r.latency_s for r in results)
    p50 = lats[len(lats) // 2]
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
    with_dl = [r for r in results if r.deadline_s is not None]
    met = sum(1 for r in with_dl if r.deadline_met) / max(len(with_dl), 1)
    detail["latency_deadline"] = {
        "p50_ms": round(p50 * 1e3, 2), "p99_ms": round(p99 * 1e3, 2),
        "deadline_met_frac": round(met, 3), "requests": n_req,
        "critical": len(with_dl), "policy": "LatencyPolicy(aging_ticks=8)",
    }
    return ("serve/latency_deadline", p50 * 1e6,
            f"p50_ms={p50*1e3:.1f} p99_ms={p99*1e3:.1f} "
            f"deadline_met={met:.2f} requests={n_req}")


def _obs_section(obs, st):
    """Observed (registry-backed) counters + trace latency aggregates for
    the instrumented engine row, cross-checked against the legacy stats
    surface — `counters_match` pins that the two views agree exactly."""
    label = obs.engine_label
    reg = obs.registry
    observed = {
        "decode_tokens": reg.value("serve_engine_decode_tokens_total",
                                   engine=label),
        "prefill_tokens": reg.value("serve_engine_prefill_tokens_total",
                                    engine=label),
        "finished": reg.value("serve_engine_finished_total", engine=label),
        "ticks": reg.value("serve_engine_ticks_total", engine=label),
    }
    agg = obs.trace_sink.aggregates()
    return {
        "counters": {k: int(v) for k, v in observed.items()},
        "counters_match": all(int(observed[k]) == st[k] for k in observed),
        "ttft_ms": _ms(agg["ttft_s"]),
        "queue_wait_ms": _ms(agg["queue_wait_s"]),
        "decode_tok_ms": _ms(agg["decode_tok_s"]),
        "retired_traces": agg["retired"],
    }


def _ms(p):
    return {k: (round(v * 1e3, 3) if k != "count" else v)
            for k, v in p.items()}


def _quant_health(smoke):
    """NVFP4 quantization-accuracy scoreboard (obs/quant_probe.py) over the
    llama_200m weight sites: MS-EDEN vs SR relative MSE plus scale-
    saturation/clip fractions per site — the paper's Table-1 comparison on
    real init weights, alongside the throughput rows."""
    from repro.obs.metrics import MetricsRegistry
    from repro.obs.quant_probe import QuantProbe
    cfg = registry.get("llama_200m").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    probe = QuantProbe(scheme="quartet2", max_sites=4 if smoke else 8,
                       registry=MetricsRegistry())
    sites = probe.probe_params(params, phase="prequant")
    out = {"config": "llama_200m(reduced)", "scheme": "quartet2",
           "sites": {}}
    for name, vals in sites.items():
        out["sites"][name] = {k: round(v, 6) for k, v in vals.items()}
    return out


def _kv_quant_section(smoke):
    """Cache-quantization scoreboard for BENCH_serve.json: storage bytes per
    element and the cache-rounding relative MSE of the three candidate
    rounding modes on pool-shaped N(0,1) bf16 blocks (table1_mse.py style).
    The shipped cache codec is deterministic RTN (block immutability +
    hot == cold need a value-pure encoding); MS-EDEN's rotated encoding
    would need the inverse rotation inside the decode kernel, and plain SR
    measures ~2.2x WORSE than RTN here (variance without an accumulation
    loop to average over) — the scoreboard keeps all three honest across
    PRs. tests/test_kv_quant.py pins the ordering ms_eden < rtn < sr."""
    from repro.core import formats as F
    from repro.core import ms_eden as ME
    from repro.core import quant as Q
    rng = np.random.RandomState(21)
    n = (10 if smoke else 40) * 16
    x = jnp.asarray(rng.randn(n, 128), jnp.bfloat16)
    xf = np.asarray(x, np.float64)

    def rel(d):
        df = np.asarray(d, np.float64)
        return float(np.mean((xf - df) ** 2) / np.mean(xf ** 2))

    rtn = rel(F.nvfp4_cache_decode(*F.nvfp4_cache_encode(x),
                                   dtype=jnp.float32))
    sr = rel(Q.dequant(Q.quant_sr(x, jax.random.PRNGKey(1))))
    keys = jax.random.split(jax.random.PRNGKey(2))
    eden = rel(ME.ms_eden_dequant(ME.ms_eden(x, keys[0], keys[1]),
                                  rotated=False))
    return {
        "bytes_per_element": {"bf16": 2.0,
                              "nvfp4_cache": _KVQ_BYTES_PER_ELT},
        "bytes_ratio": _KVQ_BYTES_PER_ELT / 2.0,
        "cache_rounding_rel_mse": {"rtn_codec": round(rtn, 6),
                                   "sr": round(sr, 6),
                                   "ms_eden": round(eden, 6)},
        "shipped_mode": "rtn_codec",
        "block_shape": [n, 128],
    }


def _frontend_section(cfg, params, scheme, smoke):
    """serve/frontend_stream: the asyncio HTTP frontend end-to-end — real
    sockets, SSE framing, the engine on its bridge thread. N concurrent
    streaming clients, one killed mid-stream (disconnect -> cancel ->
    reclaim). The row prices the full frontend stack in streamed tok/s;
    the detail keeps the lifecycle accounting (cancelled, reclaimed
    blocks, SSE events) BENCH_serve.json regresses across PRs."""
    import asyncio
    import json as _json

    from repro.serve.frontend import CompletionFrontend, EngineBridge, \
        FrontendConfig
    n_clients = 3 if smoke else 4
    prompt_len, max_new = (12, 6) if smoke else (16, 16)
    prompts = _workload(cfg, n_clients, prompt_len=prompt_len, seed=17)
    eng = ServeEngine(cfg, params, EngineConfig(
        n_slots=n_clients, max_len=64, prefill_chunk=16, paged=True,
        prequant=True, scheme=scheme, prefix_cache=True))
    _warm_and_reset(eng, prompts[0][:8], 2)

    async def client(port, prompt, kill_after=None):
        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        body = _json.dumps({"prompt": prompt, "max_tokens": max_new,
                            "stream": True}).encode()
        writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: b\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        await reader.readline()  # status
        t0 = time.perf_counter()
        toks, events, ttfb = [], 0, None
        while True:
            line = await reader.readline()
            if not line:
                break
            if not line.startswith(b"data: "):
                continue
            if line[6:].strip() == b"[DONE]":
                break
            if ttfb is None:
                ttfb = time.perf_counter() - t0
            events += 1
            toks.extend(_json.loads(line[6:])["choices"][0]["tokens"])
            if kill_after is not None and len(toks) >= kill_after:
                writer.transport.abort()
                return toks, events, ttfb
        writer.close()
        return toks, events, ttfb

    async def drive(port, bridge):
        t0 = time.perf_counter()
        res = await asyncio.gather(
            *[client(port, p) for p in prompts[:-1]],
            client(port, prompts[-1], kill_after=2))
        wall = time.perf_counter() - t0
        for _ in range(200):  # wait out the disconnect watcher's cancel
            snap = await asyncio.wrap_future(bridge.snapshot())
            if snap["stats"]["cancelled"] >= 1:
                break
            await asyncio.sleep(0.01)
        return res, wall, snap

    bridge = EngineBridge(eng)
    fe = CompletionFrontend(bridge, FrontendConfig())

    async def main():
        await fe.start()
        try:
            return await drive(fe.port, bridge)
        finally:
            await fe.stop()

    with bridge:
        res, wall, snap = asyncio.run(main())
    streamed = sum(len(t) for t, _, _ in res)
    tps = streamed / max(wall, 1e-9)
    ttfbs = sorted(t for _, _, t in res if t is not None)
    detail = {
        "clients": n_clients,
        "streamed_tokens": streamed,
        "sse_events": sum(e for _, e, _ in res),
        "tok_s_streamed": round(tps, 2),
        "ttfb_ms_p50": round(ttfbs[len(ttfbs) // 2] * 1e3, 2),
        "disconnects": 1,
        "cancelled": snap["stats"]["cancelled"],
        "pool_free_blocks_after": snap["pool_free_blocks"],
        "pool_total_blocks": snap["pool_total_blocks"],
        "live_handles_after": snap["live_handles"],
        "retry_after_s": snap["retry_after_s"],
    }
    row = ("serve/frontend_stream", 1e6 / max(tps, 1e-9),
           f"tok_s={tps:.1f} clients={n_clients} "
           f"cancelled={snap['stats']['cancelled']} "
           f"ttfb_p50_ms={detail['ttfb_ms_p50']}")
    return row, detail


def _emit_bench_json(decode_paths, rows, smoke, observability=None,
                     quant_health=None, kv_quant=None, frontend=None,
                     prefix_tiers=None):
    """BENCH_serve.json at the repo root: the serving bench trajectory
    artifact future PRs regress against."""
    payload = {
        "bench": "serve_throughput",
        "smoke": bool(smoke),
        "backend": jax.default_backend(),
        "decode_paths": decode_paths,
        "rows": [{"name": n, "us_per_call": round(us, 1), "derived": d}
                 for n, us, d in rows],
    }
    if observability is not None:
        payload["observability"] = observability
    if quant_health is not None:
        payload["quant_health"] = quant_health
    if kv_quant is not None:
        payload["kv_quant"] = kv_quant
    if frontend is not None:
        payload["frontend"] = frontend
    if prefix_tiers is not None:
        payload["prefix_tiers"] = prefix_tiers
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "BENCH_serve.json")
    with open(os.path.normpath(path), "w") as f:
        json.dump(payload, f, indent=1)


def _spec_model(cfg, params):
    """Shape random-init params like a trained model for the spec rows:
    damp every residual output projection and tie the head to the embedding
    (self-similar -> confident logits), so draft/full agreement — and thus
    the reported acceptance rate — is in the regime speculation targets."""
    import jax.tree_util as tu

    def damp(path, x):
        key = getattr(path[-1], "key", None)
        return x * 0.05 if key == "wo" else x

    shaped = dict(params)
    shaped["stages"] = [tu.tree_map_with_path(damp, st)
                        for st in params["stages"]]
    shaped["head"] = params["embed"]
    return shaped


def _spec_engine_toks(cfg, params, prompts, max_new, scheme, spec_k,
                      draft_layers):
    """Decode tok/s + acceptance for one engine config, COMPILE-EXCLUDED:
    a short warm request triggers every step shape (prefill chunk, decode,
    draft propose, verify), then stats reset before the measured batch."""
    econf = EngineConfig(n_slots=len(prompts), max_len=128, prefill_chunk=16,
                         paged=True, prequant=True, scheme=scheme,
                         spec_k=spec_k, draft_layers=draft_layers)
    eng = ServeEngine(cfg, params, econf)
    # a full prefill_chunk-sized warm prompt hits the chunked prefill shape
    # (shorter prompts take the token-by-token path instead), and max_new
    # spans TWO spec rounds so the draft catch-up step — which a first round
    # never needs — also compiles before measurement
    _warm_and_reset(eng, prompts[0], max(2 * (spec_k + 1), 3))
    for p in prompts:
        eng.submit(Request(prompt=p, max_new=max_new))
    eng.run()
    st = eng.stats
    tps = st["decode_tokens"] / max(st["decode_s"], 1e-9)
    acc = st["accepted_tokens"] / max(st["draft_tokens"], 1)
    return tps, acc, st


def run(quick: bool = True):
    smoke = getattr(common, "SMOKE", False)
    cfg = (common.smoke_bench_cfg() if smoke
           else bench_cfg(d_model=256, n_layers=2, vocab=512, d_ff=512))
    params = lm.init(cfg, jax.random.PRNGKey(0))
    scheme = "quartet2"
    batch = 4
    max_new = 8 if smoke else (24 if quick else 64)
    prompts = _workload(cfg, batch, prompt_len=16)

    rows = []
    seed_tps, _ = _seed_loop_toks(cfg, params, prompts, max_new, scheme)
    rows.append(("serve/seed_loop", 1e6 / seed_tps,
                 f"tok_s={seed_tps:.1f} batch={batch}"))

    if not smoke:  # isolates scheduler overhead; skipped on the CI path
        rq_tps, _ = _engine_toks(cfg, params, prompts, max_new, scheme,
                                 prequant=False)
        rows.append(("serve/engine_requant", 1e6 / rq_tps,
                     f"tok_s={rq_tps:.1f} batch={batch}"))

    # instrumented run: the prequant row doubles as the observability
    # smoke — BENCH carries the OBSERVED registry counters (cross-checked
    # against legacy stats) and the per-request TTFT/queue-wait aggregates
    from repro.obs import Instrumentation, MetricsRegistry
    obs = Instrumentation(registry=MetricsRegistry())
    pq_tps, pq_st = _engine_toks(cfg, params, prompts, max_new, scheme,
                                 prequant=True, obs=obs)
    observability = _obs_section(obs, pq_st)
    rows.append(("serve/engine_prequant", 1e6 / pq_tps,
                 f"tok_s={pq_tps:.1f} batch={batch} "
                 f"speedup_vs_seed={pq_tps / seed_tps:.2f}x"))

    # --- decode data-path comparison (dense / gather-view / Pallas kernel);
    # runs under --smoke too, so CI exercises the kernel wrapper. max_new is
    # capped so prompt+new stays well under the 64-position pool: the
    # capacity/actual-length GAP is the thing the bytes model measures ------
    dp_new = 4 if smoke else min(max_new, 24)
    dp_rows, dp_detail = _decode_path_rows(cfg, params, prompts, dp_new,
                                           scheme)
    rows.extend(dp_rows)

    # --- prefix cache (cold vs hot wave) + latency-aware scheduling; both
    # run under --smoke so CI exercises the radix cache and LatencyPolicy --
    rows.extend(_prefix_cache_rows(cfg, params, scheme, dp_detail, smoke))
    rows.append(_latency_policy_row(cfg, params, scheme, dp_detail, smoke))

    # --- hierarchical cache tiers: Zipf multi-tenant drop-vs-spill; runs
    # under --smoke so CI regresses the spill-beats-drop hit-rate claim ----
    zipf_rows, prefix_tiers = _prefix_tiers_rows(cfg, params, scheme, smoke)
    rows.extend(zipf_rows)

    # --- streaming HTTP frontend (bridge thread + SSE over localhost);
    # runs under --smoke so CI exercises the full stack ---------------------
    fe_row, fe_detail = _frontend_section(cfg, params, scheme, smoke)
    rows.append(fe_row)

    # --- self-speculative decoding (needs >= 2 layers for a prefix draft) ---
    spec_cfg = (bench_cfg(d_model=128, n_layers=2, vocab=256, d_ff=256)
                if smoke else cfg)
    spec_params = _spec_model(
        spec_cfg, params if spec_cfg is cfg
        else lm.init(spec_cfg, jax.random.PRNGKey(0)))
    spec_prompts = _workload(spec_cfg, batch, prompt_len=16)
    spec_new = 30 if smoke else (35 if quick else 65)
    base_tps, _, _ = _spec_engine_toks(spec_cfg, spec_params, spec_prompts,
                                       spec_new, scheme, 0, 0)
    rows.append(("serve/engine_spec_base", 1e6 / base_tps,
                 f"tok_s={base_tps:.1f} batch={batch}"))
    sp_tps, acc, _ = _spec_engine_toks(spec_cfg, spec_params, spec_prompts,
                                       spec_new, scheme, 4, 1)
    rows.append(("serve/engine_spec", 1e6 / sp_tps,
                 f"tok_s={sp_tps:.1f} accept_rate={acc:.2f} spec_k=4 "
                 f"draft_layers=1 speedup_vs_base={sp_tps / base_tps:.2f}x"))

    if not smoke:
        n_req = 8 if quick else 32
        rng = np.random.RandomState(7)
        # Poisson arrivals: mean inter-arrival tuned to keep the pipe busy
        arrivals = np.cumsum(rng.exponential(0.05, n_req)).tolist()
        po_prompts = _workload(cfg, n_req, prompt_len=16, seed=7)
        po_tps, st = _engine_toks(cfg, params, po_prompts, max_new, scheme,
                                  prequant=True, arrivals=arrivals)
        rows.append(("serve/engine_poisson", 1e6 / max(po_tps, 1e-9),
                     f"tok_s={po_tps:.1f} requests={n_req} "
                     f"slots=4 finished={st['finished']}"))
    _emit_bench_json(dp_detail, rows, smoke, observability=observability,
                     quant_health=_quant_health(smoke),
                     kv_quant=_kv_quant_section(smoke),
                     frontend=fe_detail, prefix_tiers=prefix_tiers)
    return rows
