"""Paper Table 1: quadratic error over N(0,1) per NVFP4 rounding scheme.

Paper values (MSE x 1e-3): RTN 1x16 9.0 | +4/6 7.6 | RTN 16x16 12.4 |
4/6 16x16 12.4 | SR 1x16 23.5 | SR+4/6 17.5 | MS-EDEN 9.4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit
from repro.core import ms_eden as ME
from repro.core import mxfp4 as MX
from repro.core import quant as Q
from repro.core import rht as R
from repro.core.linear import quant_sr_fos

PAPER = {"rtn_1x16": 9.0, "rtn_4over6": 7.6, "rtn_16x16": 12.4,
         "sr_1x16": 23.5, "sr_4over6": 17.5, "ms_eden": 9.4}


def run(quick: bool = True):
    from benchmarks import common
    n = (1024, 1024) if quick else (4096, 4096)
    if common.SMOKE:  # SR quantizers dominate (searchsorted): shrink hard
        n = (256, 512)
    x = jax.random.normal(jax.random.PRNGKey(0), n, jnp.float32)
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)

    def eden_mse(x):
        o = ME.ms_eden(x, k1, k2)
        d = ME.ms_eden_dequant(o, rotated=False) - x
        return jnp.mean(d * d)

    cases = {
        "rtn_1x16": lambda: Q.mse(x, Q.quant_rtn(x, s=Q.S_EDEN)),
        "rtn_4over6": lambda: Q.mse(x, Q.quant_four_over_six(x)),
        "rtn_16x16": lambda: Q.mse(x, Q.quant_square_block(x)),
        "sr_1x16": lambda: Q.mse(x, Q.quant_sr(x, k1)),
        "sr_4over6": lambda: Q.mse(x, quant_sr_fos(x, k1)),
        "ms_eden": lambda: eden_mse(x),
        # MXFP4 (OCP) comparison — the paper's Sec. 3.1 claim that NVFP4's
        # finer 16-groups + FP8 scales beat MXFP4's 32-group 2^k scales:
        "mxfp4_rtn": lambda: Q.mse(x, MX.quant_mxfp4(x)),
        "mxfp4_sr": lambda: Q.mse(x, MX.quant_mxfp4_sr(x, k2)),
    }
    rows = []
    for name, fn in cases.items():
        f = jax.jit(fn)
        mse = float(f()) * 1e3
        us = timeit(f, iters=3, warmup=1)
        paper = PAPER.get(name, float("nan"))
        rows.append((f"table1/{name}", us,
                     f"mse_e-3={mse:.2f} paper={paper} "
                     f"match={'Y' if abs(mse - paper) / paper < 0.15 else 'n'}"))
    return rows
