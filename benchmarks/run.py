"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,fig9]

Prints `name,us_per_call,derived` CSV (harness contract)."""

from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = ["table1_mse", "fig9_unbiasedness", "table2_bandwidth",
           "kernel_overhead", "fig2_forward_ablation",
           "fig1_backward_ablation", "fig4_full_quant", "nanochat_style",
           "serve_throughput"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-closer sizes/steps (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="<30s-per-module CPU path (CI): forces quick sizes "
                         "and trims training steps via benchmarks.common.SMOKE")
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    args = ap.parse_args()
    if args.smoke:
        from benchmarks import common
        common.SMOKE = True
        args.full = False
    mods = MODULES
    if args.only:
        keys = args.only.split(",")
        mods = [m for m in MODULES if any(m.startswith(k) for k in keys)]
    print("name,us_per_call,derived")
    ok = True
    for name in mods:
        t0 = time.time()
        try:
            # free compiled executables between modules: XLA-CPU's JIT dylib
            # table is finite and the training benches compile many programs
            import jax
            jax.clear_caches()
            mod = importlib.import_module(f"benchmarks.{name}")
            for row, us, derived in mod.run(quick=not args.full):
                print(f"{row},{us:.1f},{derived}")
        except Exception:
            ok = False
            traceback.print_exc()
            print(f"{name},nan,FAILED")
        print(f"# {name} took {time.time() - t0:.1f}s", file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
