"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,fig9]

Prints `name,us_per_call,derived` CSV (harness contract)."""

from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
import traceback


MODULES = ["table1_mse", "fig9_unbiasedness", "table2_bandwidth",
           "kernel_overhead", "fig2_forward_ablation",
           "fig1_backward_ablation", "fig4_full_quant", "nanochat_style",
           "serve_throughput"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-closer sizes/steps (slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="<30s-per-module CPU path (CI): forces quick sizes "
                         "and trims training steps via benchmarks.common.SMOKE")
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    args = ap.parse_args()
    mods = MODULES
    if args.only:
        keys = args.only.split(",")
        mods = [m for m in MODULES if any(m.startswith(k) for k in keys)]
    if mods == ["serve_throughput"]:
        # two simulated host-platform devices for the serve/decode_sharded
        # row — ONLY for an explicitly serve-only run (`--only serve`): the
        # device count is process-wide and must precede the first jax
        # import, so forcing it in a mixed run would silently change the
        # measurement environment of every other bench. Mixed/default runs
        # keep the pristine single-device environment and the sharded row
        # degrades to data_shards=1 (recorded in its derived column /
        # BENCH_serve.json, so the artifact stays self-describing).
        # setdefault keeps explicit operator XLA_FLAGS intact.
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=2")
    if args.smoke:
        from benchmarks import common
        common.SMOKE = True
        args.full = False
    print("name,us_per_call,derived")
    ok = True
    for name in mods:
        t0 = time.time()
        try:
            # free compiled executables between modules: XLA-CPU's JIT dylib
            # table is finite and the training benches compile many programs
            import jax
            jax.clear_caches()
            mod = importlib.import_module(f"benchmarks.{name}")
            for row, us, derived in mod.run(quick=not args.full):
                print(f"{row},{us:.1f},{derived}")
        except Exception:
            ok = False
            traceback.print_exc()
            print(f"{name},nan,FAILED")
        print(f"# {name} took {time.time() - t0:.1f}s", file=sys.stderr)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
