"""Paper Fig. 9 (App. A): concentration of the B-averaged quantized gradient
toward the exact gradient. Unbiased estimators decay ~1/B; the 4/6 backward
(NVIDIA+4/6) plateaus at its bias floor."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.linear import qlinear

BATCHES = (4, 16, 64, 256)


def run(quick: bool = True):
    from benchmarks import common
    batches = BATCHES
    m, k, n = (64, 128, 128) if quick else (256, 512, 512)
    if common.SMOKE:  # drop the B=256 vmap (dominates wall time)
        batches = BATCHES[:-1]
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    # heavy-tailed weights/cotangents make the 4/6 branch bias visible
    w = (jax.random.normal(jax.random.PRNGKey(1), (n, k)) ** 3) / (3 * np.sqrt(k))
    ct = jax.random.normal(jax.random.PRNGKey(2), (m, n)) ** 3

    def gradw(seed, scheme):
        return jax.grad(lambda w: jnp.sum(qlinear(x, w, seed, scheme) * ct))(w)

    ref = gradw(jnp.array([0, 0], jnp.uint32), "bf16")
    rows = []
    for scheme in ("abl_e_ms_eden", "abl_e_sr", "abl_e_sr_fos"):
        f = jax.jit(jax.vmap(lambda s: gradw(s, scheme)))
        errs = []
        for b in batches:
            seeds = jnp.stack([jnp.full((b,), 17, jnp.uint32),
                               jnp.arange(b, dtype=jnp.uint32)], -1)
            g = jnp.mean(f(seeds), 0)
            errs.append(float(jnp.sum((g - ref) ** 2) / jnp.sum(ref ** 2)))
        # slope of log(err) vs log(B): -1.0 = unbiased; > -0.5 = bias floor
        slope = np.polyfit(np.log(batches), np.log(errs), 1)[0]
        rows.append((f"fig9/{scheme}", 0.0,
                     "err@" + ",".join(f"B{b}={e:.2e}" for b, e in zip(batches, errs))
                     + f" slope={slope:.2f}"))
    return rows
