"""Shared benchmark harness utilities."""

from __future__ import annotations

import time

# Set by `benchmarks.run --smoke`: every module must finish < 30s on CPU.
# Modules consult `smoke_steps` / SMOKE to trim training-loop lengths.
SMOKE = False


def smoke_steps(n: int, floor: int = 20) -> int:
    """Trim a training-step count for the --smoke CI path."""
    return max(floor, n // 6) if SMOKE else n


def smoke_bench_cfg():
    """The --smoke bench model: one layer, tiny vocab — jit compile time is
    the CPU bottleneck, and one layer still exercises every scheme path."""
    return bench_cfg(d_model=128, n_layers=1, vocab=256, d_ff=256)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import lm
from repro.train.train_step import make_train_step


def timeit(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (CPU; relative numbers)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def bench_cfg(d_model=128, n_layers=2, vocab=512, d_ff=384) -> ArchConfig:
    """The paper's Llama-2-like ablation family at CPU scale."""
    import dataclasses
    base = registry.get("llama_200m")
    return dataclasses.replace(
        base, name="llama-bench", n_layers=n_layers, d_model=d_model,
        n_heads=4, n_kv_heads=4, d_ff=d_ff, vocab=vocab, head_dim=32)


def train_curve(scheme: str, *, steps: int, cfg=None, seq=64, batch=8,
                lr=2e-3, seed=0, eval_every=0):
    """Train the bench model under `scheme`; return final eval loss over a
    held-out split (deterministic across schemes: same data, same init)."""
    if cfg is None and SMOKE:
        cfg = smoke_bench_cfg()
        seq, batch = 32, 4
    cfg = cfg or bench_cfg()
    corpus = SyntheticCorpus(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                        global_batch=batch, seed=seed))
    init_state, train_step = make_train_step(
        cfg, scheme, base_lr=lr, total_steps=steps, base_seed=seed,
        weight_decay=0.1)
    step_j = jax.jit(train_step)
    state = init_state(lm.init(cfg, jax.random.PRNGKey(seed)))
    for i in range(steps):
        state, m = step_j(state, corpus.batch_at(i))
    # held-out eval: batches the training never saw (step offset 10^6)
    eval_losses = []
    eseed = jnp.array([9, 9], jnp.uint32)
    for j in range(4):
        b = corpus.batch_at(1_000_000 + j)
        eval_losses.append(float(lm.lm_loss(state.params, cfg, b, scheme, eseed)))
    return float(np.mean(eval_losses))
