"""Paper Table 2: GMEM traffic per element for the naive vs post hoc range
alignment MS-EDEN re-quantization kernels — analytic on TPU numbers, plus
measured byte movement of our two-phase Pallas kernel structure.

Naive (two passes over the tensor): load bf16 + rotate, reduce absmax;
reload + rotate again, quantize = (16 + 16) bits in, 4.5 out, 2 rotations.
Post hoc (ours / paper Fig. 8): one pass loads 16 bits, writes ER codes +
pseudo-scales (~5 bits); phase 2 touches scales only (1/16 of elements)."""

from __future__ import annotations

from repro.core import formats as F


def run(quick: bool = True):
    g = F.GROUP
    naive_in = 16 + 16            # two full loads (bf16)
    naive_out = 4 + 8 / g + 4.5   # codes+scales after the 2nd pass (+spill)
    posthoc_in = 16 + (8 + 32) / g          # one load + phase-2 scales+stats
    posthoc_out = 4 + 16 / g + (8 + 64) / g + 8 / g
    rows = [
        ("table2/naive_bits_per_elem", 0.0,
         f"in={naive_in:.2f} out={naive_out:.2f} rotations=2 (paper: 4.5+4.5 / 0+4.5, 2 mma)"),
        ("table2/posthoc_bits_per_elem", 0.0,
         f"in={posthoc_in:.2f} out={posthoc_out:.2f} rotations=1 (paper: 4.5+1 / 5+0.5, 1 mma)"),
        ("table2/phase2_fraction_of_elements", 0.0, f"1/{g} = {1 / g:.4f}"),
        ("table2/bandwidth_saving", 0.0,
         f"{1 - (posthoc_in + posthoc_out) / (naive_in + naive_out):.1%} (paper: ~20%)"),
    ]
    return rows
