"""Paper Fig. 1: C4-val-loss impact of selectively quantizing the backward
GEMMs, at CPU scale (same Llama-like family, synthetic corpus, identical
init/data across schemes). Expected ordering (paper Sec. 6.1): MS-EDEN (e)
with weight re-quantization beats SR (d) without it; every MS-EDEN variant
beats the matching SR variant."""

from __future__ import annotations

from benchmarks.common import train_curve

SCHEMES = ["bf16", "abl_a_sr", "abl_a_ms_eden", "abl_b_sr", "abl_c_sr",
           "abl_c_ms_eden", "abl_d_sr", "abl_e_sr", "abl_e_ms_eden"]


def run(quick: bool = True):
    from benchmarks import common
    from benchmarks.common import smoke_steps
    steps = smoke_steps(120 if quick else 600)
    # --smoke: headline comparison only (compiles dominate CPU wall time)
    schemes = ["bf16", "abl_e_ms_eden"] if common.SMOKE else SCHEMES
    rows = []
    base = None
    for scheme in schemes:
        loss = train_curve(scheme, steps=steps)
        if scheme == "bf16":
            base = loss
        gap = loss - base
        rows.append((f"fig1/{scheme}", 0.0,
                     f"val_loss={loss:.4f} gap_vs_bf16={gap:+.4f}"))
    return rows
