"""Griffin / RecurrentGemma building blocks: RG-LRU recurrent block with a
short depthwise temporal conv, plus sliding-window local attention, in the
1-attention-per-2-recurrent layer pattern.

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    log a_t = -c * softplus(L) . r_t      (c = 8)
    h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . x_t)

Full sequences evaluate via jax.lax.associative_scan (log-depth, O(S) work,
sub-quadratic — this is why recurrentgemma runs the long_500k cell); decode
is a single elementwise step. Projections are quantized linears; the
recurrence stays fp32 elementwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import qlinear
from repro.models.blocks import linear_init, site_seed

LRU_C = 8.0


def rglru_init(key, cfg):
    g = cfg.griffin
    w = g.lru_width or cfg.d_model
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "w_in": linear_init(ks[0], w, d),      # x branch
        "w_gate": linear_init(ks[1], w, d),    # gelu gate branch
        "w_out": linear_init(ks[2], d, w),
        "conv_w": jax.random.normal(ks[3], (g.conv_width, w), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "wa": linear_init(ks[4], w, w, scale=0.01),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": linear_init(ks[5], w, w, scale=0.01),
        "bx": jnp.zeros((w,), jnp.float32),
        # Lambda init so a^c ~ U[0.9, 0.999] (per the Griffin paper)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / LRU_C)).astype(jnp.float32),
    }


def _conv1d(x, w, b, tail=None):
    """Causal depthwise temporal conv, width K. x: (B,S,W); tail: (B,K-1,W)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k))
    return out + b.astype(x.dtype), xp[:, -(k - 1):]


def _rglru_gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wa"].T.astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid(uf @ p["wx"].T.astype(jnp.float32) + p["bx"])
    log_a = -LRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12, 1.0))
    return a, beta * i * uf


def rglru_scan(p, u, h0=None):
    """Full-sequence RG-LRU via associative scan. u: (B,S,W) -> (B,S,W)."""
    a, b = _rglru_gates(p, u)
    if h0 is not None:
        # fold the carried state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)

    def comb(x, y):
        return (x[0] * y[0], x[1] * y[0] + y[1])

    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(u.dtype)


def rglru_step(p, u1, h):
    """One decode step. u1: (B,1,W); h: (B,W)."""
    a, b = _rglru_gates(p, u1)
    h = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h[:, None].astype(u1.dtype), h


def recurrent_block_apply(p, x, cfg, scheme, seed, layer, *, state=None):
    """Griffin recurrent block. state = (h, conv_tail) or None (train)."""
    b, s, _ = x.shape
    u = qlinear(x, p["w_in"], site_seed(seed, layer, 0), scheme)
    gate = qlinear(x, p["w_gate"], site_seed(seed, layer, 1), scheme)
    h0, tail = state if state is not None else (None, None)
    u, tail = _conv1d(u, p["conv_w"], p["conv_b"], tail)
    if s == 1 and h0 is not None:
        hseq, h = rglru_step(p, u, h0)
    else:
        hseq = rglru_scan(p, u, h0)
        h = hseq[:, -1].astype(jnp.float32)
    y = hseq * jax.nn.gelu(gate.astype(jnp.float32)).astype(hseq.dtype)
    out = qlinear(y, p["w_out"], site_seed(seed, layer, 2), scheme)
    return out, (h, tail)


def recurrent_state_init(cfg, batch: int):
    g = cfg.griffin
    w = g.lru_width or cfg.d_model
    return (jnp.zeros((batch, w), jnp.float32),
            jnp.zeros((batch, g.conv_width - 1, w), jnp.bfloat16))
