"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill use the expanded form; decode uses the weight-absorbed form over
the compressed latent cache (kv_lora_rank + rope dims per position — the whole
point of MLA: the KV cache is ~576 floats/token instead of 2*H*hd).

All projections are quantized linears; the absorbed decode einsums are bf16
(inference path, not part of the paper's training recipe).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import qlinear
from repro.models.attention import NEG_INF, apply_rope, attend, rope_tables
from repro.models.blocks import linear_init, rmsnorm, site_seed


def mla_init(key, cfg):
    m = cfg.mla
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": linear_init(ks[0], m.q_lora_rank, cfg.d_model),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": linear_init(ks[1], h * qk_dim, m.q_lora_rank),
        "wkv_a": linear_init(ks[2], m.kv_lora_rank + m.qk_rope_head_dim, cfg.d_model),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wkv_b": linear_init(ks[3], h * (m.qk_nope_head_dim + m.v_head_dim), m.kv_lora_rank),
        "wo": linear_init(ks[4], cfg.d_model, h * m.v_head_dim),
    }


def _latent(p, x, cfg, scheme, seed, layer, positions):
    """Shared projections: per-head q (nope+rope), latent c, rotated k_rope."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = qlinear(rmsnorm(qlinear(x, p["wq_a"], site_seed(seed, layer, 0), scheme),
                        p["q_norm"], cfg.norm_eps),
                p["wq_b"], site_seed(seed, layer, 1), scheme).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    kv = qlinear(x, p["wkv_a"], site_seed(seed, layer, 2), scheme)
    c = rmsnorm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]  # (B,S,1,rope)
    cos, sin = rope_tables(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, c, k_rope


def mla_apply(p, x, cfg, scheme, seed, layer, *, positions=None):
    """Expanded-form MLA (train / prefill). Returns (out, (c, k_rope)) for the
    latent decode cache."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c, k_rope = _latent(p, x, cfg, scheme, seed, layer, positions)
    kvb = qlinear(c, p["wkv_b"], site_seed(seed, layer, 3), scheme)
    kvb = kvb.reshape(b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kvb[..., : m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]
    # fold rope part into the head dim so standard SDPA applies
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_rope.shape[:2], h, k_rope.shape[-1]))], axis=-1)
    o = attend(q_full, k_full, v, causal=True)
    out = qlinear(o.reshape(b, s, -1), p["wo"], site_seed(seed, layer, 4), scheme)
    return out, (c, k_rope[:, :, 0, :])


def mla_decode(p, x, cfg, scheme, seed, layer, cache, pos, *, active=None,
               block_table=None, paged_kernel=False):
    """Absorbed-form decode over the latent cache. x: (B, Sq, D), Sq >= 1
    (Sq > 1 = chunked prefill).

    cache = (c: (B,Smax,kv_lora), kr: (B,Smax,rope)) — or pool-shaped
    (P,BS,dim) leaves addressed through `block_table` (serve/kv_pool.py);
    with `paged_kernel` the score/readout runs in the block-table
    flash-decode Pallas kernel instead of over gather_view copies.
    pos: scalar or (B,) first-token position; active: (B,) write gate.
    score_h(t) = q_nope_h^T Wuk_h c_t + q_rope_h^T kr_t   (Wuk absorbed into q)
    out_h = (sum_t p_t c_t)^T Wuv_h                        (Wuv absorbed after)

    NOTE: wkv_b participates as a RAW bf16/f32 matrix here (absorbed einsums
    are not quantized GEMMs), so the quantize-once weight cache leaves it
    unpacked (see serve/prequant.py) and the serving sharding rules keep it
    replicated (dist/sharding.py).

    Contract: row-local like gqa_decode — the sharded engine splits batch
    and latent pools over a shard_map "data" axis (shard-local table
    indices), which must not change a bit (docs/CONVENTIONS.md §3).
    """
    m = cfg.mla
    b, sq = x.shape[:2]
    h = cfg.n_heads
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = posb[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
    q_nope, q_rope, c_new, kr_new = _latent(p, x, cfg, scheme, seed, layer, positions)
    cc, kc = cache
    kr2 = kr_new[:, :, 0, :]
    valid = positions >= 0
    if active is not None:
        valid &= active[:, None]

    wkv_b = p["wkv_b"].reshape(h, m.qk_nope_head_dim + m.v_head_dim, m.kv_lora_rank)
    w_uk = wkv_b[:, : m.qk_nope_head_dim, :]     # (H, nope, lora)
    w_uv = wkv_b[:, m.qk_nope_head_dim:, :]      # (H, v, lora)
    q_abs = jnp.einsum("bqhn,hnl->bqhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))              # (B,Sq,H,lora)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim

    if block_table is not None:
        from repro.serve import kv_pool as KV
        # read table for gathers/kernel, write view (aliased prefix entries
        # -> sentinel) for scatters — see gqa_decode / CONVENTIONS.md §5
        rt, wt = KV.split_tables(block_table)
        cc = KV.scatter_tokens(cc, wt, positions, c_new, valid)
        kc = KV.scatter_tokens(kc, wt, positions, kr2, valid)
        if paged_kernel:
            from repro.kernels import ops as KOPS
            if isinstance(cc, KV.PackedKV):
                # NVFP4 latent pools: kernel dequantizes in VMEM
                o_lat = KOPS.paged_mla_attention_q(
                    q_abs, q_rope, cc.codes, cc.scales, kc.codes, kc.scales,
                    rt, posb, qk_dim=qk_dim)
            else:
                o_lat = KOPS.paged_mla_attention(q_abs, q_rope, cc, kc,
                                                 rt, posb, qk_dim=qk_dim)
            cv = None
        else:
            cv = KV.gather_view(cc, rt)
            kv = KV.gather_view(kc, rt)
    else:
        idx = jnp.where(valid, positions, cc.shape[1])  # OOB => write dropped
        bi = jnp.arange(b)[:, None]
        cc = cc.at[bi, idx].set(c_new.astype(cc.dtype), mode="drop")
        kc = kc.at[bi, idx].set(kr2.astype(kc.dtype), mode="drop")
        cv, kv = cc, kc

    if cv is not None:  # gathered-view / dense reference arithmetic
        s_lat = jnp.einsum("bqhl,btl->bhqt", q_abs, cv.astype(jnp.float32))
        s_rope = jnp.einsum("bqhr,btr->bhqt", q_rope.astype(jnp.float32),
                            kv.astype(jnp.float32))
        scale = 1.0 / jnp.sqrt(qk_dim)
        s = (s_lat + s_rope) * scale
        tmask = (jnp.arange(cv.shape[1], dtype=jnp.int32)[None, None, :]
                 <= positions[:, :, None])                    # (B,Sq,T)
        s = jnp.where(tmask[:, None], s, NEG_INF)
        prob = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhqt,btl->bqhl", prob, cv.astype(jnp.float32))
    o = jnp.einsum("bqhl,hvl->bqhv", o_lat, w_uv.astype(jnp.float32))
    if active is not None:
        # see gqa_decode: inactive rows must not read (layout-dependent)
        # stale cache memory — zero their attention output
        o = o * active[:, None, None, None].astype(o.dtype)
    out = qlinear(o.reshape(b, sq, -1).astype(x.dtype), p["wo"],
                  site_seed(seed, layer, 4), scheme)
    return out, (cc, kc)
