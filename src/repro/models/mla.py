"""Multi-head Latent Attention (DeepSeek-V2/V3).

Train/prefill use the expanded form; decode uses the weight-absorbed form over
the compressed latent cache (kv_lora_rank + rope dims per position — the whole
point of MLA: the KV cache is ~576 floats/token instead of 2*H*hd).

All projections are quantized linears; the absorbed decode einsums are bf16
(inference path, not part of the paper's training recipe).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import qlinear
from repro.models.attention import NEG_INF, apply_rope, attend, rope_tables
from repro.models.blocks import linear_init, rmsnorm, site_seed


def mla_init(key, cfg):
    m = cfg.mla
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": linear_init(ks[0], m.q_lora_rank, cfg.d_model),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": linear_init(ks[1], h * qk_dim, m.q_lora_rank),
        "wkv_a": linear_init(ks[2], m.kv_lora_rank + m.qk_rope_head_dim, cfg.d_model),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wkv_b": linear_init(ks[3], h * (m.qk_nope_head_dim + m.v_head_dim), m.kv_lora_rank),
        "wo": linear_init(ks[4], cfg.d_model, h * m.v_head_dim),
    }


def _latent(p, x, cfg, scheme, seed, layer, positions):
    """Shared projections: per-head q (nope+rope), latent c, rotated k_rope."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = qlinear(rmsnorm(qlinear(x, p["wq_a"], site_seed(seed, layer, 0), scheme),
                        p["q_norm"], cfg.norm_eps),
                p["wq_b"], site_seed(seed, layer, 1), scheme).reshape(b, s, h, qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    kv = qlinear(x, p["wkv_a"], site_seed(seed, layer, 2), scheme)
    c = rmsnorm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]  # (B,S,1,rope)
    cos, sin = rope_tables(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, c, k_rope


def mla_apply(p, x, cfg, scheme, seed, layer, *, positions=None):
    """Expanded-form MLA (train / prefill). Returns (out, (c, k_rope)) for the
    latent decode cache."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope, c, k_rope = _latent(p, x, cfg, scheme, seed, layer, positions)
    kvb = qlinear(c, p["wkv_b"], site_seed(seed, layer, 3), scheme)
    kvb = kvb.reshape(b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = kvb[..., : m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]
    # fold rope part into the head dim so standard SDPA applies
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_rope.shape[:2], h, k_rope.shape[-1]))], axis=-1)
    o = attend(q_full, k_full, v, causal=True)
    out = qlinear(o.reshape(b, s, -1), p["wo"], site_seed(seed, layer, 4), scheme)
    return out, (c, k_rope[:, :, 0, :])


def mla_decode(p, x, cfg, scheme, seed, layer, cache, pos):
    """Absorbed-form decode over the latent cache.

    cache = (c: (B,Smax,kv_lora), kr: (B,Smax,rope)); pos scalar.
    score_h(t) = q_nope_h^T Wuk_h c_t + q_rope_h^T kr_t   (Wuk absorbed into q)
    out_h = (sum_t p_t c_t)^T Wuv_h                        (Wuv absorbed after)
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    posb = jnp.full((b,), pos, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _latent(p, x, cfg, scheme, seed, layer, posb[:, None])
    cc, kc = cache
    cc = jax.lax.dynamic_update_slice_in_dim(cc, c_new.astype(cc.dtype), pos, axis=1)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, kr_new[:, :, 0, :].astype(kc.dtype), pos, axis=1)

    wkv_b = p["wkv_b"].reshape(h, m.qk_nope_head_dim + m.v_head_dim, m.kv_lora_rank)
    w_uk = wkv_b[:, : m.qk_nope_head_dim, :]     # (H, nope, lora)
    w_uv = wkv_b[:, m.qk_nope_head_dim:, :]      # (H, v, lora)

    q_abs = jnp.einsum("bqhn,hnl->bhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))              # (B,H,lora)
    s_lat = jnp.einsum("bhl,btl->bht", q_abs, cc.astype(jnp.float32))
    s_rope = jnp.einsum("bqhr,btr->bht", q_rope.astype(jnp.float32),
                        kc.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (s_lat + s_rope) * scale
    tmask = jnp.arange(cc.shape[1])[None, None, :] <= pos
    s = jnp.where(tmask, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bht,btl->bhl", prob, cc.astype(jnp.float32))
    o = jnp.einsum("bhl,hvl->bhv", o_lat, w_uv.astype(jnp.float32))
    out = qlinear(o.reshape(b, 1, -1).astype(x.dtype), p["wo"],
                  site_seed(seed, layer, 4), scheme)
    return out, (cc, kc)
