"""Mixture-of-Experts with shared experts and capacity-based dispatch.

Dispatch is sort + scatter into an (E, C, D) buffer, expert FF as batched
per-expert GEMMs (vmapped quantized linears — each expert GEMM is its own
NVFP4-quantized GEMM with per-expert scales, matching how Blackwell kernels
would run grouped GEMMs), then gather + weighted combine. FLOPs are
O(tokens * top_k * capacity_factor * d * f) — the ACTIVE compute only, never
the dense all-experts product. The (E, C, D) buffer shards over the "model"
mesh axis (expert parallelism); under pjit the scatter/gather lower to
all-to-all style collectives.

Router runs in fp32 and is NOT quantized (routing logits are tiny and
bias-sensitive; standard practice, also kept high-precision by the paper's
baselines).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import qlinear
from repro.models.blocks import linear_init, mlp_apply, mlp_init, site_seed


def moe_init(key, cfg):
    m = cfg.moe
    ks = jax.random.split(key, 5)
    d, f = cfg.d_model, m.d_ff_expert
    p = {
        "router": linear_init(ks[0], m.n_routed, d, scale=0.02),
        # routed experts: stacked (E, f, d) weights, swiglu
        "wi": jax.random.normal(ks[1], (m.n_routed, f, d), jnp.float32) * d ** -0.5,
        "wg": jax.random.normal(ks[2], (m.n_routed, f, d), jnp.float32) * d ** -0.5,
        "wo": jax.random.normal(ks[3], (m.n_routed, d, f), jnp.float32) * f ** -0.5,
    }
    if m.n_shared:
        p["shared"] = mlp_init(ks[4], d, f * m.n_shared, "swiglu")
    return p


def _capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_routed) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_apply(p, x, cfg, scheme, seed, layer):
    """x: (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    # ---- routing (fp32 dense) ----
    logits = (xf.astype(jnp.float32) @ p["router"].T.astype(jnp.float32))
    if m.score == "sigmoid":          # deepseek-v3
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(scores, m.top_k)          # (T, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    top_w = top_w * m.route_scale

    # ---- dispatch: sort token-replicas by expert, drop beyond capacity ----
    cap = _capacity(t, cfg)
    fe = top_e.reshape(-1)                                  # (T*K,)
    ft = jnp.repeat(jnp.arange(t), m.top_k)
    fw = top_w.reshape(-1)
    order = jnp.argsort(fe)
    fe_s, ft_s, fw_s = fe[order], ft[order], fw[order]
    counts = jnp.zeros((m.n_routed,), jnp.int32).at[fe_s].add(1)
    seg_start = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * m.top_k) - seg_start[fe_s]
    keep = pos_in_e < cap
    # out-of-capacity rows scatter out of bounds -> dropped
    e_idx = jnp.where(keep, fe_s, m.n_routed)
    c_idx = jnp.where(keep, pos_in_e, 0)
    buf = jnp.zeros((m.n_routed, cap, d), x.dtype)
    buf = buf.at[e_idx, c_idx].set(xf[ft_s], mode="drop")

    # ---- per-expert quantized FF (vmapped over experts) ----
    eseed = jax.vmap(lambda e: site_seed(seed, layer, 20))(jnp.arange(m.n_routed))
    eseed = eseed.at[:, 1].add(jnp.arange(m.n_routed, dtype=jnp.uint32))

    def expert_ff(xb, wi, wg, wo, sd):
        h = qlinear(xb, wi, sd, scheme)
        g = qlinear(xb, wg, sd + jnp.uint32(1), scheme)
        a = jax.nn.silu(h.astype(jnp.float32)).astype(xb.dtype) * g
        return qlinear(a, wo, sd + jnp.uint32(2), scheme)

    from repro.core import linear as QL
    # NOTE: do NOT pin the dispatch buffer to (E->model,...) — GSPMD lowers
    # the cross-shard scatter as replicate+all-reduce of the whole buffer
    # (measured +2.1x collective on deepseek-v3; Perf iteration 7). Token
    # hints are suppressed inside the vmapped expert GEMMs instead, and the
    # buffer layout is left to propagation.
    with QL.no_hints():
        out_buf = jax.vmap(expert_ff)(buf, p["wi"], p["wg"], p["wo"], eseed)

    # ---- combine: gather back, weight, unsort-scatter-add ----
    gathered = out_buf.at[e_idx, c_idx].get(mode="fill", fill_value=0.0)
    weighted = gathered.astype(jnp.float32) * fw_s[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[ft_s].add(weighted)
    y = y.astype(x.dtype).reshape(b, s, d)

    # ---- shared experts (dense path over all tokens) ----
    if m.n_shared:
        y = y + mlp_apply(p["shared"], x, "swiglu", scheme, seed, layer)

    # load-balance aux loss (Switch-style), returned for the trainer
    me = jnp.mean(jax.nn.one_hot(top_e, m.n_routed, dtype=jnp.float32), axis=(0, 1))
    pe = jnp.mean(scores, axis=0)
    aux = m.n_routed * jnp.sum(me * pe)
    return y, aux
