"""RWKV-6 "Finch": attention-free time-mix with data-dependent per-channel
decay, in a chunk-parallel formulation.

Recurrence per head (d = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t

Chunked evaluation (chunk C): with logP = cumsum(log w) inside the chunk and
logQ_t = logP_{t-1} (logQ_0 = 0), the intra-chunk pairwise decays factor as
exp(logQ_t - logP_j) = exp(logQ_t) * exp(-logP_j), so

    o_t = (r_t . exp(logQ_t)) @ S_0                          (inter-chunk)
        + tril_strict[(r.exp(logQ)) @ (k.exp(-logP))^T] @ v  (intra-chunk)
        + (r_t . u . k_t) v_t                                (current token)
    S_C = exp(logP_C) . S_0 + ((k.exp(-logP)) * exp(logP_C))^T @ v

Numerics: the factored form needs exp(-logP) bounded; per-step log-decay is
clamped to >= LOG_W_MIN so exp(-logP) <= exp(C * |LOG_W_MIN|) stays in fp32
(documented deviation from reference RWKV-6, which allows unbounded decay).

All projections (r/k/v/gate/output, channel-mix) are quantized linears; the
recurrence itself is elementwise fp32 (the paper only quantizes GEMMs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import qlinear
from repro.models.blocks import linear_init, rmsnorm, site_seed

LOG_W_MIN = -5.0  # per-step decay clamp (see numerics note above)


def rwkv_init(key, cfg):
    d = cfg.d_model
    r = cfg.rwkv.lora_rank
    ks = jax.random.split(key, 12)
    return {
        # token-shift static mixes + data-dependent LoRA (5 targets: r,k,v,w,g)
        "mu": jax.random.uniform(ks[0], (5, d), jnp.float32),
        "mix_w1": linear_init(ks[1], 5 * r, d, scale=0.01),
        "mix_w2": jax.random.normal(ks[2], (5, r, d), jnp.float32) * 0.01,
        "wr": linear_init(ks[3], d, d),
        "wk": linear_init(ks[4], d, d),
        "wv": linear_init(ks[5], d, d),
        "wg": linear_init(ks[6], d, d),
        "wo": linear_init(ks[7], d, d),
        # decay: w0 static + LoRA; u bonus
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "ww1": linear_init(ks[8], r, d, scale=0.01),
        "ww2": linear_init(ks[9], d, r, scale=0.01),
        "u": jax.random.normal(ks[10], (d,), jnp.float32) * 0.1,
        "gn": jnp.ones((d,), jnp.float32),  # per-head groupnorm gain
        # channel-mix
        "cm_mu": jax.random.uniform(ks[11], (2, d), jnp.float32),
        "cm_wr": linear_init(jax.random.fold_in(key, 20), d, d),
        "cm_wk": linear_init(jax.random.fold_in(key, 21), cfg.d_ff, d),
        "cm_wv": linear_init(jax.random.fold_in(key, 22), d, cfg.d_ff),
    }


def _shift(x: jax.Array, prev: jax.Array | None):
    """Token shift: x_{t-1} (prev carries the last token across steps/chunks)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix_inputs(p, x, shifted):
    """Data-dependent token-shift interpolation (5 mixed variants of x)."""
    xx = shifted - x
    dyn = jnp.tanh(xx.astype(jnp.float32) @ p["mix_w1"].T.astype(jnp.float32))
    b, s, _ = x.shape
    r = p["mix_w2"].shape[1]
    dyn = dyn.reshape(b, s, 5, r)
    off = jnp.einsum("bsfr,frd->bsfd", dyn, p["mix_w2"].astype(jnp.float32))
    mix = p["mu"][None, None] + off                    # (B,S,5,D)
    return x[:, :, None, :] + xx[:, :, None, :] * mix.astype(x.dtype)


def _decay(p, xw):
    """Per-token per-channel log-decay, clamped (see module docstring)."""
    lo = jnp.tanh(xw.astype(jnp.float32) @ p["ww1"].T.astype(jnp.float32)) @ p["ww2"].T.astype(jnp.float32)
    logw = -jnp.exp(jnp.clip(p["w0"][None, None] + lo, -8.0, 1.6))
    return jnp.clip(logw, LOG_W_MIN, -1e-4)


def wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunk-parallel WKV. r/k/v/logw: (B,S,H,d); u: (H,d);
    state: (B,H,d,d). Returns (out (B,S,H,d), new state)."""
    b, s, h, d = r.shape
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    n = s // chunk

    def to_chunks(x):
        return x.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)  # (N,B,H,C,d)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))

    def step(S, inp):
        rr, kk, vv, ww = [t.astype(jnp.float32) for t in inp]
        logP = jnp.cumsum(ww, axis=-2)                 # (B,H,C,d)
        logQ = logP - ww                               # logP_{t-1}
        rq = rr * jnp.exp(logQ)
        kp = kk * jnp.exp(-logP)
        A = jnp.einsum("bhtd,bhjd->bhtj", rq, kp)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        intra = jnp.einsum("bhtj,bhjd->bhtd", A, vv)
        bonus = jnp.einsum("bhtd,hd,bhtd->bht", rr, u.astype(jnp.float32), kk)
        intra = intra + bonus[..., None] * vv
        inter = jnp.einsum("bhtd,bhde->bhte", rq, S)
        pC = jnp.exp(logP[:, :, -1])                   # (B,H,d)
        S_new = pC[..., None] * S + jnp.einsum(
            "bhjd,bhje->bhde", kp * pC[:, :, None, :], vv)
        return S_new, (intra + inter)

    state, outs = jax.lax.scan(step, state.astype(jnp.float32), (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, d)
    return out.astype(r.dtype), state


def _wkv_step(rr, kk, vv, ww, u, state):
    """One recurrence step. rr/kk/vv/ww: (B,H,d) fp32; state (B,H,d,d)."""
    o = jnp.einsum("bhd,bhde->bhe", rr, state) + \
        jnp.einsum("bhd,hd,bhd->bh", rr, u, kk)[..., None] * vv
    state = jnp.exp(ww)[..., None] * state + kk[..., None] * vv[:, :, None, :]
    return o, state


def wkv_decode(r, k, v, logw, u, state):
    """Single-token WKV: O(d^2) per head. r/k/v/logw: (B,1,H,d)."""
    rr, kk, vv, ww = [t[:, 0].astype(jnp.float32) for t in (r, k, v, logw)]
    o, state = _wkv_step(rr, kk, vv, ww, u.astype(jnp.float32), state)
    return o[:, None].astype(r.dtype), state


def wkv_apply(r, k, v, logw, u, state, chunk: int):
    """Chunked WKV for any sequence length: full chunks via the parallel form,
    the remainder via a per-token scan (remainder < chunk, cheap)."""
    b, s, h, d = r.shape
    s_main = (s // chunk) * chunk
    outs = []
    if s_main:
        o1, state = wkv_chunked(r[:, :s_main], k[:, :s_main], v[:, :s_main],
                                logw[:, :s_main], u, state, chunk)
        outs.append(o1)
    if s > s_main:
        xs = tuple(t[:, s_main:].astype(jnp.float32).transpose(1, 0, 2, 3)
                   for t in (r, k, v, logw))
        uf = u.astype(jnp.float32)

        def step(S, inp):
            rr, kk, vv, ww = inp
            o, S = _wkv_step(rr, kk, vv, ww, uf, S)
            return S, o

        state, otail = jax.lax.scan(step, state.astype(jnp.float32), xs)
        outs.append(otail.transpose(1, 0, 2, 3).astype(r.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0], state


def timemix_apply(p, x, cfg, scheme, seed, layer, *, state=None, prev=None):
    """RWKV-6 time-mix. state: (B,H,d,d) or None; prev: (B,1,D) last token."""
    b, s, dm = x.shape
    hd = cfg.rwkv.head_dim
    h = dm // hd
    shifted = _shift(x, prev)
    xm = _mix_inputs(p, x, shifted)
    xr, xk, xv, xw, xg = [xm[:, :, i] for i in range(5)]
    r = qlinear(xr, p["wr"], site_seed(seed, layer, 0), scheme).reshape(b, s, h, hd)
    k = qlinear(xk, p["wk"], site_seed(seed, layer, 1), scheme).reshape(b, s, h, hd)
    v = qlinear(xv, p["wv"], site_seed(seed, layer, 2), scheme).reshape(b, s, h, hd)
    g = qlinear(xg, p["wg"], site_seed(seed, layer, 3), scheme)
    logw = _decay(p, xw).reshape(b, s, h, hd)
    u = p["u"].reshape(h, hd)
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    if s == 1:
        o, state = wkv_decode(r, k, v, logw, u, state)
    else:
        o, state = wkv_apply(r, k, v, logw, u, state, cfg.rwkv.chunk)
    # per-head groupnorm then gate
    o = rmsnorm(o, p["gn"].reshape(h, hd), cfg.norm_eps).reshape(b, s, dm)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    out = qlinear(o, p["wo"], site_seed(seed, layer, 4), scheme)
    return out, state, x[:, -1:]


def channelmix_apply(p, x, cfg, scheme, seed, layer, *, prev=None):
    """RWKV-6 channel-mix (the FFN analogue)."""
    shifted = _shift(x, prev)
    xx = shifted - x
    xk = x + xx * p["cm_mu"][0].astype(x.dtype)
    xr = x + xx * p["cm_mu"][1].astype(x.dtype)
    k = qlinear(xk, p["cm_wk"], site_seed(seed, layer, 5), scheme)
    k = (jax.nn.relu(k.astype(jnp.float32)) ** 2).astype(x.dtype)
    v = qlinear(k, p["cm_wv"], site_seed(seed, layer, 6), scheme)
    r = qlinear(xr, p["cm_wr"], site_seed(seed, layer, 7), scheme)
    return jax.nn.sigmoid(r.astype(jnp.float32)).astype(x.dtype) * v, x[:, -1:]
