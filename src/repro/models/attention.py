"""Attention: GQA/MQA with RoPE (full/partial), QK-norm, sliding-window (local)
masks, chunked online-softmax for long prefill, and KV-cache decode.

All four projections run through the quantized linear (paper Fig. 3 applies
the scheme to every linear layer); the softmax itself stays fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import qlinear
from repro.models.blocks import rmsnorm, site_seed

NEG_INF = -1e30
# plain (materialized-scores) attention below this sequence length; chunked
# online-softmax above (prefill_32k would otherwise materialize S^2 scores).
CHUNK_THRESHOLD = 8192
Q_BLOCK = 1024
KV_BLOCK = 1024


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_tables(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for `dim` rotary dims at given positions (…,)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, fraction: float = 1.0):
    """Rotate the first `fraction` of head dims (chatglm3 uses 0.5, '2d' RoPE)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    c = cos[..., None, : rot // 2]
    s = sin[..., None, : rot // 2]
    o1 = x1 * c - x2 * s
    o2 = x2 * c + x1 * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# masks / SDPA
# --------------------------------------------------------------------------

def _mask_bias(sq: int, sk: int, q_off, causal: bool, window: int | None):
    qi = q_off + jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kj <= qi
    if window is not None:
        ok &= kj > qi - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def sdpa(q, k, v, *, causal=True, window=None, q_off=0):
    """Plain SDPA. q: (B,Sq,H,hd), k: (B,Sk,KV,hd), v: (B,Sk,KV,vd)
    -> (B,Sq,H,vd). vd may differ from hd (MLA)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    qf = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qf, kf) / jnp.sqrt(hd)
    scores = scores + _mask_bias(sq, k.shape[1], q_off, causal, window)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgrqk,bkgv->bqgrv", p, vf)
    return o.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


def chunked_sdpa(q, k, v, *, causal=True, window=None):
    """Online-softmax attention over KV blocks (flash-style, inference paths).

    Never materializes (Sq, Sk) scores: peak transient is (B, H, Q_BLOCK,
    KV_BLOCK) — the memory-roofline fix for prefill_32k.
    """
    b, sq, h, hd = q.shape
    vd = v.shape[-1]
    kv = k.shape[2]
    rep = h // kv
    sk = k.shape[1]
    nq, nk = sq // Q_BLOCK if sq >= Q_BLOCK else 1, max(sk // KV_BLOCK, 1)
    qb = Q_BLOCK if sq >= Q_BLOCK else sq
    kb = sk // nk
    qf = q.reshape(b, nq, qb, kv, rep, hd).astype(jnp.float32)
    kf = k.reshape(b, nk, kb, kv, hd).astype(jnp.float32)
    vf = v.reshape(b, nk, kb, kv, vd).astype(jnp.float32)

    def q_block(qi, qblk):
        q_off = qi * qb

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = kf[:, ki]
            vblk = vf[:, ki]
            s = jnp.einsum("bqgrh,bkgh->bgrqk", qblk, kblk) / jnp.sqrt(hd)
            s = s + _mask_bias(qb, kb, q_off - ki * kb, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bgrqk,bkgv->bgrqv", p, vblk)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kv, rep, qb), NEG_INF, jnp.float32),
                jnp.zeros((b, kv, rep, qb), jnp.float32),
                jnp.zeros((b, kv, rep, qb, vd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 3, 1, 2, 4)  # (b, qb, kv, rep, hd)

    out = jax.lax.map(lambda qi: q_block(qi, qf[:, qi]), jnp.arange(nq))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, vd)
    return out.astype(q.dtype)


def attend(q, k, v, *, causal=True, window=None):
    if q.shape[1] > CHUNK_THRESHOLD or k.shape[1] > CHUNK_THRESHOLD:
        return chunked_sdpa(q, k, v, causal=causal, window=window)
    return sdpa(q, k, v, causal=causal, window=window)


def decode_sdpa(q, k_cache, v_cache, pos, window=None, abs_pos=None):
    """Decode attention over a cache. q: (B,Sq,H,hd); caches (B,Smax,KV,hd);
    pos (B,) is the absolute position of each row's FIRST query token (Sq > 1
    is a chunked-prefill step, Sq == 1 plain decode).

    `abs_pos` (B,Smax) optionally maps cache index -> absolute position for
    ring buffers (sliding-window caches that wrap); entries < 0 mean "never
    written". Default: cache index IS the absolute position.
    """
    from repro.core import linear as QL  # sharding hints (None off-mesh)
    b, sq, h, hd = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    sk = k_cache.shape[1]
    qf = q.reshape(b, sq, kv, rep, hd).astype(jnp.float32)
    # Perf iteration (decode): the KV cache shards head_dim over "model"; pin
    # q to the SAME hd sharding and the score layout to batch-DP so the
    # contraction lowers to a psum of (B,KV,rep,Sq,S) scores instead of
    # all-gathering the multi-GiB cache.
    qf = QL._hint(qf, (QL._dp(b), None, None, None, QL._tp(hd)))
    s = jnp.einsum("bqgrh,bkgh->bgrqk", qf, k_cache.astype(jnp.float32)) / jnp.sqrt(hd)
    s = QL._hint(s, (QL._dp(b), None, None, None, None))
    qpos = pos[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]  # (B,Sq)
    if abs_pos is None:
        kj = jnp.arange(sk, dtype=jnp.int32)[None, :]
    else:
        kj = abs_pos
    ok = kj[:, None, :] <= qpos[:, :, None]                          # (B,Sq,Sk)
    if abs_pos is not None:
        ok &= kj[:, None, :] >= 0
    if window is not None:
        ok &= kj[:, None, :] > qpos[:, :, None] - window
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgv->bqgrv", p, v_cache.astype(jnp.float32))
    return o.reshape(b, sq, h, v_cache.shape[-1]).astype(q.dtype)


def ring_abs_pos(pos, sq: int, cap: int):
    """Absolute position held by each ring-buffer slot after writing a chunk.

    With per-row last written position P = pos + sq - 1, slot j holds the
    largest position <= P congruent to j mod cap; negative results mean the
    slot was never written. Valid whenever cap == window (a slot's previous
    occupant is at least one full window older, so masking by query position
    is exact)."""
    pmax = pos + sq - 1
    j = jnp.arange(cap, dtype=jnp.int32)[None, :]
    return pmax[:, None] - ((pmax[:, None] - j) % cap)


# --------------------------------------------------------------------------
# GQA block
# --------------------------------------------------------------------------

def gqa_init(key, cfg):
    from repro.models.blocks import linear_init
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], cfg.n_heads * hd, cfg.d_model),
        "wk": linear_init(ks[1], cfg.n_kv_heads * hd, cfg.d_model),
        "wv": linear_init(ks[2], cfg.n_kv_heads * hd, cfg.d_model),
        "wo": linear_init(ks[3], cfg.d_model, cfg.n_heads * hd),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones((hd,), jnp.float32)
        p["kn"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg, scheme, seed, layer, positions):
    b, s, _ = x.shape
    hd = cfg.hd
    q = qlinear(x, p["wq"], site_seed(seed, layer, 0), scheme).reshape(b, s, cfg.n_heads, hd)
    k = qlinear(x, p["wk"], site_seed(seed, layer, 1), scheme).reshape(b, s, cfg.n_kv_heads, hd)
    v = qlinear(x, p["wv"], site_seed(seed, layer, 2), scheme).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["qn"], cfg.norm_eps)
        k = rmsnorm(k, p["kn"], cfg.norm_eps)
    if cfg.rope_fraction > 0:
        rot = int(hd * cfg.rope_fraction)
        cos, sin = rope_tables(positions, rot, cfg.rope_theta)
        q = apply_rope(q, cos, sin, cfg.rope_fraction)
        k = apply_rope(k, cos, sin, cfg.rope_fraction)
    return q, k, v


def gqa_apply(p, x, cfg, scheme, seed, layer, *, causal=True, window=None,
              positions=None):
    """Full-sequence GQA (train / prefill). Returns (out, (k, v)) so callers
    can populate a decode cache."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, scheme, seed, layer, positions)
    o = attend(q, k, v, causal=causal, window=window)
    out = qlinear(o.reshape(b, s, -1), p["wo"], site_seed(seed, layer, 3), scheme)
    return out, (k, v)


def gqa_decode(p, x, cfg, scheme, seed, layer, cache_kv, pos, *, window=None,
               active=None, block_table=None, paged_kernel=False):
    """Cached decode / chunked-prefill step. x: (B, Sq, D) with Sq >= 1.

    pos: scalar or (B,) — absolute position of each row's first token
      (per-sequence vector = ragged prompts / continuous batching).
    active: (B,) bool — rows whose cache may be written (inactive slots in a
      serving batch keep their cache bit-for-bit: writes are routed out of
      bounds and dropped).
    block_table: (B, MAXB) int32 — when given, cache_kv holds POOL-shaped
      (P, BS, KV, hd) leaves and reads/writes go through the paged KV pool
      (serve/kv_pool.py); unallocated entries carry the pool's OOB sentinel.
    paged_kernel: attend with the block-table flash-decode Pallas kernel
      (kernels/paged_attention.py) instead of materializing gather_view
      copies — O(row length) HBM traffic instead of O(table capacity).

    Contract: this step is ROW-LOCAL (row b reads/writes only row b of x,
    positions, and the cache — shard-local block-table indices included),
    so the mesh-sharded serving engine may split the batch and pool across
    a shard_map "data" axis without changing a bit, and the sentinel is
    always derived from the (possibly shard-local) pool leaf itself
    (docs/CONVENTIONS.md §2-3).
    """
    b, sq = x.shape[:2]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = posb[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
    q, k, v = _project_qkv(p, x, cfg, scheme, seed, layer, positions)
    kc, vc = cache_kv
    valid = positions >= 0
    if active is not None:
        valid &= active[:, None]
    if block_table is not None:
        from repro.serve import kv_pool as KV
        # reads resolve through the READ table; scatters go through the
        # WRITE view, whose prefix-cache-aliased entries hold the sentinel —
        # shared blocks are provably never written (docs/CONVENTIONS.md §5).
        # A plain (B, MAXB) table is its own write view.
        rt, wt = KV.split_tables(block_table)
        kc = KV.scatter_tokens(kc, wt, positions, k, valid)
        vc = KV.scatter_tokens(vc, wt, positions, v, valid)
        if paged_kernel:
            from repro.kernels import ops as KOPS
            if isinstance(kc, KV.PackedKV):
                # NVFP4 pool: hand the kernel the raw packed leaves; it
                # dequantizes block-wise in VMEM (kernels/paged_attention.py)
                o = KOPS.paged_attention_q(q, kc.codes, kc.scales,
                                           vc.codes, vc.scales, rt, posb,
                                           window=window)
            else:
                o = KOPS.paged_attention(q, kc, vc, rt, posb,
                                         window=window)
        else:
            # gather_view dequantizes PackedKV pools to bf16 (exactly), so
            # the reference path is storage-mode agnostic
            o = decode_sdpa(q, KV.gather_view(kc, rt),
                            KV.gather_view(vc, rt), posb,
                            window=window)
    else:
        cap = kc.shape[1]
        ring = window is not None and cap == window
        if ring and sq > 1:
            # in-chunk ring writes evict keys still inside earlier chunk
            # queries' windows, and ring_abs_pos labels slots from the
            # chunk's LAST position only — correct solely for sq == 1
            raise NotImplementedError(
                "ring-buffer (cap == window) caches decode one token at a "
                "time; chunked prefill needs a full-capacity or paged cache")
        idx = positions % cap if ring else positions
        idx = jnp.where(valid, idx, cap)  # OOB index => scatter drops the row
        bi = jnp.arange(b)[:, None]
        kc = kc.at[bi, idx].set(k.astype(kc.dtype), mode="drop")
        vc = vc.at[bi, idx].set(v.astype(vc.dtype), mode="drop")
        abs_pos = ring_abs_pos(posb, sq, cap) if ring else None
        o = decode_sdpa(q, kc, vc, posb, window=window, abs_pos=abs_pos)
    if active is not None:
        # Inactive rows must not read cache memory: their stale contents are
        # layout-dependent (dense keeps retired sequences' K/V, the pool
        # reads zeros) and any nonzero garbage would leak into active rows
        # through the per-tensor activation-quantization absmax. Zeroing the
        # attention output makes inactive rows a pure function of their
        # (deterministic) token stream.
        o = o * active[:, None, None, None].astype(o.dtype)
    out = qlinear(o.reshape(b, sq, -1), p["wo"], site_seed(seed, layer, 3), scheme)
    return out, (kc, vc)
