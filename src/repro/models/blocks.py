"""Shared building blocks: norms, MLPs, embeddings, seed plumbing, init."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linear import dense, qlinear


def site_seed(seed: jax.Array, layer, site: int) -> jax.Array:
    """Derive a distinct uint32[2] sub-seed per (layer, call-site).

    Cheap LCG-style mixing (no threefry inside scan bodies); qlinear folds the
    result into a typed key anyway.
    """
    layer = jnp.asarray(layer, jnp.uint32)
    a = seed[0] ^ (layer * jnp.uint32(2654435761) + jnp.uint32(site) * jnp.uint32(40503))
    b = seed[1] + layer * jnp.uint32(97) + jnp.uint32(site)
    return jnp.stack([a, b])


def rmsnorm(x: jax.Array, g: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    n = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (n * g.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * g + b).astype(x.dtype)


def norm(x, p, kind: str, eps: float):
    if kind == "layernorm":
        return layernorm(x, p["g"], p["b"], eps)
    return rmsnorm(x, p["g"], eps)


def norm_init(d: int, kind: str):
    if kind == "layernorm":
        return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    return {"g": jnp.ones((d,), jnp.float32)}


def linear_init(key, n_out: int, n_in: int, scale: float | None = None) -> jax.Array:
    s = scale if scale is not None else n_in ** -0.5
    return (jax.random.normal(key, (n_out, n_in), jnp.float32) * s)


def mlp_apply(p, x, kind: str, scheme: str, seed, layer):
    """swiglu | relu2 | gelu feed-forward, all matmuls quantized per scheme."""
    if kind == "swiglu":
        h = qlinear(x, p["wi"], site_seed(seed, layer, 10), scheme)
        g = qlinear(x, p["wg"], site_seed(seed, layer, 11), scheme)
        a = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * g
    elif kind == "relu2":
        h = qlinear(x, p["wi"], site_seed(seed, layer, 10), scheme)
        a = (jax.nn.relu(h.astype(jnp.float32)) ** 2).astype(x.dtype)
    else:  # gelu
        h = qlinear(x, p["wi"], site_seed(seed, layer, 10), scheme)
        a = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return qlinear(a, p["wo"], site_seed(seed, layer, 12), scheme)


def mlp_init(key, d_model: int, d_ff: int, kind: str):
    ks = jax.random.split(key, 3)
    p = {"wi": linear_init(ks[0], d_ff, d_model),
         "wo": linear_init(ks[1], d_model, d_ff)}
    if kind == "swiglu":
        p["wg"] = linear_init(ks[2], d_ff, d_model)
    return p


def embed_init(key, vocab: int, d_model: int):
    return jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02


def embed_lookup(table: jax.Array, tokens: jax.Array, dtype=jnp.bfloat16):
    return jnp.take(table, tokens, axis=0).astype(dtype)


def lm_head(x: jax.Array, w: jax.Array, quantize: bool, scheme: str, seed) -> jax.Array:
    """Final projection to vocab. Paper practice keeps this in BF16."""
    if quantize:
        return qlinear(x, w, site_seed(seed, 0, 99), scheme)
    return dense(x, w)


def cross_entropy(logits: jax.Array, labels: jax.Array, z_loss: float = 0.0):
    """Token-mean CE in fp32; labels < 0 are masked out."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_head_ce(x: jax.Array, head_w: jax.Array, labels: jax.Array,
                    quantize: bool, scheme: str, seed,
                    chunk_tokens: int = 1024) -> jax.Array:
    """Fused LM-head + CE that never materializes the full (tokens, vocab)
    logits: the flattened token axis is processed in chunks under
    jax.checkpoint, so both forward and backward peak at
    (chunk_tokens x vocab) — the memory-roofline fix for 256k-vocab archs
    (nemotron, recurrentgemma) where full logits would be O(100GiB)/device.

    Returns (sum_nll, n_tokens) so callers can combine with masking."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    lf = labels.reshape(t)
    n_chunks = max(1, t // chunk_tokens)
    while t % n_chunks:
        n_chunks -= 1
    xc = xf.reshape(n_chunks, t // n_chunks, d)
    lc = lf.reshape(n_chunks, t // n_chunks)

    @jax.checkpoint
    def one(xi, li):
        logits = lm_head(xi[None], head_w, quantize, scheme, seed)[0]
        logf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logf, axis=-1)
        gold = jnp.take_along_axis(
            logf, jnp.maximum(li, 0)[:, None], axis=-1)[:, 0]
        mask = (li >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    def body(carry, inp):
        nll, cnt = one(*inp)
        return (carry[0] + nll, carry[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    return nll / jnp.maximum(cnt, 1.0)
