"""Unified language model over every assigned architecture family.

A model is a list of STAGES; each stage is `count` structurally-identical
layers whose parameters are stacked on a leading axis and executed with
jax.lax.scan (keeps HLO size O(1) in depth — essential for 61-layer dry-run
compiles). A layer is (mixer, ff):

    mixer: gqa | lattn (sliding window) | mla | rwkv_tm | rec (RG-LRU)
    ff:    mlp (swiglu/relu2/gelu) | moe | rwkv_cm

Hybrid patterns (recurrentgemma's rec,rec,attn) become stages whose layer spec
is the whole pattern, scanned over pattern repetitions; remainders become a
trailing stage. Whisper (enc_dec) runs an encoder stack then a decoder stack
with cross-attention.

Decode caches are pytrees aligned with the stage structure (stacked on the
same leading axis, consumed/emitted through the same scan). Sliding-window
layers use ring buffers of size `window` — the reason long_500k decode state
stays O(window + d^2) for the hybrid/ssm archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import griffin as G
from repro.models import mla as M
from repro.models import moe as X
from repro.models import rwkv6 as W
from repro.models.blocks import (chunked_head_ce, cross_entropy, embed_init,
                                 embed_lookup, lm_head, linear_init,
                                 mlp_apply, mlp_init, norm, norm_init,
                                 site_seed)

# --------------------------------------------------------------------------
# stage structure
# --------------------------------------------------------------------------

def layer_specs(cfg: ArchConfig) -> list[tuple[tuple[tuple[str, str], ...], int]]:
    """[(pattern, repeats)] — pattern is a tuple of (mixer, ff) layer specs."""
    if cfg.family == "ssm":
        return [((("rwkv_tm", "rwkv_cm"),), cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = tuple(("rec", "mlp") if t == "rec" else ("lattn", "mlp")
                    for t in cfg.griffin.pattern)
        reps, rem = divmod(cfg.n_layers, len(pat))
        stages = [(pat, reps)] if reps else []
        if rem:
            stages.append((pat[:rem], 1))
        return stages
    mixer = "mla" if cfg.attn == "mla" else "gqa"
    ff = "moe" if cfg.moe else "mlp"
    return [(((mixer, ff),), cfg.n_layers)]


def total_layers(cfg: ArchConfig) -> int:
    return sum(count * len(pattern) for pattern, count in layer_specs(cfg))


def _prefix_plan(cfg: ArchConfig, n_prefix: int):
    """Cut layer_specs after the first `n_prefix` layers.

    Returns (specs, plan) where plan entries (si, rep_start, reps, pat_len)
    select `reps` repetitions of stage `si` starting at repetition
    `rep_start`, truncated to the first `pat_len` layers of the pattern.
    A cut inside a hybrid pattern yields a trailing partial entry (reps=1),
    so any 0 < n_prefix < total_layers is a valid draft depth.
    """
    specs = layer_specs(cfg)
    total = total_layers(cfg)
    if not 0 < n_prefix < total:
        raise ValueError(
            f"prefix depth must satisfy 0 < n < {total}, got {n_prefix}")
    plan, left = [], n_prefix
    for si, (pattern, count) in enumerate(specs):
        if left <= 0:
            break
        per = len(pattern)
        reps = min(count, left // per)
        if reps:
            plan.append((si, 0, reps, per))
            left -= reps * per
        if left and reps < count:
            plan.append((si, reps, 1, left))
            left = 0
    return specs, plan


def prefix_specs(cfg: ArchConfig, n_prefix: int):
    """layer_specs truncated to the first n_prefix layers (draft stack)."""
    specs, plan = _prefix_plan(cfg, n_prefix)
    return [(specs[si][0][:plen], reps) for si, _, reps, plen in plan]


def prefix_stage_params(params, cfg: ArchConfig, n_prefix: int):
    """Stage-param views positionally aligned with prefix_specs.

    Slices the stacked (count, ...) leaves, so the draft reuses the full
    model's parameters — including PackedQWeight stacks — with the SAME
    per-layer ids (and therefore the same quantization site seeds) as the
    first n_prefix layers of the full forward.
    """
    specs, plan = _prefix_plan(cfg, n_prefix)
    out = []
    for si, r0, reps, plen in plan:
        sp = params["stages"][si]
        sub = {f"l{i}": sp[f"l{i}"] for i in range(plen)}
        if r0 == 0 and reps == specs[si][1] and plen == len(specs[si][0]):
            out.append(sub)
        else:
            out.append(jax.tree.map(lambda x: x[r0:r0 + reps], sub))
    return out


def _mixer_init(key, mixer: str, cfg):
    if mixer in ("gqa", "lattn"):
        return A.gqa_init(key, cfg)
    if mixer == "mla":
        return M.mla_init(key, cfg)
    if mixer == "rwkv_tm":
        return W.rwkv_init(key, cfg)
    if mixer == "rec":
        return G.rglru_init(key, cfg)
    raise ValueError(mixer)


def _ff_init(key, ff: str, cfg):
    if ff == "mlp":
        return mlp_init(key, cfg.d_model, cfg.d_ff, cfg.mlp)
    if ff == "moe":
        return X.moe_init(key, cfg)
    if ff == "rwkv_cm":
        return {}  # rwkv_init already carries channel-mix params
    raise ValueError(ff)


def _layer_init(key, spec, cfg):
    mixer, ff = spec
    km, kf = jax.random.split(key)
    p = {"mix": _mixer_init(km, mixer, cfg),
         "n1": norm_init(cfg.d_model, cfg.norm)}
    if ff != "rwkv_cm":
        p["ff"] = _ff_init(kf, ff, cfg)
    p["n2"] = norm_init(cfg.d_model, cfg.norm)
    return p


def _stack_init(key, pattern, count, cfg):
    """Stacked params: every leaf gets a leading (count,) axis."""
    def one(k):
        ks = jax.random.split(k, len(pattern))
        return {f"l{i}": _layer_init(ks[i], pattern[i], cfg)
                for i in range(len(pattern))}
    return jax.vmap(one)(jax.random.split(key, count))


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------

def _merge_state(active, new, old):
    """Keep `new` state rows where active, `old` elsewhere (per batch row).

    Token-cache kinds gate writes inside the mixer (OOB scatter drop); the
    recurrent kinds (wkv / tm_prev / cm_prev / lru) update unconditionally in
    their scans, so a serving batch must restore inactive rows here or a slot
    mid-prefill would be corrupted by the interleaved decode steps."""
    if active is None:
        return new

    def sel(n, o):
        m = active.reshape(active.shape[0], *([1] * (n.ndim - 1)))
        return jnp.where(m, n, o.astype(n.dtype))

    return jax.tree.map(sel, new, old)


def _apply_layer(spec, p, x, cfg, scheme, seed, layer_id, *, mode,
                 cache=None, pos=None, positions=None, enc_out=None,
                 active=None, block_table=None, paged_kernel=False):
    """One (mixer, ff) layer. Returns (x, new_cache_entry, aux)."""
    mixer, ff = spec
    window = cfg.griffin.window if (cfg.griffin and mixer == "lattn") else None
    aux = jnp.zeros((), jnp.float32)
    h = norm(x, p["n1"], cfg.norm, cfg.norm_eps)

    if mixer in ("gqa", "lattn"):
        if mode == "decode":
            o, new_kv = A.gqa_decode(p["mix"], h, cfg, scheme, seed, layer_id,
                                     cache["kv"], pos, window=window,
                                     active=active, block_table=block_table,
                                     paged_kernel=paged_kernel)
            cache = {**cache, "kv": new_kv}
        else:
            o, kv = A.gqa_apply(p["mix"], h, cfg, scheme, seed, layer_id,
                                causal=(mode != "encode"), window=window,
                                positions=positions)
            if cache is not None:
                cache = {**cache, "kv": _fill_cache(cache["kv"], kv, window)}
    elif mixer == "mla":
        if mode == "decode":
            o, new_c = M.mla_decode(p["mix"], h, cfg, scheme, seed, layer_id,
                                    cache["mla"], pos, active=active,
                                    block_table=block_table,
                                    paged_kernel=paged_kernel)
            cache = {**cache, "mla": new_c}
        else:
            o, ckr = M.mla_apply(p["mix"], h, cfg, scheme, seed, layer_id,
                                 positions=positions)
            if cache is not None:
                cache = {**cache, "mla": _fill_cache(cache["mla"], ckr, None)}
    elif mixer == "rwkv_tm":
        st = cache["wkv"] if cache is not None else None
        pv = cache["tm_prev"] if (cache is not None and mode != "train") else None
        o, st, last = W.timemix_apply(p["mix"], h, cfg, scheme, seed, layer_id,
                                      state=st if mode != "train" else None,
                                      prev=pv)
        if cache is not None:
            if mode == "decode":
                st = _merge_state(active, st, cache["wkv"])
                last = _merge_state(active, last, cache["tm_prev"])
            cache = {**cache, "wkv": st, "tm_prev": last}
    elif mixer == "rec":
        st = cache["lru"] if (cache is not None and mode != "train") else None
        o, st = G.recurrent_block_apply(p["mix"], h, cfg, scheme, seed,
                                        layer_id, state=st)
        if cache is not None:
            if mode == "decode":
                st = _merge_state(active, st, cache["lru"])
            cache = {**cache, "lru": st}
    else:
        raise ValueError(mixer)
    x = x + o

    # cross-attention (whisper decoder): between mixer and ff
    if enc_out is not None and "xattn" in p:
        h = norm(x, p["nx"], cfg.norm, cfg.norm_eps)
        o = _cross_attend(p["xattn"], h, enc_out, cfg, scheme, seed, layer_id)
        x = x + o

    h = norm(x, p["n2"], cfg.norm, cfg.norm_eps)
    if ff == "mlp":
        x = x + mlp_apply(p["ff"], h, cfg.mlp, scheme, seed, layer_id)
    elif ff == "moe":
        o, aux = X.moe_apply(p["ff"], h, cfg, scheme, seed, layer_id)
        x = x + o
    elif ff == "rwkv_cm":
        pv = cache["cm_prev"] if (cache is not None and mode != "train") else None
        o, last = W.channelmix_apply(p["mix"], h, cfg, scheme, seed, layer_id,
                                     prev=pv)
        if cache is not None:
            if mode == "decode":
                last = _merge_state(active, last, cache["cm_prev"])
            cache = {**cache, "cm_prev": last}
        x = x + o
    return x, cache, aux


def _fill_cache(buf, new, window):
    """Write prefill K/V (or latents) into a (possibly ring) cache buffer.

    Ring alignment: decode (attention.ring_abs_pos) expects slot j to hold
    the position ≡ j (mod cap), so the last `cap` prefill positions are
    rolled into place rather than written flat — with prompt length S the
    key for position p lands at slot p % cap."""
    def put(b, n):
        n = n.astype(b.dtype)
        s, cap = n.shape[1], b.shape[1]
        if window is not None and s > cap:
            # keep the last `cap` positions S-cap..S-1 and rotate so that
            # position p sits at slot p % cap (roll by S mod cap)
            n = jnp.roll(n[:, -cap:], s % cap, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(b, n, 0, axis=1)
    return jax.tree.map(put, buf, tuple(new) if isinstance(new, tuple) else new)


def _cross_attend(p, h, enc_out, cfg, scheme, seed, layer_id):
    from repro.core.linear import qlinear
    b, s, _ = h.shape
    hd = cfg.hd
    q = qlinear(h, p["wq"], site_seed(seed, layer_id, 30), scheme).reshape(b, s, cfg.n_heads, hd)
    if isinstance(enc_out, tuple):  # precomputed cross K/V (decode)
        k, v = enc_out
    else:
        k = qlinear(enc_out, p["wk"], site_seed(seed, layer_id, 31), scheme)
        v = qlinear(enc_out, p["wv"], site_seed(seed, layer_id, 32), scheme)
        k = k.reshape(b, -1, cfg.n_kv_heads, hd)
        v = v.reshape(b, -1, cfg.n_kv_heads, hd)
    o = A.attend(q, k, v, causal=False)
    return qlinear(o.reshape(b, s, -1), p["wo"], site_seed(seed, layer_id, 33), scheme)


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def _layer_cache(spec, cfg, batch: int, max_len: int, *, lattn_ring: bool = True):
    mixer, ff = spec
    hd = cfg.hd
    c: dict[str, Any] = {}
    if mixer in ("gqa", "lattn"):
        cap = max_len
        if mixer == "lattn" and cfg.griffin and lattn_ring:
            cap = min(max_len, cfg.griffin.window)
        kv = jnp.zeros((batch, cap, cfg.n_kv_heads, hd), jnp.bfloat16)
        c["kv"] = (kv, kv)
    elif mixer == "mla":
        m = cfg.mla
        c["mla"] = (jnp.zeros((batch, max_len, m.kv_lora_rank), jnp.bfloat16),
                    jnp.zeros((batch, max_len, m.qk_rope_head_dim), jnp.bfloat16))
    elif mixer == "rwkv_tm":
        h = cfg.d_model // cfg.rwkv.head_dim
        c["wkv"] = jnp.zeros((batch, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32)
        c["tm_prev"] = jnp.zeros((batch, 1, cfg.d_model), jnp.bfloat16)
    elif mixer == "rec":
        c["lru"] = G.recurrent_state_init(cfg, batch)
    if ff == "rwkv_cm":
        c["cm_prev"] = jnp.zeros((batch, 1, cfg.d_model), jnp.bfloat16)
    return c


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *,
               lattn_ring: bool = True):
    """Stacked cache pytree aligned with layer_specs(cfg).

    lattn_ring=False allocates full max_len capacity for sliding-window
    layers instead of a window-sized ring (required for ragged batches:
    the prefill ring roll assumes one shared prompt length)."""
    stages = []
    for pattern, count in layer_specs(cfg):
        one = {f"l{i}": _layer_cache(pattern[i], cfg, batch, max_len,
                                     lattn_ring=lattn_ring)
               for i in range(len(pattern))}
        stages.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (count, *x.shape)), one))
    return stages


# --------------------------------------------------------------------------
# model init / apply
# --------------------------------------------------------------------------

def init(cfg: ArchConfig, key: jax.Array):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    if cfg.input_mode == "tokens" or not cfg.enc_dec:
        params["embed"] = embed_init(ks[0], cfg.vocab, cfg.d_model)
    stages = []
    for i, (pattern, count) in enumerate(layer_specs(cfg)):
        stages.append(_stack_init(jax.random.fold_in(ks[1], i), pattern, count, cfg))
    params["stages"] = stages
    params["final_norm"] = norm_init(cfg.d_model, cfg.norm)
    if not cfg.tie_embeddings:
        params["head"] = linear_init(ks[2], cfg.vocab, cfg.d_model, scale=0.02)
    if cfg.enc_dec:
        params.update(_encdec_extra_init(cfg, ks[3]))
    return params


# Activation checkpointing for the layer scan (train dry-runs at production
# scale assume remat; smoke tests run without). Toggled by launch/dryrun.
REMAT = False


def _run_stages(params, x, cfg, scheme, seed, *, mode, caches=None,
                pos=None, positions=None, enc_out=None, stages=None,
                layer_offset=0, active=None, block_table=None,
                paged_kernel=False, unroll_stages=False):
    """`unroll_stages=True` fully unrolls the layer scan (lax.scan unroll ==
    trip count, so no while op reaches XLA). Only the mesh-sharded serving
    step sets it, and only when the mesh has a non-trivial GSPMD `auto` axis:
    this XLA's SPMD partitioner cannot propagate shardings into a while body
    inside a manual-subgroup (shard_map auto) region — it CHECK-fails on
    hlo_sharding_util's IsManualSubgroup. Costs HLO size O(depth), which
    serving (compile once, decode forever) tolerates."""
    specs = stages if stages is not None else layer_specs(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    off = layer_offset
    for si, (pattern, count) in enumerate(specs):
        sp = params["stages"][si]
        cache_s = caches[si] if caches is not None else None

        def body(carry, inp):
            x, aux = carry
            idx, layer_p, layer_c = inp
            new_c = {} if layer_c is not None else None
            for li, spec in enumerate(pattern):
                lid = off + idx * len(pattern) + li
                c_in = layer_c[f"l{li}"] if layer_c is not None else None
                x, c_out, a = _apply_layer(
                    spec, layer_p[f"l{li}"], x, cfg, scheme, seed, lid,
                    mode=mode, cache=c_in, pos=pos, positions=positions,
                    enc_out=enc_out, active=active, block_table=block_table,
                    paged_kernel=paged_kernel)
                if new_c is not None:
                    new_c[f"l{li}"] = c_out
                aux = aux + a
            return (x, aux), new_c

        # remat on every differentiated path (train + the encoder stack that
        # feeds the decoder's training loss); decode/prefill have no backward
        fn = jax.checkpoint(body) if (REMAT and mode in ("train", "encode")) else body
        unroll = count if unroll_stages else 1
        if cache_s is None:
            (x, aux_total), _ = jax.lax.scan(
                fn, (x, aux_total),
                (jnp.arange(count), sp, None), unroll=unroll)
        else:
            (x, aux_total), new_cache_s = jax.lax.scan(
                fn, (x, aux_total), (jnp.arange(count), sp, cache_s),
                unroll=unroll)
            new_caches.append(new_cache_s)
        off += count * len(pattern)
    return x, (new_caches if caches is not None else None), aux_total


def head_weight(params, cfg):
    if cfg.enc_dec:
        return params["dec_head"]
    return params["embed"] if cfg.tie_embeddings else params["head"]


def forward(params, cfg: ArchConfig, inputs, scheme: str, seed: jax.Array,
            *, caches=None, mode: str = "train", pos=None, head: bool = True,
            active=None, block_table=None, paged_kernel=False,
            unroll_stages=False):
    """Full model. inputs: {"tokens": (B,S)} or {"embeds": (B,S,D)} (+ both
    for enc-dec). Returns (logits_or_hidden, new_caches, aux_loss); with
    head=False the final normed hidden states are returned (lm_loss fuses the
    head with a chunked CE so full logits never materialize).

    Decode mode serves ragged batches: `pos` may be a scalar (uniform batch,
    legacy) or a per-sequence (B,) vector; S >= 1 tokens are consumed per row
    (S > 1 = chunked prefill into the cache). `active` (B,) gates cache
    writes per row; `block_table` — (B, MAXB), or (B, 2, MAXB) stacking a
    read table and a write-masked table (prefix-cache aliasing;
    kv_pool.split_tables) — switches kv/mla cache leaves to
    the paged pool layout (see serve/kv_pool.py); `paged_kernel` attends
    through the block-table flash-decode Pallas kernel instead of gathered
    views (kernels/paged_attention.py — requires block_table)."""
    if cfg.enc_dec:
        return _encdec_forward(params, cfg, inputs, scheme, seed,
                               caches=caches, mode=mode, pos=pos, head=head)
    if "embeds" in inputs and mode != "decode":
        x = inputs["embeds"].astype(jnp.bfloat16)
    else:
        x = embed_lookup(params["embed"], inputs["tokens"])
    b, s = x.shape[:2]
    if mode == "decode":
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        positions = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    else:
        positions = jnp.arange(s)[None, :]
    x, caches, aux = _run_stages(params, x, cfg, scheme, seed, mode=mode,
                                 caches=caches, pos=pos, positions=positions,
                                 active=active, block_table=block_table,
                                 paged_kernel=paged_kernel,
                                 unroll_stages=unroll_stages)
    x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    if not head:
        return x, caches, aux
    logits = lm_head(x, head_weight(params, cfg), cfg.quantize_lm_head, scheme, seed)
    return logits, caches, aux


def forward_prefix(params, cfg: ArchConfig, inputs, scheme: str,
                   seed: jax.Array, *, n_prefix: int, caches=None,
                   mode: str = "decode", pos=None, active=None,
                   block_table=None, paged_kernel=False,
                   unroll_stages=False):
    """Early-exit forward: the first `n_prefix` layers + final norm + head.

    This is the self-speculative DRAFT stack (serve/spec_decode.py): it
    reuses the full model's (possibly prequantized) parameters and shared LM
    head — no second model — and runs layers with the same ids/site seeds as
    the full forward, so a draft layer computes bit-for-bit what the same
    layer computes inside the full stack. `caches` must be a prefix-shaped
    pytree (kv_pool.init_cache with specs=prefix_specs(cfg, n_prefix))."""
    if cfg.enc_dec:
        raise NotImplementedError("enc-dec draft stacks are not supported")
    specs = prefix_specs(cfg, n_prefix)
    sub = {"stages": prefix_stage_params(params, cfg, n_prefix)}
    x = embed_lookup(params["embed"], inputs["tokens"])
    b, s = x.shape[:2]
    if mode == "decode":
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
        positions = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    else:
        positions = jnp.arange(s)[None, :]
    x, new_caches, aux = _run_stages(sub, x, cfg, scheme, seed, mode=mode,
                                     caches=caches, pos=pos,
                                     positions=positions, stages=specs,
                                     active=active, block_table=block_table,
                                     paged_kernel=paged_kernel,
                                     unroll_stages=unroll_stages)
    x = norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    logits = lm_head(x, head_weight(params, cfg), cfg.quantize_lm_head,
                     scheme, seed)
    return logits, new_caches, aux


# --------------------------------------------------------------------------
# whisper-style encoder-decoder
# --------------------------------------------------------------------------

def _encdec_extra_init(cfg, key):
    """Decoder stack + cross-attention params; `stages` holds the encoder."""
    ks = jax.random.split(key, 4)
    dec_pattern = (("gqa", "mlp"),)

    def one(k):
        p = _layer_init(k, dec_pattern[0], cfg)
        kx = jax.random.fold_in(k, 7)
        p["xattn"] = A.gqa_init(kx, cfg)
        p["nx"] = norm_init(cfg.d_model, cfg.norm)
        return {"l0": p}

    return {
        "dec_embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
        "dec_stages": [jax.vmap(one)(jax.random.split(ks[1], cfg.n_layers))],
        "dec_final_norm": norm_init(cfg.d_model, cfg.norm),
        "dec_head": linear_init(ks[2], cfg.vocab, cfg.d_model, scale=0.02),
    }


DEC_STAGES = lambda cfg: [((("gqa", "mlp"),), cfg.n_layers)]


def _encdec_forward(params, cfg, inputs, scheme, seed, *, caches, mode, pos,
                    head: bool = True):
    if mode == "decode":
        enc_out = caches["enc_out"]
        x = embed_lookup(params["dec_embed"], inputs["tokens"])
        posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (x.shape[0],))
        positions = posb[:, None] + jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]
        dec_params = {"stages": params["dec_stages"]}
        x, new_dec, _ = _run_stages(dec_params, x, cfg, scheme, seed,
                                    mode="decode", caches=caches["dec"],
                                    pos=posb, positions=positions,
                                    enc_out=enc_out, stages=DEC_STAGES(cfg))
        x = norm(x, params["dec_final_norm"], cfg.norm, cfg.norm_eps)
        logits = lm_head(x, params["dec_head"], cfg.quantize_lm_head, scheme, seed)
        return logits, {"enc_out": enc_out, "dec": new_dec}, jnp.zeros((), jnp.float32)

    # encoder (bidirectional over stub audio embeddings)
    xe = inputs["embeds"].astype(jnp.bfloat16)
    se = xe.shape[1]
    enc_x, _, _ = _run_stages(params, xe, cfg, scheme, seed, mode="encode",
                              positions=jnp.arange(se)[None, :])
    enc_out = norm(enc_x, params["final_norm"], cfg.norm, cfg.norm_eps)

    # decoder (causal self-attn + cross-attn)
    x = embed_lookup(params["dec_embed"], inputs["tokens"])
    sd = x.shape[1]
    dec_params = {"stages": params["dec_stages"]}
    x, new_dec, _ = _run_stages(dec_params, x, cfg, scheme, seed, mode=mode,
                                caches=caches["dec"] if caches else None,
                                positions=jnp.arange(sd)[None, :],
                                enc_out=enc_out, stages=DEC_STAGES(cfg))
    x = norm(x, params["dec_final_norm"], cfg.norm, cfg.norm_eps)
    new_caches = ({"enc_out": enc_out, "dec": new_dec} if caches else None)
    if not head:
        return x, new_caches, jnp.zeros((), jnp.float32)
    logits = lm_head(x, params["dec_head"], cfg.quantize_lm_head, scheme, seed)
    return logits, new_caches, jnp.zeros((), jnp.float32)


def init_encdec_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int):
    dec = []
    one = {"l0": _layer_cache(("gqa", "mlp"), cfg, batch, max_len)}
    dec.append(jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers, *x.shape)), one))
    return {"enc_out": jnp.zeros((batch, enc_len, cfg.d_model), jnp.bfloat16),
            "dec": dec}


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def lm_loss(params, cfg, batch, scheme, seed, aux_weight: float = 0.01):
    """Fused chunked head+CE (never materializes (tokens, vocab) logits)."""
    hidden, _, aux = forward(params, cfg, batch, scheme, seed, mode="train",
                             head=False)
    ce = chunked_head_ce(hidden, head_weight(params, cfg), batch["labels"],
                         cfg.quantize_lm_head, scheme, seed)
    return ce + aux_weight * aux
