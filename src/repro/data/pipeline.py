"""Deterministic, resumable, shardable data pipeline.

No external datasets are available offline, so the corpus is synthetic but
*language-like*: a per-document Zipfian unigram mixed with an order-2 Markov
bigram kernel, which gives training curves with meaningful structure (models
must learn bigram statistics; quantization-induced loss gaps are measurable,
which is all the paper's small-scale ablations need).

Determinism/resume contract: `batch_at(step)` is a pure function of
(seed, step, shard) — restoring a checkpoint at step k reproduces the exact
token stream with no iterator state to persist. Sharding slices the global
batch by (shard_id, num_shards) for multi-host input pipelines.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    d_model: int = 0         # >0 -> also emit stub "embeds" ([audio]/[vlm])
    emit_embeds: bool = False


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return (p / p.sum()).astype(np.float32)


class SyntheticCorpus:
    """Stateless batch generator; all randomness derives from (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = jax.random.PRNGKey(cfg.seed)
        self._probs = jnp.asarray(_zipf_probs(cfg.vocab, cfg.zipf_a))
        # fixed random bigram shift: next-token dist = zipf(perm[token] mixed)
        self._perm = jax.random.permutation(jax.random.fold_in(base, 1), cfg.vocab)

    def batch_at(self, step: int, shard_id: int = 0, num_shards: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b = cfg.global_batch // num_shards
        key = jax.random.fold_in(jax.random.fold_in(
            jax.random.PRNGKey(cfg.seed), step), shard_id)
        k1, k2, k3 = jax.random.split(key, 3)
        # unigram draw
        uni = jax.random.categorical(
            k1, jnp.log(self._probs)[None, None, :], shape=(b, cfg.seq_len + 1))
        # order-2 structure: with p=0.5, token t+1 = perm[token t]
        use_bigram = jax.random.bernoulli(k2, 0.5, (b, cfg.seq_len + 1))

        def roll(tok_prev, inp):
            u, ub = inp
            t = jnp.where(ub, self._perm[tok_prev], u)
            return t, t

        _, toks = jax.lax.scan(
            roll, uni[:, 0], (uni[:, 1:].T, use_bigram[:, 1:].T))
        toks = jnp.concatenate([uni[:, :1], toks.T], axis=1)
        batch = {"tokens": toks[:, :-1].astype(jnp.int32),
                 "labels": toks[:, 1:].astype(jnp.int32)}
        if cfg.emit_embeds:
            batch["embeds"] = jax.random.normal(
                k3, (b, cfg.seq_len, cfg.d_model), jnp.bfloat16) * 0.3
        return batch


def byte_corpus_from_text(text: str, cfg: DataConfig):
    """Tiny real-data alternative: UTF-8 bytes of a supplied text, chunked
    deterministically. Used by examples when a local file is provided."""
    raw = np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32)

    class _ByteCorpus:
        def batch_at(self, step: int, shard_id: int = 0, num_shards: int = 1):
            b = cfg.global_batch // num_shards
            rng = np.random.RandomState((cfg.seed, step, shard_id).__hash__() % 2**31)
            idx = rng.randint(0, len(raw) - cfg.seq_len - 1, size=b)
            toks = np.stack([raw[i: i + cfg.seq_len + 1] for i in idx])
            return {"tokens": jnp.asarray(toks[:, :-1]),
                    "labels": jnp.asarray(toks[:, 1:])}

    return _ByteCorpus()
