"""Sampled NVFP4 quantization-health probe.

Low-precision pre-training stays on-curve only while quantization error
stays in regime — NVFP4 training reports track per-site error, block-scale
saturation, and outlier behavior continuously. This probe taps the SAME
quantizers the hot paths use (core/quant.py forward kinds, core/ms_eden.py
and `quant_sr` for the backward estimators) on a rotating sample of weight
sites and reports, per site:

  - relative quantization MSE (mean sq. reconstruction error / signal
    power) for the scheme's forward weight quantizer, for MS-EDEN (paper
    Alg. 1, reconstructed in ORIGINAL space via the inverse rotation), and
    for plain SR over the same rotated tensor — the paper's Table 1
    comparison, live on real weights;
  - e4m3 block-scale saturation (fraction of group scales at the E4M3 max,
    448) and element clip fraction (|x| beyond the FP4 grid reach of its
    group scale — MS-EDEN's s* = (1/0.93)·6·(16/17) clips ~0.7% of a
    Gaussian BY DESIGN, so a healthy value is small-but-nonzero);
  - RHT outlier mass: the energy fraction carried by post-rotation
    elements beyond 4x the tensor RMS (the rotation should have crushed
    heavy tails — growth here means the Hadamard block no longer mixes the
    outlier directions).

Overhead discipline (docs/CONVENTIONS.md §6): the probe runs at the HOST
step boundary — `Trainer` calls it every `every_n` steps, `prequantize`
once per engine build — never inside a jitted body, and the single
`jax.device_get` per probe is the only host sync it adds. Disabled is the
default and provably free: `Trainer.probe = None` costs one `is None` test
per step; `every_n = 0` makes `should_sample` constant-False (manual
`probe_params` calls still work, which is how prequant uses it).

Site sampling is deterministic: sites sort by parameter path and rotate
with the step counter, so run N and a resumed run N' probe identical
(site, layer) choices — probe output diffs are signal, not sampling noise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import ms_eden as M
from repro.core import quant as Q
from repro.core import rht as R
from repro.core import schemes as S
from repro.obs.metrics import MetricsRegistry, default_registry
from repro.serve.prequant import QUANT_KEYS, _leaf_key

#: forward weight-quantizer kinds -> quantizer (core/schemes.py fwd_w)
_FWD = {
    "rtn": Q.quant_rtn,
    "fos": Q.quant_four_over_six,
    "square": Q.quant_square_block,
}


def _mse_rel(x, rec):
    return jnp.mean((rec - x) ** 2) / (jnp.mean(x * x) + 1e-30)


def _clip_frac(x, qt):
    """Fraction of elements beyond the FP4 grid reach of their group scale
    (measured against the pre-snap tensor in the quantizer's own space)."""
    denom = jnp.repeat(qt.scales, F.GROUP, axis=-1) * qt.gscale
    clipped = jnp.abs(x) > F.FP4_MAX * denom
    return jnp.mean(jnp.where(denom > 0, clipped, False).astype(jnp.float32))


def _sat_frac(qt):
    return jnp.mean((qt.scales >= F.FP8_MAX).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("fwd_kind",))
def _health(w, rht_key, sr_key, fwd_kind: str):
    """All health scalars for one 2D site, one device round-trip.

    MS-EDEN and SR are measured in ORIGINAL space (reconstruction through
    the inverse rotation) so the two backward estimators are directly
    comparable — the rotated-space error equals the original-space error
    only up to the rotation, and SR without RHT would face a different
    input distribution entirely.
    """
    x = w.astype(jnp.float32)
    out = {}
    if fwd_kind != "none":
        qt = _FWD[fwd_kind](x)
        out["fwd_mse_rel"] = _mse_rel(x, Q.dequant(qt))
        out["fwd_scale_sat_frac"] = _sat_frac(qt)
        out["fwd_clip_frac"] = _clip_frac(x, qt)
    x_rot = R.rht(x, rht_key)
    me = M.ms_eden(x, rht_key, sr_key)
    out["ms_eden_mse_rel"] = _mse_rel(x, M.ms_eden_dequant(me, rotated=False))
    out["ms_eden_scale_sat_frac"] = _sat_frac(me.qt)
    out["ms_eden_clip_frac"] = _clip_frac(x_rot, me.qt)
    qs = Q.quant_sr(x_rot, sr_key)
    out["sr_mse_rel"] = _mse_rel(x, R.rht_inv(Q.dequant(qs), rht_key))
    out["sr_scale_sat_frac"] = _sat_frac(qs)
    out["sr_clip_frac"] = _clip_frac(x_rot, qs)
    energy = x_rot * x_rot
    rms = jnp.sqrt(jnp.mean(energy) + 1e-30)
    out["rht_outlier_mass"] = (
        jnp.sum(jnp.where(jnp.abs(x_rot) > 4.0 * rms, energy, 0.0))
        / (jnp.sum(energy) + 1e-30))
    return out


class QuantProbe:
    """Rotating-sample quantization-health tap over a params pytree.

    `every_n = 0` (default): never auto-samples (`should_sample` is False);
    explicit `probe_params` calls — the prequant path — still probe.
    """

    def __init__(self, scheme: str = "quartet2", every_n: int = 0,
                 max_sites: int = 8, base_seed: int = 0,
                 registry: MetricsRegistry | None = None):
        self.scheme = scheme
        self.fwd_kind = S.get(scheme).fwd_w
        self.every_n = every_n
        self.max_sites = max_sites
        self.base_seed = base_seed
        self.registry = registry if registry is not None else default_registry()
        labels = ("site", "phase", "quantizer")
        self._mse = self.registry.gauge(
            "nvfp4_quant_mse_rel",
            "relative quantization MSE at a sampled weight site", labels)
        self._sat = self.registry.gauge(
            "nvfp4_scale_saturation_frac",
            "fraction of e4m3 group scales at the E4M3 max", labels)
        self._clip = self.registry.gauge(
            "nvfp4_clip_frac",
            "fraction of elements beyond their group's FP4 reach", labels)
        self._outlier = self.registry.gauge(
            "nvfp4_rht_outlier_mass",
            "post-RHT energy fraction beyond 4x RMS", ("site", "phase"))
        self._samples = self.registry.counter(
            "nvfp4_probe_samples_total", "per-site probe evaluations",
            ("phase",))

    def should_sample(self, step: int) -> bool:
        return self.every_n > 0 and step % self.every_n == 0

    # ---- site discovery --------------------------------------------------

    @staticmethod
    def sites(params) -> list[tuple[str, jax.Array]]:
        """Deterministic (path, leaf) list of quantized weight sites: the
        QUANT_KEYS leaves prequant/qlinear feed through NVFP4, 2D or
        stacked, raw (unpacked) arrays only, sorted by path."""
        tree = params.get("stages", params) if isinstance(params, dict) else params
        found: list[tuple[str, jax.Array]] = []

        def visit(path, leaf):
            if (hasattr(leaf, "ndim") and leaf.ndim >= 2
                    and not hasattr(leaf, "codes_packed")
                    and _leaf_key(path) in QUANT_KEYS):
                name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                                for p in path)
                found.append((name, leaf))
            return leaf

        jax.tree_util.tree_map_with_path(visit, tree)
        found.sort(key=lambda kv: kv[0])
        return found

    # ---- probing ---------------------------------------------------------

    def probe_params(self, params, step: int = 0,
                     phase: str = "train") -> dict:
        """Probe up to `max_sites` sites (rotating with `step`), record the
        gauges, and return {site: {metric: float}}. One `device_get`."""
        sites = self.sites(params)
        if not sites:
            return {}
        k = min(self.max_sites, len(sites))
        period = max(self.every_n, 1)
        start = ((step // period) * k) % len(sites)
        key = jax.random.fold_in(jax.random.PRNGKey(self.base_seed), step)
        pending = {}
        for j in range(k):
            name, leaf = sites[(start + j) % len(sites)]
            if leaf.shape[-1] % F.GROUP:
                continue  # not NVFP4-groupable; qlinear pads, the probe skips
            mat = leaf
            if leaf.ndim > 2:
                flat = leaf.reshape((-1, *leaf.shape[-2:]))
                mat = flat[(step // period + j) % flat.shape[0]]
            site_key = jax.random.fold_in(key, j)
            rht_key, sr_key = jax.random.split(site_key)
            pending[name] = _health(mat, rht_key, sr_key, self.fwd_kind)
        results = jax.device_get(pending)  # the probe's ONLY host sync
        for name, vals in results.items():
            out = {m: float(v) for m, v in vals.items()}
            results[name] = out
            for metric, v in out.items():
                if metric == "rht_outlier_mass":
                    self._outlier.labels(site=name, phase=phase).set(v)
                    continue
                quantizer, field = metric.split("_", 1)
                if quantizer == "ms":  # ms_eden_*
                    quantizer, field = "ms_eden", metric[len("ms_eden_"):]
                gauge = {"mse_rel": self._mse,
                         "scale_sat_frac": self._sat,
                         "clip_frac": self._clip}[field]
                gauge.labels(site=name, phase=phase, quantizer=quantizer).set(v)
            self._samples.labels(phase=phase).inc()
        return results
