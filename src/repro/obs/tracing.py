"""Per-request lifecycle tracing for the serving engine.

Each `Request` admitted to a `ServeEngine` with observability enabled gets a
`RequestTrace`: an ordered list of timestamped spans recorded at the
engine's host-side transition points —

    queued ──admit──▶ prefill ──first token──▶ decode ──▶ retired
       │                  │                       │
       └─ rejected        └─ cancelled ◀──────────┘
          (event)            (terminal event, open span closed)

plus point events (`prefill_skipped` for prefix-cache hits, `rejected` with
a reason) and the frontend lifecycle spans (`streamed`, `disconnected`,
`requeued`, `drained` — serve/frontend.py). Timestamps are monotonic floats
stamped by the engine's injectable clock (`EngineConfig.clock`;
`time.perf_counter` by default — the same clock that stamps `arrival_s`),
so span boundaries are directly comparable to `RequestResult.finish_s` and
fake-clock tests never need real sleeps.

Traces are host-only bookkeeping: no device interaction, no effect on any
compiled step (tests/test_obs.py asserts greedy streams are bitwise
unchanged with tracing on). Finished traces land in a bounded `TraceSink`
which exports structured JSONL (`write_jsonl`) and latency aggregates
(`aggregates`: TTFT / queue-wait / per-token decode percentiles).
"""

from __future__ import annotations

import json

#: span / terminal-state names (the JSONL schema's `span` field)
QUEUED, PREFILL, DECODE = "queued", "prefill", "decode"
RETIRED, CANCELLED, REJECTED = "retired", "cancelled", "rejected"
#: frontend lifecycle (serve/frontend.py): `streamed` is an interval span
#: opened at the first token delivered to a live consumer (auto-closed by
#: whatever terminal transition follows); `disconnected` / `requeued` are
#: terminal states for consumer-vanished / visibility-timeout cancellations;
#: `drained` marks a completed drain (point event, req_id -1).
STREAMED = "streamed"
DISCONNECTED, REQUEUED, DRAINED = "disconnected", "requeued", "drained"


class Span:
    """One named interval: [t0, t1] (t1 is None while open; t0 == t1 for
    point events) plus free-form attrs."""

    __slots__ = ("name", "t0", "t1", "attrs")

    def __init__(self, name, t0, attrs=None):
        self.name = name
        self.t0 = t0
        self.t1 = None
        self.attrs = attrs or {}

    @property
    def dur_s(self):
        return None if self.t1 is None else self.t1 - self.t0

    def to_event(self, req_id, state):
        ev = {"req_id": req_id, "span": self.name, "t0": self.t0,
              "t1": self.t1, "dur_s": self.dur_s, "state": state}
        ev.update(self.attrs)
        return ev


class RequestTrace:
    __slots__ = ("req_id", "spans", "state", "_open")

    def __init__(self, req_id: int):
        self.req_id = req_id
        self.spans: list[Span] = []
        self.state = None        # terminal: retired/cancelled/rejected
        self._open: dict[str, Span] = {}

    # ---- recording -------------------------------------------------------

    def begin(self, name: str, t: float, **attrs) -> Span:
        span = Span(name, t, attrs)
        self.spans.append(span)
        self._open[name] = span
        return span

    def end(self, name: str, t: float, **attrs) -> Span | None:
        span = self._open.pop(name, None)
        if span is not None:
            span.t1 = t
            span.attrs.update(attrs)
        return span

    def event(self, name: str, t: float, **attrs) -> Span:
        span = Span(name, t, attrs)
        span.t1 = t
        self.spans.append(span)
        return span

    def finish(self, state: str, t: float) -> None:
        """Terminal transition: closes any still-open spans at `t` and
        records the terminal state as a point event."""
        for name in list(self._open):
            self.end(name, t)
        self.state = state
        self.event(state, t)

    # ---- derived latencies ----------------------------------------------

    def span(self, name: str) -> Span | None:
        for s in self.spans:
            if s.name == name:
                return s
        return None

    @property
    def queue_wait_s(self) -> float | None:
        s = self.span(QUEUED)
        return s.dur_s if s is not None else None

    @property
    def ttft_s(self) -> float | None:
        """submit -> first sampled token (end of the prefill span)."""
        q, p = self.span(QUEUED), self.span(PREFILL)
        if q is None or p is None or p.t1 is None:
            return None
        return p.t1 - q.t0

    def decode_tok_s(self, n_tokens: int) -> float | None:
        """Mean seconds per decode-step token: the decode span covers the
        n_tokens - 1 tokens sampled AFTER the first (prefill) token."""
        d = self.span(DECODE)
        if d is None or d.dur_s is None:
            return None
        return d.dur_s / max(n_tokens - 1, 1)

    # ---- export ----------------------------------------------------------

    def events(self) -> list[dict]:
        return [s.to_event(self.req_id, self.state) for s in self.spans]


class TraceSink:
    """Bounded collector of finished traces (oldest dropped past capacity;
    `dropped` counts them so aggregates are honest about truncation)."""

    def __init__(self, max_traces: int = 4096):
        self.max_traces = max_traces
        self.traces: list[RequestTrace] = []
        self.dropped = 0

    def append(self, trace: RequestTrace) -> None:
        self.traces.append(trace)
        if len(self.traces) > self.max_traces:
            self.traces.pop(0)
            self.dropped += 1

    def write_jsonl(self, path: str) -> int:
        """One JSON event per line, traces in completion order. Returns the
        number of events written."""
        n = 0
        with open(path, "w") as f:
            for tr in self.traces:
                for ev in tr.events():
                    f.write(json.dumps(ev) + "\n")
                    n += 1
        return n

    def aggregates(self) -> dict:
        """Percentile summary over RETIRED traces (rejections/cancellations
        have no stable latency semantics)."""
        done = [t for t in self.traces if t.state == RETIRED]
        out = {"retired": len(done), "total": len(self.traces),
               "dropped": self.dropped}
        series = {
            "queue_wait_s": [t.queue_wait_s for t in done],
            "ttft_s": [t.ttft_s for t in done],
            "decode_tok_s": [
                t.decode_tok_s(t.span(DECODE).attrs.get("tokens", 1))
                for t in done],
        }
        for name, vals in series.items():
            vals = sorted(v for v in vals if v is not None)
            out[name] = _pctiles(vals)
        return out


def _pctiles(sorted_vals: list[float]) -> dict:
    if not sorted_vals:
        return {"count": 0}
    return {"count": len(sorted_vals),
            "mean": sum(sorted_vals) / len(sorted_vals),
            "p50": _pct(sorted_vals, 0.50),
            "p95": _pct(sorted_vals, 0.95),
            "p99": _pct(sorted_vals, 0.99),
            "max": sorted_vals[-1]}


def _pct(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank-with-interpolation percentile on a sorted list."""
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac
