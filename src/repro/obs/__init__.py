"""Unified observability layer: metrics registry, request tracing,
engine instrumentation, and NVFP4 quantization-health probes.

Dependency-free by design (stdlib + the repo's own jax surface in the
probe); see docs/CONVENTIONS.md §6 for the instrumentation boundary rule.
"""

from repro.obs.instrumentation import (NULL, Instrumentation,
                                       STAT_FLOAT_KEYS, STAT_INT_KEYS,
                                       STAT_KEYS, legacy_stats_dict)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               default_registry)
from repro.obs.tracing import RequestTrace, Span, TraceSink

__all__ = [
    "NULL", "Instrumentation", "STAT_FLOAT_KEYS", "STAT_INT_KEYS",
    "STAT_KEYS", "legacy_stats_dict", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "default_registry", "RequestTrace", "Span",
    "TraceSink",
]
