"""One observability hook object threaded through the serving engine.

`Instrumentation` is the single point every serve-layer component reports
into: the engine threads it via `EngineConfig(obs=...)` and hands it to the
KV pool / prefix cache / scheduler / spec-decode paths it owns. It bundles

  - registry-backed engine counters that replace the raw `engine.stats`
    dict behind a backward-compatible `MutableMapping` view (`stats_view`),
  - per-request lifecycle traces (obs/tracing.py) recorded at the engine's
    host transition points, collected in a bounded `TraceSink`,
  - per-tick gauges (slot occupancy, free blocks per shard, pool
    fragmentation, queue depth/aging, cached radix nodes),
  - step-duration histograms split by `phase`: `dispatch` (host returned
    from enqueue) vs `synced` (device finished, cache writes included),
  - spec-decode acceptance histograms and pool/cache event counters,
  - an optional NVFP4 quantization-health probe (obs/quant_probe.py).

Disabled mode: `EngineConfig(obs=None)` resolves to the `NULL` sentinel —
a slotted singleton whose only attribute is `enabled = False`. Every engine
hook site guards with `if self.obs.enabled:` so the disabled hot path costs
one attribute read and allocates NOTHING (no trace objects, no metric
children, no dict churn); tests/test_obs.py pins both properties.

One `Instrumentation` serves ONE engine (trace lifecycles and the stats
view are per-engine state). Point several engines' Instrumentation at a
shared `MetricsRegistry` to get one combined snapshot — the `engine` label
keeps their series apart.
"""

from __future__ import annotations

import itertools
from collections.abc import MutableMapping

from repro.obs import tracing
from repro.obs.metrics import MetricsRegistry, default_registry

#: legacy `engine.stats` keys, in their historical dict order (the stats
#: view iterates in this order so `for k in eng.stats` is unchanged);
#: "cancelled" is new in the observability PR (engine.cancel()).
STAT_FLOAT_KEYS = ("prefill_s", "decode_s")
STAT_INT_KEYS = ("prefill_tokens", "decode_tokens", "decode_steps", "ticks",
                 "admitted", "rejected", "finished", "spec_rounds",
                 "draft_tokens", "accepted_tokens", "prefill_steps",
                 "prefill_skipped_tokens", "prefix_hits", "cancelled",
                 "handoffs")
STAT_KEYS = STAT_FLOAT_KEYS + STAT_INT_KEYS


def legacy_stats_dict() -> dict:
    """The plain-dict stats store used when observability is disabled."""
    d = {k: 0.0 for k in STAT_FLOAT_KEYS}
    d.update({k: 0 for k in STAT_INT_KEYS})
    return d


class NullInstrumentation:
    """Disabled-mode sentinel: engine hook sites check `.enabled` and do
    nothing else. Slotted and attribute-free so any accidental use as a
    real Instrumentation fails loudly instead of silently recording."""

    __slots__ = ()
    enabled = False


NULL = NullInstrumentation()

_ENGINE_IDS = itertools.count()

#: spec-decode acceptance histogram buckets: accepted DRAFT tokens per
#: (slot, round) — small integers, one bucket each up to 16.
_SPEC_BUCKETS = tuple(float(i) for i in range(17))


class Instrumentation:
    enabled = True

    def __init__(self, registry: MetricsRegistry | None = None,
                 engine_label: str | None = None, max_traces: int = 4096,
                 quant_probe=None):
        self.registry = registry if registry is not None else default_registry()
        self.engine_label = (engine_label if engine_label is not None
                             else str(next(_ENGINE_IDS)))
        self.reg = self.registry.child(engine=self.engine_label)
        self.quant_probe = quant_probe
        self.trace_sink = tracing.TraceSink(max_traces=max_traces)
        self._live: dict[int, tracing.RequestTrace] = {}
        reg = self.reg

        # -- engine stat counters (legacy engine.stats, registry-backed) --
        self._stat_cells = {}
        for k in STAT_KEYS:
            unit = "seconds" if k in STAT_FLOAT_KEYS else None
            name = (f"serve_engine_{k[:-2]}_seconds_total" if unit
                    else f"serve_engine_{k}_total")
            c = reg.counter(name, f"engine stat '{k}'")
            self._stat_cells[k] = c.labels()  # materialize the series now

        # -- request lifecycle histograms ---------------------------------
        self.queue_wait_hist = reg.histogram(
            "serve_request_queue_wait_seconds",
            "submit -> slot admission")
        self.ttft_hist = reg.histogram(
            "serve_request_ttft_seconds",
            "submit -> first sampled token")
        self.decode_tok_hist = reg.histogram(
            "serve_request_decode_token_seconds",
            "mean per-token decode latency of a retired request")
        self.latency_hist = reg.histogram(
            "serve_request_latency_seconds",
            "submit -> retirement (RequestResult.latency_s)")

        # -- step durations: dispatch (enqueue returned) vs synced (device
        #    done, KV-cache writes included) -------------------------------
        self.prefill_step_hist = reg.histogram(
            "serve_prefill_step_seconds",
            "one prefill chunk; phase=dispatch|synced", labels=("phase",))
        self.decode_step_hist = reg.histogram(
            "serve_decode_step_seconds",
            "one batched decode step; phase=dispatch|synced",
            labels=("phase",))

        # -- per-tick gauges ----------------------------------------------
        self.queue_depth = reg.gauge(
            "serve_queue_depth", "queued requests at tick start")
        self.queue_age = reg.gauge(
            "serve_queue_age_ticks", "max queued_ticks over the queue")
        self.queue_slack = reg.gauge(
            "serve_queue_min_slack_seconds",
            "tightest deadline slack in the queue (LatencyPolicy)")
        self.slots_gauge = reg.gauge(
            "serve_slots", "slots by state", labels=("state",))
        self.pool_free_blocks = reg.gauge(
            "serve_pool_free_blocks", "free blocks per shard",
            labels=("shard",))
        self.pool_frag_tokens = reg.gauge(
            "serve_pool_fragmentation_tokens",
            "allocated-but-unoccupied token capacity (internal frag)")
        self.pool_frag_ratio = reg.gauge(
            "serve_pool_fragmentation_ratio",
            "fragmentation_tokens / allocated token capacity")
        self.cache_nodes = reg.gauge(
            "serve_prefix_cache_nodes", "radix nodes (cached blocks)")

        # -- pool / cache event counters ----------------------------------
        self.pool_alloc = reg.counter(
            "serve_pool_blocks_allocated_total", "blocks taken from free lists")
        self.pool_freed = reg.counter(
            "serve_pool_blocks_freed_total", "blocks returned to free lists")
        self.pool_reclaimed = reg.counter(
            "serve_pool_blocks_reclaimed_total",
            "out-of-window blocks reclaimed mid-sequence")
        self.pool_cow = reg.counter(
            "serve_pool_cow_total", "copy-on-write block copies")
        self.cache_lookups = reg.counter(
            "serve_prefix_cache_lookups_total", "admissions consulting the cache")
        self.cache_hits = reg.counter(
            "serve_prefix_cache_hits_total", "admissions that adopted a prefix")
        self.cache_hit_tokens = reg.counter(
            "serve_prefix_cache_hit_tokens_total", "prompt tokens served from cache")
        self.cache_inserted = reg.counter(
            "serve_prefix_cache_inserted_blocks_total", "blocks newly cached")
        self.cache_evicted = reg.counter(
            "serve_prefix_cache_evicted_blocks_total", "cached blocks evicted")

        # -- hierarchical cache tiers / disaggregation ---------------------
        self.cache_spilled = reg.counter(
            "serve_prefix_cache_spilled_blocks_total",
            "evicted blocks snapshotted to the host tier instead of dropped")
        self.cache_swapped_in = reg.counter(
            "serve_prefix_cache_swapped_in_blocks_total",
            "host-tier blocks copied back into device pools")
        self.cache_swapin_hist = reg.histogram(
            "serve_prefix_cache_swap_in_seconds",
            "host->device swap-in dispatch time per materialize call")
        self.cache_replicated = reg.counter(
            "serve_prefix_cache_replicated_blocks_total",
            "hot-prefix blocks copied into peer shards via the host tier")
        self.host_tier_bytes = reg.gauge(
            "serve_prefix_cache_host_bytes",
            "bytes held by host-RAM prefix snapshots")
        # (handoff exports ride the regular stats keys: "handoffs" in
        # STAT_INT_KEYS -> serve_engine_handoffs_total above)

        # -- speculative decoding -----------------------------------------
        self.spec_accepted_hist = reg.histogram(
            "serve_spec_accepted_per_round",
            "accepted draft tokens per (slot, round)",
            buckets=_SPEC_BUCKETS)

        # -- structured rejections / cancellations (reason-labelled; the
        #    legacy stats view keeps its fixed key set, so per-reason
        #    breakdown lives here instead of new stats keys) ---------------
        self.reject_reasons = reg.counter(
            "serve_rejections_total",
            "engine admission rejections by reason "
            "(queue_full | unservable)", labels=("reason",))
        self.cancel_reasons = reg.counter(
            "serve_cancellations_total",
            "engine cancellations by reason "
            "(cancelled | disconnected | requeued)", labels=("reason",))

        # -- streaming frontend (serve/frontend.py) -----------------------
        self.streams_open = reg.gauge(
            "serve_frontend_streams_open", "live SSE streams")
        self.streamed_tokens = reg.counter(
            "serve_frontend_streamed_tokens_total",
            "tokens flushed to SSE streams")
        self.frontend_rejects = reg.counter(
            "serve_frontend_rejections_total",
            "frontend-side rejections by reason (backpressure | "
            "rate_limited | budget_exhausted | draining)",
            labels=("reason",))

    # ---- engine.stats compatibility -------------------------------------

    def stats_view(self) -> "_StatsView":
        return _StatsView(self._stat_cells)

    # ---- request lifecycle ----------------------------------------------

    def on_submit(self, req, t: float) -> None:
        tr = tracing.RequestTrace(req.req_id)
        tr.begin(tracing.QUEUED, t)
        self._live[req.req_id] = tr

    def on_reject(self, req, reason: str, t: float) -> None:
        self.reject_reasons.labels(reason=reason).inc()
        tr = tracing.RequestTrace(req.req_id)  # -1: rejected pre-id
        tr.finish(tracing.REJECTED, t)
        tr.spans[-1].attrs["reason"] = reason
        self.trace_sink.append(tr)

    def on_admit(self, req, slot: int, skipped: int, t: float) -> None:
        tr = self._live.get(req.req_id)
        if tr is None:
            return
        tr.end(tracing.QUEUED, t)
        self.queue_wait_hist.observe(t - tr.span(tracing.QUEUED).t0)
        if skipped:
            tr.event("prefill_skipped", t, tokens=skipped)
        tr.begin(tracing.PREFILL, t, slot=slot)

    def on_first_token(self, req, t: float) -> None:
        tr = self._live.get(req.req_id)
        if tr is None:
            return
        tr.end(tracing.PREFILL, t)
        tr.begin(tracing.DECODE, t)
        ttft = tr.ttft_s
        if ttft is not None:
            self.ttft_hist.observe(ttft)

    def on_retire(self, req, result, n_tokens: int, t: float) -> None:
        """Close the trace and surface its latencies on the result."""
        tr = self._live.pop(req.req_id, None)
        if tr is None:
            return
        tr.end(tracing.DECODE, t, tokens=n_tokens)
        tr.finish(tracing.RETIRED, t)
        result.queue_wait_s = tr.queue_wait_s
        result.ttft_s = tr.ttft_s
        result.decode_tok_s = tr.decode_tok_s(n_tokens)
        if result.decode_tok_s is not None:
            self.decode_tok_hist.observe(result.decode_tok_s)
        self.latency_hist.observe(result.latency_s)
        self.trace_sink.append(tr)

    def on_cancel(self, req, t: float, reason: str = "cancelled") -> None:
        """Cancellation terminal. `reason` picks the terminal span name:
        "disconnected" / "requeued" (the frontend's lifecycle states) map
        to their own spans, anything else lands as `cancelled`."""
        self.cancel_reasons.labels(reason=reason).inc()
        tr = self._live.pop(req.req_id, None)
        if tr is None:
            return
        state = {"disconnected": tracing.DISCONNECTED,
                 "requeued": tracing.REQUEUED}.get(reason, tracing.CANCELLED)
        tr.finish(state, t)
        self.trace_sink.append(tr)

    # ---- streaming frontend (serve/frontend.py) --------------------------
    # Trace-touching hooks (`_live`) are engine-thread-only — the frontend
    # bridge invokes them from the engine tick thread (token hook / command
    # queue). Metric-only hooks are lock-protected and safe from the asyncio
    # thread (docs/CONVENTIONS.md §8).

    def on_stream_open(self, req, t: float) -> None:
        """First token delivered to a live consumer: opens the `streamed`
        span (auto-closed by whichever terminal transition follows —
        retire, disconnect, requeue, cancel). Engine-thread only."""
        self.streams_open.inc(1)
        tr = self._live.get(req.req_id)
        if tr is not None and tr.span(tracing.STREAMED) is None:
            tr.begin(tracing.STREAMED, t)

    def on_stream_close(self) -> None:
        self.streams_open.inc(-1)

    def on_stream_tokens(self, n: int) -> None:
        if n:
            self.streamed_tokens.inc(n)

    def on_frontend_reject(self, reason: str) -> None:
        """Frontend-side rejection: no engine Request exists yet (drain
        mode, tenant rate limit/budget, admission backpressure), so this
        books only the reason-labelled counter — no trace."""
        self.frontend_rejects.labels(reason=reason).inc()

    def on_drain(self, t: float) -> None:
        """Drain completed (engine thread): point `drained` marker trace."""
        tr = tracing.RequestTrace(-1)
        tr.finish(tracing.DRAINED, t)
        self.trace_sink.append(tr)

    # ---- step timing -----------------------------------------------------

    def on_prefill_step(self, dispatch_s: float, synced_s: float) -> None:
        self.prefill_step_hist.labels(phase="dispatch").observe(dispatch_s)
        self.prefill_step_hist.labels(phase="synced").observe(synced_s)

    def on_decode_step(self, dispatch_s: float, synced_s: float) -> None:
        self.decode_step_hist.labels(phase="dispatch").observe(dispatch_s)
        self.decode_step_hist.labels(phase="synced").observe(synced_s)

    # ---- per-tick gauges -------------------------------------------------

    def on_tick(self, eng) -> None:
        """Engine tick boundary: refresh occupancy/pool/cache gauges.
        Host-side reads only — no device interaction (CONVENTIONS §6)."""
        counts = {"free": 0, "prefill": 0, "decode": 0}
        for s in eng.slots:
            counts[s.state] += 1
        for state, n in counts.items():
            self.slots_gauge.labels(state=state).set(n)
        u = eng.pool.utilization()
        for sh, n in enumerate(u["free_by_shard"]):
            self.pool_free_blocks.labels(shard=str(sh)).set(n)
        self.pool_frag_tokens.set(u["frag_tokens"])
        self.pool_frag_ratio.set(u["frag_ratio"])
        if eng.cache is not None:
            self.cache_nodes.set(eng.cache.cached_blocks())
            if eng.cache.spill:
                self.host_tier_bytes.set(eng.cache.host_bytes)

    # ---- pool / cache / spec events -------------------------------------

    def on_pool_alloc(self, n: int) -> None:
        self.pool_alloc.inc(n)

    def on_pool_free(self, n: int = 1) -> None:
        self.pool_freed.inc(n)

    def on_pool_reclaim(self, n: int) -> None:
        self.pool_reclaimed.inc(n)

    def on_pool_cow(self) -> None:
        self.pool_cow.inc()

    def on_cache_record(self, hit: bool, tokens: int) -> None:
        self.cache_lookups.inc()
        if hit:
            self.cache_hits.inc()
            self.cache_hit_tokens.inc(tokens)

    def on_cache_insert(self, blocks: int) -> None:
        if blocks:
            self.cache_inserted.inc(blocks)

    def on_cache_evict(self, blocks: int) -> None:
        if blocks:
            self.cache_evicted.inc(blocks)

    def on_cache_spill(self, blocks: int, bytes_: int) -> None:
        if blocks:
            self.cache_spilled.inc(blocks)

    def on_cache_swap_in(self, blocks: int, seconds: float) -> None:
        if blocks:
            self.cache_swapped_in.inc(blocks)
            self.cache_swapin_hist.observe(seconds)

    def on_cache_replicate(self, blocks: int) -> None:
        if blocks:
            self.cache_replicated.inc(blocks)


    # ---- exposition ------------------------------------------------------

    def prometheus(self) -> str:
        return self.registry.to_prometheus()

    def snapshot(self) -> dict:
        return self.registry.snapshot()


class _StatsView(MutableMapping):
    """`engine.stats` backed by registry counters: same keys, same int/float
    value types, same iteration order as the legacy dict — existing callers
    (`stats[k] += n`, bench reset loops `stats[k] = 0`) work unchanged while
    every mutation lands in the metrics registry."""

    __slots__ = ("_cells",)

    def __init__(self, cells):
        self._cells = cells  # key -> metric child (insertion-ordered)

    def __getitem__(self, k):
        v = self._cells[k].get()
        return v if k in STAT_FLOAT_KEYS else int(v)

    def __setitem__(self, k, v):
        self._cells[k].set(v)

    def __delitem__(self, k):
        raise TypeError("engine.stats has a fixed key set")

    def __iter__(self):
        return iter(self._cells)

    def __len__(self):
        return len(self._cells)

    def __repr__(self):
        return repr(dict(self))
