"""Dependency-free metrics registry (Counter / Gauge / Histogram).

Design constraints (docs/CONVENTIONS.md §6):
  - instruments are updated from HOST Python only — never inside jitted or
    shard_map bodies — so a plain lock suffices and updates cost one dict
    lookup plus a float add on the hot path;
  - `snapshot()` is atomic: it takes the registry lock once and copies every
    series, so a concurrently updating engine can never expose a histogram
    whose `_sum` and `_count` disagree;
  - exposition is Prometheus text format (`to_prometheus`) and plain JSON
    (`to_json`) — no client library, no network, no background thread.

Label model: a metric is declared once with a fixed tuple of label NAMES;
each distinct tuple of label VALUES materializes one child series on first
use (`metric.labels(...)`), cached forever after. A metric declared with no
labels acts as its own single series (`counter.inc()` works directly).

Scoping: `default_registry()` is the process-global registry; components
that need isolation (tests, per-engine Instrumentation) construct their own
`MetricsRegistry`. `registry.child(**const_labels)` returns a view that
transparently stamps constant labels (e.g. `engine="0"`) onto every metric
declared through it — the underlying series still live in the parent, so
one snapshot covers all engines.
"""

from __future__ import annotations

import json
import math
import threading

_INF = float("inf")

#: default histogram buckets — wide enough for µs-scale CPU smoke steps and
#: second-scale real decodes (upper bounds in seconds; +Inf appended).
DEFAULT_BUCKETS = (1e-5, 1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _check_label_values(labelnames, values, kw):
    if values and kw:
        raise ValueError("pass label values positionally OR by name, not both")
    if kw:
        try:
            values = tuple(kw[n] for n in labelnames)
        except KeyError as e:
            raise ValueError(f"missing label {e} (have {labelnames})") from e
        if len(kw) != len(labelnames):
            extra = set(kw) - set(labelnames)
            raise ValueError(f"unknown labels {sorted(extra)}")
    else:
        values = tuple(values)
    if len(values) != len(labelnames):
        raise ValueError(
            f"expected {len(labelnames)} label values {labelnames}, "
            f"got {len(values)}")
    return tuple(str(v) for v in values)


class _Child:
    """One series of a Counter/Gauge: a float cell under the registry lock."""

    __slots__ = ("_lock", "value")

    def __init__(self, lock):
        self._lock = lock
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def get(self) -> float:
        with self._lock:
            return self.value


class _HistChild:
    """One histogram series: cumulative-style bucket counts + sum + count."""

    __slots__ = ("_lock", "buckets", "counts", "sum", "count")

    def __init__(self, lock, buckets):
        self._lock = lock
        self.buckets = buckets            # ascending upper bounds, ends +Inf
        self.counts = [0] * len(buckets)  # per-bucket (non-cumulative)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        with self._lock:
            self.sum += value
            self.count += 1
            for i, le in enumerate(self.buckets):
                if value <= le:
                    self.counts[i] += 1
                    break

    def cumulative(self) -> list[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile (0 <= q <= 1). Returns nan when the
        series is empty; the last finite bound when q lands in +Inf."""
        with self._lock:
            if self.count == 0:
                return math.nan
            rank = q * self.count
            acc, lo = 0, 0.0
            for i, le in enumerate(self.buckets):
                prev = acc
                acc += self.counts[i]
                if acc >= rank:
                    if le == _INF:
                        return self.buckets[i - 1] if i else math.nan
                    if self.counts[i] == 0:
                        return le
                    frac = (rank - prev) / self.counts[i]
                    return lo + frac * (le - lo)
                lo = le if le != _INF else lo
            return self.buckets[-2] if len(self.buckets) > 1 else math.nan


class _Metric:
    kind = "untyped"

    def __init__(self, name, help_, labelnames, lock):
        self.name = name
        self.help = help_
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._children: dict[tuple, object] = {}
        self._default = None  # lazily created zero-label child

    def _new_child(self):
        raise NotImplementedError

    def labels(self, *values, **kw):
        key = _check_label_values(self.labelnames, values, kw)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._new_child()
            return child

    def _self_child(self):
        """The single series of a label-less metric."""
        if self.labelnames:
            raise ValueError(
                f"{self.name} has labels {self.labelnames}; call .labels()")
        if self._default is None:
            self._default = self.labels()
        return self._default

    def series(self):
        """Atomic copy: [(label_values_tuple, child), ...]."""
        with self._lock:
            return list(self._children.items())


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return _Child(self._lock)

    def inc(self, amount: float = 1.0):
        self._self_child().inc(amount)

    def get(self) -> float:
        return self._self_child().get()


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return _Child(self._lock)

    def set(self, value: float):
        self._self_child().set(value)

    def inc(self, amount: float = 1.0):
        self._self_child().inc(amount)

    def get(self) -> float:
        return self._self_child().get()


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help_, labelnames, lock,
                 buckets=DEFAULT_BUCKETS):
        super().__init__(name, help_, labelnames, lock)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError("histogram needs at least one bucket")
        if b[-1] != _INF:
            b = b + (_INF,)
        self.buckets = b

    def _new_child(self):
        return _HistChild(self._lock, self.buckets)

    def observe(self, value: float):
        self._self_child().observe(value)

    def quantile(self, q: float) -> float:
        return self._self_child().quantile(q)


class MetricsRegistry:
    """Owns metrics by name. Declaration is idempotent: re-declaring with the
    same (kind, labelnames) returns the existing metric; a conflicting
    re-declaration raises."""

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict[str, _Metric] = {}

    def _declare(self, cls, name, help_, labels, **kw):
        labels = tuple(labels)
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls) or m.labelnames != labels:
                    raise ValueError(
                        f"metric {name!r} already declared as {m.kind}"
                        f"{m.labelnames}, conflicting with {cls.kind}{labels}")
                return m
            m = cls(name, help_, labels, self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name, help_="", labels=()) -> Counter:
        return self._declare(Counter, name, help_, labels)

    def gauge(self, name, help_="", labels=()) -> Gauge:
        return self._declare(Gauge, name, help_, labels)

    def histogram(self, name, help_="", labels=(),
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._declare(Histogram, name, help_, labels, buckets=buckets)

    def get(self, name) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    def child(self, **const_labels) -> "ChildRegistry":
        return ChildRegistry(self, const_labels)

    # ---- exposition ------------------------------------------------------

    def snapshot(self) -> dict:
        """Atomic plain-dict snapshot of every series."""
        with self._lock:
            out = {}
            for name, m in self._metrics.items():
                series = []
                for key, child in m._children.items():
                    labels = dict(zip(m.labelnames, key))
                    if m.kind == "histogram":
                        series.append({
                            "labels": labels, "count": child.count,
                            "sum": child.sum,
                            "buckets": list(zip(m.buckets,
                                                child.cumulative()))})
                    else:
                        series.append({"labels": labels,
                                       "value": child.value})
                out[name] = {"type": m.kind, "help": m.help,
                             "series": series}
            return out

    def value(self, name, **labels) -> float:
        """Convenience: current value of one counter/gauge series (0.0 when
        the series has never been touched)."""
        m = self.get(name)
        if m is None:
            return 0.0
        key = _check_label_values(m.labelnames, (), labels) if labels else ()
        with self._lock:
            child = m._children.get(key)
            return child.value if child is not None else 0.0

    def to_prometheus(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        snap = self.snapshot()
        lines = []
        for name in sorted(snap):
            fam = snap[name]
            lines.append(f"# HELP {name} {_esc_help(fam['help'])}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for s in fam["series"]:
                lbl = _fmt_labels(s["labels"])
                if fam["type"] == "histogram":
                    for le, cum in s["buckets"]:
                        ble = _fmt_labels({**s["labels"], "le": _fmt_le(le)})
                        lines.append(f"{name}_bucket{ble} {cum}")
                    lines.append(f"{name}_sum{lbl} {_fmt_val(s['sum'])}")
                    lines.append(f"{name}_count{lbl} {s['count']}")
                else:
                    lines.append(f"{name}{lbl} {_fmt_val(s['value'])}")
        return "\n".join(lines) + "\n"

    def to_json(self, indent=None) -> str:
        return json.dumps(self.snapshot(), indent=indent, default=_fmt_le)


class _BoundMetric:
    """A metric viewed through a ChildRegistry: constant labels pre-bound."""

    __slots__ = ("_metric", "_const")

    def __init__(self, metric, const):
        self._metric = metric
        self._const = const  # dict name -> value, subset of labelnames

    def labels(self, *values, **kw):
        free = tuple(n for n in self._metric.labelnames
                     if n not in self._const)
        vals = _check_label_values(free, values, kw)
        full = dict(zip(free, vals))
        full.update(self._const)
        return self._metric.labels(**full)

    # label-less-through-the-view convenience (all free labels empty)
    def inc(self, amount: float = 1.0):
        self.labels().inc(amount)

    def set(self, value: float):
        self.labels().set(value)

    def observe(self, value: float):
        self.labels().observe(value)

    def get(self) -> float:
        return self.labels().get()

    def quantile(self, q: float) -> float:
        return self.labels().quantile(q)


class ChildRegistry:
    """Declaration view stamping constant labels (e.g. engine id) onto every
    metric; series live in the parent registry."""

    def __init__(self, parent: MetricsRegistry, const_labels: dict):
        self.parent = parent
        self.const_labels = {k: str(v) for k, v in const_labels.items()}

    def _wrap(self, fn, name, help_, labels, **kw):
        all_labels = tuple(self.const_labels) + tuple(labels)
        return _BoundMetric(fn(name, help_, all_labels, **kw),
                            self.const_labels)

    def counter(self, name, help_="", labels=()):
        return self._wrap(self.parent.counter, name, help_, labels)

    def gauge(self, name, help_="", labels=()):
        return self._wrap(self.parent.gauge, name, help_, labels)

    def histogram(self, name, help_="", labels=(), buckets=DEFAULT_BUCKETS):
        return self._wrap(self.parent.histogram, name, help_, labels,
                          buckets=buckets)


def _esc_help(s: str) -> str:
    return s.replace("\\", r"\\").replace("\n", r"\n")


def _esc_label(s: str) -> str:
    return s.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_esc_label(str(v))}"'
                     for k, v in labels.items())
    return "{" + inner + "}"


def _fmt_le(le) -> str:
    if le == _INF:
        return "+Inf"
    return repr(float(le))


def _fmt_val(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


_DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry (components default to private registries
    via Instrumentation; use this for cross-cutting process metrics)."""
    return _DEFAULT_REGISTRY
