"""whisper-tiny [audio]: enc-dec, conv frontend stubbed to frame embeddings.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536, vocab=51865,
    attn="gqa", mlp="gelu", norm="layernorm", enc_dec=True, input_mode="embeds",
    rope_fraction=0.0,  # whisper uses absolute positions; stub embeds carry them
    source="arXiv:2212.04356",
)
