"""llama-200m: the paper's own ablation family (Table 3, largest size).
10L d_model=1280 10H swiglu; used by the Fig. 1/2/4 reproduction benches."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-200m", family="dense",
    n_layers=10, d_model=1280, n_heads=10, n_kv_heads=10, d_ff=3456, vocab=32000,
    attn="gqa", mlp="swiglu",
    source="paper Table 3",
)
