"""recurrentgemma-9b [hybrid]: RG-LRU + local attention (MQA kv=1), 1:2 pattern.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig, GriffinConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000,
    attn="gqa", mlp="swiglu",
    griffin=GriffinConfig(lru_width=4096, conv_width=4, window=2048,
                          pattern=("rec", "rec", "attn")),
    source="arXiv:2402.19427",
)
