"""Architecture registry: `get("yi-9b")`, `names()`."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

ARCH_IDS = [
    "whisper_tiny",
    "chatglm3_6b",
    "nemotron_4_15b",
    "granite_8b",
    "yi_9b",
    "deepseek_moe_16b",
    "deepseek_v3_671b",
    "llava_next_mistral_7b",
    "rwkv6_7b",
    "recurrentgemma_9b",
    "llama_200m",  # the paper's own ablation family (Table 3)
]


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str) -> ArchConfig:
    norm = _norm(name)
    if norm not in ARCH_IDS:
        raise KeyError(f"unknown arch '{name}'; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{norm}")
    return mod.CONFIG


def names() -> list[str]:
    return list(ARCH_IDS)
