"""Architecture configuration dataclasses.

One `ArchConfig` fully specifies a model; `reduced()` derives the CPU smoke
variant of the same family (small width/depth/experts/vocab) used by tests.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_ff_expert: int
    score: str = "softmax"        # "softmax" | "sigmoid" (deepseek-v3)
    capacity_factor: float = 1.25
    route_scale: float = 1.0


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    lora_rank: int = 32           # data-dependent token-shift / decay LoRA
    chunk: int = 16               # chunked-WKV block length


@dataclass(frozen=True)
class GriffinConfig:
    lru_width: int = 0            # 0 -> d_model
    conv_width: int = 4
    window: int = 2048            # local-attention window
    pattern: tuple[str, ...] = ("rec", "rec", "attn")  # 1 attn : 2 rec


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention flavour
    attn: str = "gqa"             # gqa | mla | none
    rope_fraction: float = 1.0    # chatglm3 "RoPE 2d": rotary on half the dims
    rope_theta: float = 10000.0
    qk_norm: bool = False
    # mlp flavour
    mlp: str = "swiglu"           # swiglu | relu2 | gelu
    # sub-configs
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    rwkv: RWKVConfig | None = None
    griffin: GriffinConfig | None = None
    # structure
    enc_dec: bool = False         # whisper: n_layers encoder + n_layers decoder
    input_mode: str = "tokens"    # tokens | embeds (stubbed modality frontend)
    tie_embeddings: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-5
    # which layers the NVFP4 scheme touches (paper keeps head in BF16)
    quantize_lm_head: bool = False
    # provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long_500k? (assignment: SSM/hybrid/linear-attn)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # no encoder-only archs in the assignment

    def reduced(self) -> "ArchConfig":
        """Same-family CPU smoke config: small dims, few experts, tiny vocab."""
        changes: dict = dict(
            # hybrids keep one full layer pattern so the smoke covers all types
            n_layers=min(self.n_layers, 3 if self.griffin else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=256,
            vocab=512,
            head_dim=32,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_routed=8, top_k=2, d_ff_expert=64)
        if self.mla:
            changes["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                       qk_nope_head_dim=32, qk_rope_head_dim=16,
                                       v_head_dim=32)
            changes["n_kv_heads"] = 4
        if self.rwkv:
            changes["rwkv"] = RWKVConfig(head_dim=32, lora_rank=8, chunk=8)
        if self.griffin:
            changes["griffin"] = dataclasses.replace(
                self.griffin, lru_width=128, window=32)
        return dataclasses.replace(self, name=self.name + "-smoke", **changes)


# ---- shape cells (assignment) ---------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
