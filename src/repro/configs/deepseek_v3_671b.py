"""deepseek-v3-671b [moe]: MLA attention, 1 shared + 256 routed top-8 experts.
Assignment simplification: all 61 layers are MoE (official v3 keeps the first
3 dense); MTP head omitted (not in the assigned config line).
[arXiv:2412.19437; hf]"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280, attn="mla", mlp="swiglu",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, d_ff_expert=2048,
                  score="sigmoid", route_scale=2.5),
    source="arXiv:2412.19437",
)
