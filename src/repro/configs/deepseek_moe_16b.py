"""deepseek-moe-16b [moe]: fine-grained 64 routed top-6 + 2 shared experts.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408, vocab=102400,
    attn="gqa", mlp="swiglu",
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
                  score="softmax"),
    source="arXiv:2401.06066",
)
