"""chatglm3-6b [dense]: GQA kv=2, 2d (half-dim) RoPE. [arXiv:2406.12793; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024,
    attn="gqa", rope_fraction=0.5, mlp="swiglu",
    source="arXiv:2406.12793",
)
