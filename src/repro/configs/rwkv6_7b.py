"""rwkv6-7b "Finch" [ssm]: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig, RWKVConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=0, d_ff=14336, vocab=65536,
    attn="none", rwkv=RWKVConfig(head_dim=64, lora_rank=64, chunk=16),
    source="arXiv:2404.05892",
)
