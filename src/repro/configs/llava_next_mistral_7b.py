"""llava-next-mistral-7b [vlm]: mistral-7b backbone; anyres patch frontend is
stubbed (input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
    attn="gqa", mlp="swiglu", input_mode="embeds",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
