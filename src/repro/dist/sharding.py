"""Sharding rules: parameter / optimizer-state / cache / input partition specs
for the production (data, model) mesh.

The rules are name- and shape-driven (no per-arch tables):

  - 1D params (norm gains, biases, lambdas) replicate.
  - router weights replicate (fp32, tiny, bias-sensitive — never sharded).
  - 2D weights put the out-dim on "model" when divisible, else try the in-dim;
    the in-dim additionally goes to "data" under FSDP (ZeRO-3-style).
  - stacked layer params (leading scan axis from lm._stack_init) never shard
    the leading axis; the rules above apply to the trailing dims.
  - 4D expert stacks (L, E, f, d) put experts on "model" (expert parallelism)
    and the trailing in-dim on "data" under FSDP.
  - decode caches shard batch -> "data" and head_dim -> "model"; the sequence
    axis stays unsharded (decode appends along it).

An axis is only assigned when its size divides the mesh axis size — GSPMD
would otherwise pad-and-replicate, which costs more wire than replication.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_REPLICATED_TOKENS = ("router", "norm", "/n1", "/n2", "/nx", "gn", "mu",
                      "lam", "bias", "/ba", "/bx", "conv_b")


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def param_spec(path: str, shape: tuple[int, ...], *, model: int, data: int,
               fsdp: bool) -> P:
    """PartitionSpec for one parameter leaf addressed by `path`."""
    axes: list = [None] * len(shape)
    if len(shape) <= 1 or any(t in path for t in _REPLICATED_TOKENS):
        return P(*axes)

    if len(shape) == 4:
        # stacked expert weights (L, E, f, d): experts -> model (EP)
        if _div(shape[1], model):
            axes[1] = "model"
        if fsdp and _div(shape[3], data):
            axes[3] = "data"
        return P(*axes)

    # trailing (out, in) matrix; leading stacked axis (if 3D) stays None
    o, i = len(shape) - 2, len(shape) - 1
    if _div(shape[o], model):
        axes[o] = "model"
    elif _div(shape[i], model):
        axes[i] = "model"
    if fsdp and axes[i] is None and _div(shape[i], data):
        axes[i] = "data"
    return P(*axes)


def cache_spec(kind: str, shape: tuple[int, ...], *, model: int, data: int) -> P:
    """Decode-cache leaf spec. Layout convention: (L, B, S?, ..., feature).

    Batch (axis 1) -> data; the trailing feature axis -> model when the leaf
    is wide enough to matter (>= 3 trailing dims, e.g. (B, S, KV, hd) K/V or
    (B, H, d, d) WKV state); sequence/position axes stay unsharded.
    """
    axes: list = [None] * len(shape)
    if len(shape) >= 2 and _div(shape[1], data):
        axes[1] = "data"
    if len(shape) >= 4 and _div(shape[-1], model):
        axes[-1] = "model"
    return P(*axes)


# --------------------------------------------------------------------------
# tree-level builders (used by launch/dryrun and the distributed examples)
# --------------------------------------------------------------------------

def _mesh_sizes(mesh) -> tuple[int, int]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1), sizes.get("data", 1)


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))))
    return "/".join(out)


def state_shardings(state, mesh, *, fsdp: bool):
    """NamedShardings for a params-or-train-state pytree (shape-structs ok)."""
    model, data = _mesh_sizes(mesh)

    def one(path, leaf):
        spec = param_spec(_path_str(path), tuple(leaf.shape),
                          model=model, data=data, fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state)


def cache_shardings(cache, mesh):
    model, data = _mesh_sizes(mesh)

    def one(path, leaf):
        p = _path_str(path)
        kind = p.rsplit("/", 1)[-1]
        spec = cache_spec(kind, tuple(leaf.shape), model=model, data=data)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def input_shardings(batch, mesh):
    """Token/label/embed inputs: batch axis -> data, rest replicated."""
    _, data = _mesh_sizes(mesh)

    def one(leaf):
        axes: list = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and _div(leaf.shape[0], data):
            axes[0] = "data"
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, batch)
