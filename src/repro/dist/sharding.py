"""Sharding rules: parameter / optimizer-state / cache / input partition specs
for the production (data, model) mesh.

The rules are name- and shape-driven (no per-arch tables):

  - 1D params (norm gains, biases, lambdas) replicate.
  - router weights replicate (fp32, tiny, bias-sensitive — never sharded).
  - 2D weights put the out-dim on "model" when divisible, else try the in-dim;
    the in-dim additionally goes to "data" under FSDP (ZeRO-3-style).
  - stacked layer params (leading scan axis from lm._stack_init) never shard
    the leading axis; the rules above apply to the trailing dims.
  - 4D expert stacks (L, E, f, d) put experts on "model" (expert parallelism)
    and the trailing in-dim on "data" under FSDP.
  - decode caches shard batch -> "data" and head_dim -> "model"; the sequence
    axis stays unsharded (decode appends along it).

An axis is only assigned when its size divides the mesh axis size — GSPMD
would otherwise pad-and-replicate, which costs more wire than replication.

SERVING (`serve_*` below — serve/engine.py mesh mode) uses a different split
of the same mesh, because decode-step traffic is cache-dominated and the
slot-affine KV pool (serve/kv_pool.py) makes every cache access shard-local:

  - every KV-pool cache leaf shards axis 1 — the physical-BLOCK axis of
    token kinds, the SLOT axis of recurrent state / dense caches — over
    "data", never the feature axis (the decode step is manual over "data"
    via shard_map; feature-axis splits would force collectives *inside*
    each manual shard for no bandwidth win at decode batch sizes);
  - `PackedQWeight` leaves (quantize-once NVFP4 weights, core/linear.py)
    shard their out-feature axis — `packed`/`scales8` axis -2 — over
    "model"; the per-matrix `gscale` replicates. "model" stays a GSPMD
    `auto` axis inside the serving shard_map, so XLA inserts the activation
    reductions for the row-split GEMMs;
  - raw serving leaves (embeddings, norms, MLA's wkv_b, the head) fall back
    to `param_spec` with fsdp off — "data" never appears on weights (every
    shard needs the full model to decode its own slots).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_REPLICATED_TOKENS = ("router", "norm", "/n1", "/n2", "/nx", "gn", "mu",
                      "lam", "bias", "/ba", "/bx", "conv_b")


def _div(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def param_spec(path: str, shape: tuple[int, ...], *, model: int, data: int,
               fsdp: bool) -> P:
    """PartitionSpec for one parameter leaf addressed by `path`."""
    axes: list = [None] * len(shape)
    if len(shape) <= 1 or any(t in path for t in _REPLICATED_TOKENS):
        return P(*axes)

    if len(shape) == 4:
        # stacked expert weights (L, E, f, d): experts -> model (EP)
        if _div(shape[1], model):
            axes[1] = "model"
        if fsdp and _div(shape[3], data):
            axes[3] = "data"
        return P(*axes)

    # trailing (out, in) matrix; leading stacked axis (if 3D) stays None
    o, i = len(shape) - 2, len(shape) - 1
    if _div(shape[o], model):
        axes[o] = "model"
    elif _div(shape[i], model):
        axes[i] = "model"
    if fsdp and axes[i] is None and _div(shape[i], data):
        axes[i] = "data"
    return P(*axes)


def cache_spec(kind: str, shape: tuple[int, ...], *, model: int, data: int) -> P:
    """Decode-cache leaf spec. Layout convention: (L, B, S?, ..., feature).

    Batch (axis 1) -> data; the trailing feature axis -> model when the leaf
    is wide enough to matter (>= 3 trailing dims, e.g. (B, S, KV, hd) K/V or
    (B, H, d, d) WKV state); sequence/position axes stay unsharded.
    """
    axes: list = [None] * len(shape)
    if len(shape) >= 2 and _div(shape[1], data):
        axes[1] = "data"
    if len(shape) >= 4 and _div(shape[-1], model):
        axes[-1] = "model"
    return P(*axes)


# --------------------------------------------------------------------------
# tree-level builders (used by launch/dryrun and the distributed examples)
# --------------------------------------------------------------------------

def _mesh_sizes(mesh) -> tuple[int, int]:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1), sizes.get("data", 1)


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))))
    return "/".join(out)


def state_shardings(state, mesh, *, fsdp: bool):
    """NamedShardings for a params-or-train-state pytree (shape-structs ok)."""
    model, data = _mesh_sizes(mesh)

    def one(path, leaf):
        spec = param_spec(_path_str(path), tuple(leaf.shape),
                          model=model, data=data, fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, state)


def cache_shardings(cache, mesh):
    model, data = _mesh_sizes(mesh)

    def one(path, leaf):
        p = _path_str(path)
        kind = p.rsplit("/", 1)[-1]
        spec = cache_spec(kind, tuple(leaf.shape), model=model, data=data)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache)


def input_shardings(batch, mesh):
    """Token/label/embed inputs: batch axis -> data, rest replicated."""
    _, data = _mesh_sizes(mesh)

    def one(leaf):
        axes: list = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and _div(leaf.shape[0], data):
            axes[0] = "data"
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, batch)


# --------------------------------------------------------------------------
# serving (mesh-sharded ServeEngine: slot-affine pool over "data", packed
# weights over "model" — see the module docstring and serve/README.md)
# --------------------------------------------------------------------------

# every serving-cache leaf — (stack, n_blocks, block, ...) token pools,
# (stack, n_slots, ...) recurrent state, (stack, n_slots, max_len, ...) dense
# caches — splits its axis-1 slot/block home over "data"; usable directly as
# the shard_map in/out spec prefix for the whole cache pytree
SERVE_CACHE_SPEC = P(None, "data")


def packed_weight_spec(shape: tuple[int, ...], *, model: int) -> P:
    """Spec for one field of a PackedQWeight: `packed` (..., N, K/2) and
    `scales8` (..., N, K/16) shard the out-feature axis N over "model"
    (group boundaries along K stay device-local by construction); the
    per-matrix `gscale` (...,) replicates. Leading stacked layer/expert axes
    are never sharded, mirroring `param_spec`."""
    axes: list = [None] * len(shape)
    if len(shape) >= 2 and _div(shape[-2], model):
        axes[-2] = "model"
    return P(*axes)


def serve_param_shardings(params, mesh):
    """NamedShardings for a serving params pytree (prequantized or raw).

    PackedQWeight leaves use `packed_weight_spec`; raw leaves use the
    training `param_spec` with fsdp off, so only "model" is ever assigned —
    inside the serving shard_map "data" is a MANUAL axis over decode slots
    and weights must be replicated across it. Works on concrete arrays and
    on eval_shape structs (dry-run lowering)."""
    from repro.core.linear import PackedQWeight
    model, _ = _mesh_sizes(mesh)

    def one(path, leaf):
        if isinstance(leaf, PackedQWeight):
            return PackedQWeight(
                *(NamedSharding(mesh, packed_weight_spec(tuple(f.shape),
                                                         model=model))
                  for f in leaf))
        spec = param_spec(_path_str(path), tuple(leaf.shape),
                          model=model, data=1, fsdp=False)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, PackedQWeight))


def serve_cache_shardings(cache, mesh):
    """NamedShardings placing every serving-cache leaf on SERVE_CACHE_SPEC."""
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, SERVE_CACHE_SPEC), cache)
