"""NVFP4 gradient compression for data-parallel all-reduce.

Inside a `shard_map` over the DP axis, each device stochastically rounds its
local gradient shard to NVFP4 (packed 4-bit codes + e4m3 group scales on the
wire = 4.5 bits/element vs 32 for fp32) and the mean is taken over the psum
of the dequantized values. Q_SR is unbiased (paper Sec. 3.1), so the
compressed mean is an unbiased estimator of the exact mean — averaging over
seeds/steps converges to it, which is what keeps training unbiased end-to-end.

Per-device seeds derive from (caller seed, axis_index, leaf index): devices
must NOT share rounding randomness or the SR errors correlate and stop
averaging out across the reduce.

Callers enter through `repro.dist.shard_map` (the version shim, manual
axes only — docs/CONVENTIONS.md §1); `tests/test_substrate.py` checks the
compressed mean's accuracy and unbiasedness on a simulated 4-device mesh.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import quant as Q


def _device_key(seed: jax.Array, axis_name: str, tag: int) -> jax.Array:
    key = jax.random.wrap_key_data(jnp.asarray(seed).astype(jnp.uint32))
    key = jax.random.fold_in(key, jax.lax.axis_index(axis_name))
    return jax.random.fold_in(key, tag)


def _sr_roundtrip(x: jax.Array, key: jax.Array) -> jax.Array:
    """Quantize one leaf to NVFP4 with SR and dequantize (simulated wire)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % F.GROUP
    if pad:
        flat = jnp.pad(flat, (0, pad))
    qt = Q.quant_sr(flat[None, :], key)
    deq = Q.dequant(qt)[0]
    if pad:
        deq = deq[: x.size]
    return deq.reshape(x.shape)


def compressed_psum_mean(x: jax.Array, axis_name: str, seed: jax.Array,
                         tag: int = 0) -> jax.Array:
    """Unbiased NVFP4-compressed mean of `x` over `axis_name` (one leaf).

    Call inside shard_map; `seed` is a uint32[2] per-step seed shared by all
    devices (the device index is folded in here).
    """
    deq = _sr_roundtrip(x, _device_key(seed, axis_name, tag))
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (jax.lax.psum(deq, axis_name) / n).astype(x.dtype)


def compressed_grad_mean(grads, axis_name: str, seed: jax.Array):
    """Tree version of `compressed_psum_mean` for a gradient pytree.

    Leaves smaller than one scale group skip quantization (norm gains and
    biases — a few floats; compressing them saves nothing and the e4m3 scale
    overhead would exceed the payload).
    """
    leaves, treedef = jax.tree.flatten(grads)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    out = []
    for i, g in enumerate(leaves):
        if g.size < F.GROUP:
            out.append((jax.lax.psum(g.astype(jnp.float32), axis_name) / n)
                       .astype(g.dtype))
        else:
            deq = _sr_roundtrip(g, _device_key(seed, axis_name, i + 1))
            out.append((jax.lax.psum(deq, axis_name) / n).astype(g.dtype))
    return jax.tree.unflatten(treedef, out)
