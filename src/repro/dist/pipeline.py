"""GPipe-style pipeline parallelism over a `shard_map`-ed "pipe" mesh axis.

Each device holds one contiguous stage of layers; microbatches stream through
the ring via `ppermute`. The schedule is the classic GPipe fill-drain: with S
stages and M microbatches the pipe runs M + S - 1 ticks, of which S - 1 are
bubble — `bubble_fraction` below, the quantity the launch cost model charges.

Stage boundaries optionally compress activations to NVFP4 before the hop
(`compress=True`): the wire payload becomes 4.5 bits/element (packed codes +
e4m3 group scales), the same format the gradient compression uses. Boundary
compression is deterministic RTN — serving-style forward-only traffic, no
unbiasedness requirement.

Runs under the PLAIN manual `repro.dist.shard_map` shim (no `auto` axes),
so the schedule's internal scans are safe — the while-body sharding
limitation that forces the serving path to unroll does not apply here; see
docs/CONVENTIONS.md §1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant as Q


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule: (S-1) / (M + S - 1)."""
    if n_stages <= 1:
        return 0.0
    return (n_stages - 1) / (n_micro + n_stages - 1)


def stack_stage_params(params, n_stages: int):
    """Split every leaf's leading (layers) axis into (n_stages, per_stage).

    The result feeds `shard_map` with in_spec P("pipe") so each device
    receives its own stage's layer stack.
    """
    def one(x):
        n = x.shape[0]
        assert n % n_stages == 0, (x.shape, n_stages)
        return x.reshape(n_stages, n // n_stages, *x.shape[1:])

    return jax.tree.map(one, params)


def _compress_boundary(y: jax.Array) -> jax.Array:
    """Round-trip a stage boundary through NVFP4 (simulated 4.5-bit wire)."""
    flat = y.reshape(y.shape[0], -1)
    qt = Q.quant_rtn(flat, s=Q.S_EDEN)
    return Q.dequant(qt, jnp.float32).reshape(y.shape).astype(y.dtype)


def gpipe(stage_fn, n_stages: int, n_micro: int, compress: bool = False):
    """Build the per-device GPipe body for `shard_map`.

    stage_fn(w, x) applies one stage. The returned `run(ws, xs)` expects
    `ws` sharded P("pipe") (leading stage axis, one stage per device) and
    `xs` replicated with a leading (n_micro,) axis; it returns the
    replicated (n_micro, ...) outputs of the final stage.
    """

    def run(ws, xs):
        stage = jax.lax.axis_index("pipe")
        w = jax.tree.map(lambda x: x[0], ws)  # this device's stage params
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        m = n_micro

        def tick(carry, t):
            recv, outs = carry
            mb = t - stage  # microbatch this stage works on at tick t
            inp = jnp.where(stage == 0, xs[jnp.clip(t, 0, m - 1)], recv)
            y = stage_fn(w, inp)
            wire = _compress_boundary(y) if compress else y
            nxt = jax.lax.ppermute(wire, "pipe", perm)
            valid = (mb >= 0) & (mb < m) & (stage == n_stages - 1)
            slot = jnp.clip(mb, 0, m - 1)
            outs = outs.at[slot].set(jnp.where(valid, y, outs[slot]))
            return (nxt, outs), None

        init = (jnp.zeros_like(xs[0]), jnp.zeros_like(xs))
        (_, outs), _ = jax.lax.scan(tick, init, jnp.arange(m + n_stages - 1))
        # outputs live on the last stage only; replicate for out_specs=P()
        mine = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(mine, "pipe")

    return run
