"""Distribution layer: sharding rules, pipeline parallelism, NVFP4 gradient
compression, and a version-spanning `shard_map` shim.

`shard_map` moved from `jax.experimental.shard_map` (kwarg `check_rep`) to
`jax.shard_map` (kwarg `check_vma`) across jax releases; callers here use one
spelling and run on either.
"""

from __future__ import annotations


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, check_rep=None):
    """`jax.shard_map` / `jax.experimental.shard_map` compat wrapper.

    `check_vma` (new spelling) and `check_rep` (old spelling) are the same
    knob; pass either and it is translated to whatever the installed jax
    expects.
    """
    flag = check_vma if check_vma is not None else check_rep
    try:
        from jax import shard_map as _sm  # jax >= 0.6
        kw = {} if flag is None else {"check_vma": flag}
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        kw = {} if flag is None else {"check_rep": flag}
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
