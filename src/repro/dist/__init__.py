"""Distribution layer: sharding rules, pipeline parallelism, NVFP4 gradient
compression, and a version-spanning `shard_map` shim.

`shard_map` moved from `jax.experimental.shard_map` (kwarg `check_rep`) to
`jax.shard_map` (kwarg `check_vma`) across jax releases; callers here use one
spelling and run on either. Repo-wide distribution conventions (this shim,
the OOB-high scatter-sentinel rule) are recorded in docs/CONVENTIONS.md.
"""

from __future__ import annotations


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, check_rep=None,
              auto=None):
    """`jax.shard_map` / `jax.experimental.shard_map` compat wrapper.

    `check_vma` (new spelling) and `check_rep` (old spelling) are the same
    knob; pass either and it is translated to whatever the installed jax
    expects.

    `auto` names mesh axes left under GSPMD control while the rest go
    manual — the sharded serving step uses it to keep packed weights
    "model"-partitioned (XLA inserts the reductions) inside a manual
    "data"-split over decode slots. Requires a jax whose shard_map takes
    `auto`; passing a non-empty set on one that doesn't raises TypeError
    rather than silently computing with replicated weights.
    """
    flag = check_vma if check_vma is not None else check_rep
    try:
        from jax import shard_map as _sm  # jax >= 0.6
        kw = {} if flag is None else {"check_vma": flag}
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        kw = {} if flag is None else {"check_rep": flag}
    if auto:
        kw["auto"] = frozenset(auto)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
