"""Fault-tolerant training loop.

Production posture (what a 1000-node job needs), realized on one host:
  - periodic async checkpoints (compute overlaps the disk write),
  - emergency checkpoint on ANY exception or SIGTERM/SIGINT (preemption),
  - deterministic resume: data batches are pure functions of the step, so
    restore(step k) continues the exact stream — verified bitwise in tests,
  - straggler watchdog: per-step wall-time EWMA; steps slower than
    `straggler_factor` x EWMA are logged (at scale this feeds the
    reschedule/hot-spare path; here it records to metrics),
  - NaN-loss circuit breaker: skip-and-log (bad node / bad batch at scale).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.train.train_step import TrainState


@dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 200
    log_every: int = 10
    keep_ckpts: int = 3
    straggler_factor: float = 3.0
    async_ckpt: bool = True


@dataclass
class Trainer:
    cfg: TrainerConfig
    train_step: object          # jitted (state, batch) -> (state, metrics)
    corpus: object              # .batch_at(step, shard_id, num_shards)
    shard_id: int = 0
    num_shards: int = 1
    history: list = field(default_factory=list)
    # optional quantization-health tap (obs/quant_probe.py QuantProbe):
    # consulted at the HOST step boundary only (docs/CONVENTIONS.md §6 —
    # never inside the jitted step). None (the default) costs one `is None`
    # test per step: provably zero-overhead when disabled.
    probe: object = None
    _stop: bool = field(default=False, repr=False)

    def __post_init__(self):
        self.ckpt = Checkpointer(self.cfg.ckpt_dir, keep=self.cfg.keep_ckpts)

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._stop = True  # drain current step, then emergency-save
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread (tests)

    def run(self, state: TrainState, resume: bool = True) -> TrainState:
        self._install_signal_handlers()
        start = 0
        if resume and self.ckpt.latest_step() is not None:
            state, meta = self.ckpt.restore(state)
            start = meta["step"]
            print(f"[trainer] resumed from step {start}")

        ewma = None
        step = start
        try:
            for step in range(start, self.cfg.total_steps):
                if self._stop:
                    raise KeyboardInterrupt("preemption signal")
                batch = self.corpus.batch_at(step, self.shard_id, self.num_shards)
                t0 = time.perf_counter()
                state, metrics = self.train_step(state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0

                # sampled quantization-health tap (off unless a probe with
                # every_n > 0 is attached); runs AFTER the step's own host
                # sync so it never serializes the training dispatch
                if self.probe is not None and self.probe.should_sample(step):
                    self.probe.probe_params(state.params, step=step,
                                            phase="train")

                # straggler watchdog
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                straggler = dt > self.cfg.straggler_factor * ewma and step > start + 3
                if straggler:
                    print(f"[watchdog] step {step} took {dt:.2f}s "
                          f"(ewma {ewma:.2f}s) — straggler suspected")

                # NaN circuit breaker
                if not np.isfinite(loss):
                    print(f"[trainer] non-finite loss at step {step}; "
                          f"checkpointing and continuing")
                    self.ckpt.emergency_save(step, state, {"nan_at": step})

                self.history.append({"step": step, "loss": loss, "dt": dt,
                                     "straggler": straggler})
                if step % self.cfg.log_every == 0:
                    print(f"[trainer] step {step} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
                if step and step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step + 1, state,
                                   blocking=not self.cfg.async_ckpt)
        except BaseException as e:  # noqa: BLE001 — preemption path
            ok = self.ckpt.emergency_save(step + 1, state,
                                          {"reason": repr(e)[:200]})
            print(f"[trainer] emergency checkpoint "
                  f"{'written' if ok else 'FAILED'} at step {step + 1}: {e!r}")
            if not isinstance(e, KeyboardInterrupt):
                raise
        finally:
            self.ckpt.wait()
        self.ckpt.save(self.cfg.total_steps, state, blocking=True)
        return state
