"""The jitted training step: loss, grads, clipping, optimizer, seeds.

Per-step quantization seeds follow the paper's re-randomization contract
(App. A item 2): a fresh uint32 pair derived from (base_seed, step,
microbatch) feeds every qlinear call site, which further mixes in
(layer, site) — rotations/SR re-randomize per-tensor per-microbatch.

Gradient accumulation splits the per-device batch into microbatches
(jax.lax.scan over microbatch slices) so huge global batches fit; each
microbatch gets its own quantization seed.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.optim import adamw, muon, schedules


class TrainState(NamedTuple):
    params: dict
    opt: object
    step: jax.Array


def step_seed(base_seed: int, step: jax.Array, micro: jax.Array | int = 0) -> jax.Array:
    s = jnp.asarray(step, jnp.uint32)
    m = jnp.asarray(micro, jnp.uint32)
    return jnp.stack([jnp.uint32(base_seed) ^ (s * jnp.uint32(0x9E3779B9)),
                      s + m * jnp.uint32(0x85EBCA6B)])


def make_train_step(cfg, scheme: str, *, optimizer: str = "adamw",
                    base_lr: float = 3e-4, total_steps: int = 1000,
                    schedule: str = "cosine", weight_decay: float = 0.1,
                    grad_clip: float = 1.0, base_seed: int = 0,
                    microbatches: int = 1, aux_weight: float = 0.01,
                    grad_transform=None):
    """Returns (init_state_fn, train_step_fn).

    grad_transform(grads, seed) -> grads: hook for DP gradient compression
    (dist.compression) or any custom reduction; applied before clipping.
    """
    opt_mod = {"adamw": adamw, "muon": muon}[optimizer]
    sched = schedules.get(schedule)

    def init_state(params) -> TrainState:
        return TrainState(params, opt_mod.init(params), jnp.zeros((), jnp.int32))

    def loss_fn(params, batch, seed):
        return lm.lm_loss(params, cfg, batch, scheme, seed, aux_weight=aux_weight)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if microbatches == 1:
            seed = step_seed(base_seed, state.step, 0)
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch, seed)
        else:
            def micro(i):
                mb = jax.tree.map(
                    lambda x: x.reshape(microbatches, -1, *x.shape[1:])[i], batch)
                seed = step_seed(base_seed, state.step, i)
                return jax.value_and_grad(loss_fn)(state.params, mb, seed)

            def acc(carry, i):
                l, g = micro(i)
                cl, cg = carry
                return (cl + l, jax.tree.map(jnp.add, cg, g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros(()), zero), jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        if grad_transform is not None:
            grads = grad_transform(grads, step_seed(base_seed ^ 0x5555, state.step))

        grads, gnorm = adamw.clip_by_global_norm(grads, grad_clip)
        lr = sched(state.step, base_lr=base_lr, total_steps=total_steps)
        new_params, new_opt = opt_mod.update(
            grads, state.opt, state.params, lr=lr, weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return init_state, train_step
