"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import ms_eden as ME
from repro.core import quant as Q


def nvfp4_fos_quant_ref(x: jax.Array):
    """Oracle for kernels.nvfp4_quant.nvfp4_fos_quant."""
    qt = Q.quant_four_over_six(x)
    deq = Q.dequant(qt, jnp.bfloat16)
    return deq, qt.codes, qt.scales, qt.gscale


def ms_eden_requant_ref(x: jax.Array, rht_key: jax.Array, sr_key: jax.Array):
    """Oracle for kernels.ms_eden_requant (the two-phase post-hoc path)."""
    p1 = ME.ms_eden_phase1(x, jax.random.wrap_key_data(rht_key))
    qt = ME.ms_eden_phase2(p1, jax.random.wrap_key_data(sr_key))
    return qt.codes, qt.scales, qt.gscale


def paged_attention_ref(q, k_pool, v_pool, table, pos, *, window=None):
    """Oracle for kernels.ops.paged_attention: literally today's serving
    reference path — materialize gather_view(pool, table) and run
    decode_sdpa over the full table capacity."""
    from repro.models.attention import decode_sdpa
    from repro.serve.kv_pool import gather_view
    return decode_sdpa(q, gather_view(k_pool, table),
                       gather_view(v_pool, table),
                       jnp.asarray(pos, jnp.int32), window=window)


def paged_mla_attention_ref(q_abs, q_rope, cc_pool, kc_pool, table, pos, *,
                            qk_dim: int):
    """Oracle for kernels.ops.paged_mla_attention: the gathered-view
    absorbed-form score/readout einsums from models.mla.mla_decode
    (o_lat, fp32 — before the caller's W_uv absorption)."""
    from repro.models.attention import NEG_INF
    from repro.serve.kv_pool import gather_view
    cv = gather_view(cc_pool, table)
    kv = gather_view(kc_pool, table)
    posb = jnp.asarray(pos, jnp.int32)
    sq = q_abs.shape[1]
    positions = posb[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
    s_lat = jnp.einsum("bqhl,btl->bhqt", q_abs.astype(jnp.float32),
                       cv.astype(jnp.float32))
    s_rope = jnp.einsum("bqhr,btr->bhqt", q_rope.astype(jnp.float32),
                        kv.astype(jnp.float32))
    s = (s_lat + s_rope) * (1.0 / jnp.sqrt(jnp.float32(qk_dim)))
    tmask = (jnp.arange(cv.shape[1], dtype=jnp.int32)[None, None, :]
             <= positions[:, :, None])
    s = jnp.where(tmask[:, None], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,btl->bqhl", prob, cv.astype(jnp.float32))


def paged_attention_q_ref(q, k_codes, k_scales, v_codes, v_scales, table,
                          pos, *, window=None):
    """Oracle for kernels.ops.paged_attention_q: decode the packed pools
    to bf16 (exact — e2m1 x e4m3 products fit bf16) and run the bf16
    reference path, so kernel and oracle see bit-identical operands."""
    from repro.core.formats import nvfp4_cache_decode
    return paged_attention_ref(q, nvfp4_cache_decode(k_codes, k_scales),
                               nvfp4_cache_decode(v_codes, v_scales),
                               table, pos, window=window)


def paged_mla_attention_q_ref(q_abs, q_rope, cc_codes, cc_scales, kc_codes,
                              kc_scales, table, pos, *, qk_dim: int):
    """Oracle for kernels.ops.paged_mla_attention_q (same decode-then-
    reference construction)."""
    from repro.core.formats import nvfp4_cache_decode
    return paged_mla_attention_ref(
        q_abs, q_rope, nvfp4_cache_decode(cc_codes, cc_scales),
        nvfp4_cache_decode(kc_codes, kc_scales), table, pos, qk_dim=qk_dim)


def fp4_matmul_ref(a_packed, a_scales, b_packed, b_scales, ga, gb):
    """Oracle for kernels.fp4_matmul."""
    def deq(p, s, g):
        codes = F.unpack_fp4(p)
        vals = F.fp4_decode(codes)
        return vals * jnp.repeat(s.astype(jnp.float32), F.GROUP, -1) * g
    a = deq(a_packed, a_scales, ga)
    b = deq(b_packed, b_scales, gb)
    return a @ b.T
