"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import ms_eden as ME
from repro.core import quant as Q


def nvfp4_fos_quant_ref(x: jax.Array):
    """Oracle for kernels.nvfp4_quant.nvfp4_fos_quant."""
    qt = Q.quant_four_over_six(x)
    deq = Q.dequant(qt, jnp.bfloat16)
    return deq, qt.codes, qt.scales, qt.gscale


def ms_eden_requant_ref(x: jax.Array, rht_key: jax.Array, sr_key: jax.Array):
    """Oracle for kernels.ms_eden_requant (the two-phase post-hoc path)."""
    p1 = ME.ms_eden_phase1(x, jax.random.wrap_key_data(rht_key))
    qt = ME.ms_eden_phase2(p1, jax.random.wrap_key_data(sr_key))
    return qt.codes, qt.scales, qt.gscale


def fp4_matmul_ref(a_packed, a_scales, b_packed, b_scales, ga, gb):
    """Oracle for kernels.fp4_matmul."""
    def deq(p, s, g):
        codes = F.unpack_fp4(p)
        vals = F.fp4_decode(codes)
        return vals * jnp.repeat(s.astype(jnp.float32), F.GROUP, -1) * g
    a = deq(a_packed, a_scales, ga)
    b = deq(b_packed, b_scales, gb)
    return a @ b.T
