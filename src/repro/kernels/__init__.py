# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# The contract applies to INFERENCE kernels too, not just the paper's
# training-side quantizer/GEMM kernels: every kernel ships as
#   <name>.py  — the Pallas body (grid, block specs, scratch)
#   ops.py     — the public jit'd wrapper (static shape/flag handling;
#                interpret=None resolves per backend: compiled on TPU,
#                interpreted elsewhere so CPU CI always runs the body)
#   ref.py     — a pure-jnp oracle, which for inference kernels is the
#                exact serving reference path being replaced (e.g.
#                paged_attention's oracle is gather_view + decode_sdpa)
# and a parity suite under tests/ (marker: kernels) pinning kernel ==
# oracle across the shapes the serving/training paths actually use.
