"""Pallas TPU kernels: MS-EDEN re-quantization with post hoc range alignment
(paper Section 7, Figures 7-8, adapted to TPU — DESIGN.md Section 2).

Phase 1 (full tensor, one pass, no global-absmax barrier):
  - blocked RHT as an in-VMEM GEMM against the 128x128 signed-Hadamard
    operand (the MXU analogue of the paper's mma.m16n8k16 rotation),
  - E8M3 pseudo-scales (extended-range, bf16-exact) — no global alignment,
  - FP4 codes against the pseudo-scales,
  - EDEN dot products <x,x>, <x,Q(x)> per 16-group,
  - per-tile absmax partials (reduced to the global absmax by XLA).

Phase 2 (scales only, d/16 elements — the paper measures >10x lower latency
than phase 1):
  - shift pseudo-scales into the FP8 range with the now-known global absmax,
  - apply the EDEN correction S_g,
  - stochastic-round to E4M3 (uniforms are an explicit operand: hardware
    would use the on-chip PRNG; an operand keeps the kernel pure/testable).

Table 2 economics on TPU: phase 1 moves 16+4.5 bits/element once instead of
the naive two full passes (16+16+4.5); phase 2 touches 1/16 of the elements.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import formats as F
from repro.core import quant as Q
from repro.core import rht as R
from repro.kernels.nvfp4_quant import _fp4_code_vec, _fp4_rtn_vec

DEF_BM = 128


def _e8m3_vec(x):
    m, e = jnp.frexp(jnp.maximum(x, 1e-38))
    mq = jnp.round(m * 16.0) / 16.0
    return jnp.where(x <= 0, 0.0, jnp.ldexp(mq, e))


def _phase1_kernel(x_ref, dh_ref, codes_ref, ps_ref, num_ref, den_ref,
                   amax_ref, *, s: float):
    b = dh_ref.shape[0]
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    # blocked RHT: (bm, bk/b, b) @ (b, b) on the MXU
    xr = x.reshape(bm, bk // b, b)
    rot = jax.lax.dot_general(xr, dh_ref[...],
                              (((2,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    rot = rot.reshape(bm, bk)
    g = rot.reshape(bm, bk // F.GROUP, F.GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1)
    pseudo = _e8m3_vec(gmax / s)                      # extended-range scales
    denom = jnp.repeat(jnp.where(pseudo == 0, 1.0, pseudo), F.GROUP, -1)
    denom = denom.reshape(bm, bk)
    q = _fp4_rtn_vec(rot / denom)
    deq = q * denom
    codes_ref[...] = _fp4_code_vec(q)
    ps_ref[...] = pseudo
    num_ref[...] = (rot * rot).reshape(bm, bk // F.GROUP, F.GROUP).sum(-1)
    den_ref[...] = (rot * deq).reshape(bm, bk // F.GROUP, F.GROUP).sum(-1)
    amax_ref[0, 0] = jnp.max(jnp.abs(rot))


def _phase2_kernel(amax_ref, ps_ref, num_ref, den_ref, u_ref, scales_ref,
                   *, s: float):
    gscale = amax_ref[0, 0] / (s * 256.0)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    num, den = num_ref[...], den_ref[...]
    S = jnp.where(den != 0, num / jnp.where(den == 0, 1.0, den), 1.0)
    target = jnp.clip(S * ps_ref[...] / gscale, 0.0, F.FP8_MAX)
    # SR to e4m3 via the uint8 lattice walk (same math as formats.fp8_sr_pos)
    near = target.astype(jnp.float8_e4m3fn)
    near_f = near.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(near, jnp.uint8)
    up = jnp.minimum(bits + 1, jnp.uint8(0x7E))
    down = jnp.where(bits > 0, bits - 1, jnp.uint8(0))
    other = jax.lax.bitcast_convert_type(
        jnp.where(near_f < target, up, down), jnp.float8_e4m3fn
    ).astype(jnp.float32)
    lo = jnp.minimum(near_f, other)
    hi = jnp.maximum(near_f, other)
    p_up = jnp.where(hi > lo, (target - lo) / jnp.maximum(hi - lo, 1e-30), 0.0)
    out = jnp.where(u_ref[...] < p_up, hi, lo)
    scales_ref[...] = jnp.where(near_f == target, near_f, out)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def ms_eden_requant(x: jax.Array, rht_key: jax.Array, sr_key: jax.Array,
                    *, bm: int = DEF_BM, interpret: bool = True):
    """Two-phase MS-EDEN re-quantization of x (M, K), K % 16 == 0.

    Returns (codes u8 (M,K) in ROTATED space, scales f32 (M,K/16) on the
    e4m3 grid, gscale f32) — consumed by fp4_matmul with a peer tensor
    rotated with the same key.
    """
    m, k = x.shape
    bm = min(bm, m)
    assert m % bm == 0 and k % F.GROUP == 0
    s = Q.S_EDEN
    b = R.block_size(k)
    dh = jnp.asarray(R.hadamard(b)) * R.sign_vector(rht_key, b)[:, None]

    grid1 = (m // bm,)
    codes, pseudo, num, den, amax_part = pl.pallas_call(
        functools.partial(_phase1_kernel, s=s),
        grid=grid1,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
            pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
            pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.uint8),
            jax.ShapeDtypeStruct((m, k // F.GROUP), jnp.float32),
            jax.ShapeDtypeStruct((m, k // F.GROUP), jnp.float32),
            jax.ShapeDtypeStruct((m, k // F.GROUP), jnp.float32),
            jax.ShapeDtypeStruct((m // bm, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, dh)

    absmax = jnp.max(amax_part)  # tiny cross-tile reduction (XLA)
    gscale = absmax / (s * 256.0)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    uniforms = jax.random.uniform(jax.random.wrap_key_data(sr_key),
                                  num.shape, jnp.float32)

    grid2 = (m // bm,)
    scales = pl.pallas_call(
        functools.partial(_phase2_kernel, s=s),
        grid=grid2,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
            pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
            pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
            pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, k // F.GROUP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k // F.GROUP), jnp.float32),
        interpret=interpret,
    )(absmax.reshape(1, 1), pseudo, num, den, uniforms)

    return codes, scales, gscale
