"""Pallas TPU kernel: fused Four-over-Six NVFP4 forward quantization.

One pass over the tensor in (BM, BK) VMEM tiles: per 16-group absmax, both
4/6 scale branches evaluated in-register, min-MSE branch selected, FP4 codes
+ E4M3 scales + dequantized bf16 values emitted. The global absmax arrives
as a scalar operand — on TPU it is fused into the producer of the tensor
(optimizer step for weights, norm/activation for activations), exactly the
paper's "abs-max reduction fused into the previous kernel" (App. D.1).

Block sizes default to MXU/VREG-aligned (128 rows x 512 lanes = 8 scale
groups of 16 x 4 sublane tiles); both are parameters so tests sweep them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import formats as F
from repro.core import quant as Q

DEF_BM = 128
DEF_BK = 512


def _fp4_rtn_vec(x):
    """Branchless round-to-nearest-even onto {0,.5,1,1.5,2,3,4,6} (+sign)."""
    mag = jnp.abs(x)
    # thresholds are the round-half-even decision points
    q = jnp.where(mag < 0.25, 0.0,
        jnp.where(mag <= 0.75, 0.5,
        jnp.where(mag < 1.25, 1.0,
        jnp.where(mag <= 1.75, 1.5,
        jnp.where(mag <= 2.5, 2.0,
        jnp.where(mag < 3.5, 3.0,
        jnp.where(mag <= 5.0, 4.0, 6.0)))))))
    return jnp.sign(x) * q


def _fp8_rtn_vec(x):
    """RTN to e4m3 via dtype round-trip (native converts on TPU)."""
    return jnp.clip(x, 0.0, F.FP8_MAX).astype(jnp.float8_e4m3fn).astype(jnp.float32)


def _fp4_code_vec(q):
    mag = jnp.abs(q)
    idx = jnp.where(mag < 0.25, 0,
          jnp.where(mag < 0.75, 1,
          jnp.where(mag < 1.25, 2,
          jnp.where(mag < 1.75, 3,
          jnp.where(mag < 2.5, 4,
          jnp.where(mag < 3.5, 5,
          jnp.where(mag < 5.0, 6, 7))))))).astype(jnp.uint8)
    sign = (q < 0).astype(jnp.uint8)
    return (sign << 3) | idx


def _kernel(gscale_ref, x_ref, deq_ref, codes_ref, scales_ref, *, s_hi: float):
    x = x_ref[...].astype(jnp.float32)
    bm, bk = x.shape
    g = x.reshape(bm, bk // F.GROUP, F.GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1)
    gsc = gscale_ref[0, 0]

    def branch(div):
        scales = _fp8_rtn_vec(gmax / (gsc * div))
        denom = jnp.repeat(scales, F.GROUP, axis=-1).reshape(bm, bk) * gsc
        safe = jnp.where(denom == 0, 1.0, denom)
        q = _fp4_rtn_vec(x / safe)
        deq = q * denom
        err = ((deq - x) ** 2).reshape(bm, bk // F.GROUP, F.GROUP).sum(-1)
        return scales, q, deq, err

    s6, q6, d6, e6 = branch(s_hi)
    s4, q4, d4, e4 = branch(s_hi * 4.0 / 6.0)
    use4 = e4 < e6
    use4e = jnp.repeat(use4, F.GROUP, axis=-1).reshape(bm, bk)
    scales_ref[...] = jnp.where(use4, s4, s6)
    q = jnp.where(use4e, q4, q6)
    codes_ref[...] = _fp4_code_vec(q)
    deq_ref[...] = jnp.where(use4e, d4, d6).astype(deq_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def nvfp4_fos_quant(x: jax.Array, *, bm: int = DEF_BM, bk: int = DEF_BK,
                    interpret: bool = True):
    """Fused 4/6 quantization. x: (M, K) -> (deq bf16, codes u8, scales f32,
    gscale f32 scalar). M % bm == 0, K % bk == 0, bk % 16 == 0."""
    m, k = x.shape
    bm, bk = min(bm, m), min(bk, k)
    assert m % bm == 0 and k % bk == 0 and bk % F.GROUP == 0
    s_hi = Q.S_EDEN
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    gscale = absmax / ((s_hi * 4.0 / 6.0) * F.FP8_MAX)
    gscale = jnp.where(gscale == 0, 1.0, gscale)

    grid = (m // bm, k // bk)
    deq, codes, scales = pl.pallas_call(
        functools.partial(_kernel, s_hi=s_hi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),          # gscale scalar
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),        # x tile
        ],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // F.GROUP), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.bfloat16),
            jax.ShapeDtypeStruct((m, k), jnp.uint8),
            jax.ShapeDtypeStruct((m, k // F.GROUP), jnp.float32),
        ],
        interpret=interpret,
    )(gscale.reshape(1, 1), x)
    return deq, codes, scales, gscale
