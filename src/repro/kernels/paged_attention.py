"""Pallas TPU kernel: block-table flash-decode over the paged KV pool.

The serving hot path used to materialize `serve.kv_pool.gather_view` — a
dense (B, MAXB*BS, ...) copy of each layer's pool — and then score against
the FULL table capacity every step. This kernel consumes the pool-shaped
leaves directly:

  - the (B, MAXB) block table and the (B,) position vector are
    SCALAR-PREFETCHED; each grid cell's BlockSpec index map resolves
    `table[b, j]` on the fly, so the pipeline DMAs exactly one physical
    pool block per (row, logical-block) cell and no gathered view ever
    exists in HBM;
  - the grid is (batch row, logical KV block) with the block axis
    innermost; a per-row online-softmax accumulator (m, l, acc) lives in
    VMEM scratch across the block sweep (flash-decode);
  - blocks that cannot contribute are SKIPPED, not masked after the fact:
    OOB-sentinel table entries (unallocated / inactive rows), blocks
    entirely beyond the row's newest query position (causal), and — for
    sliding-window `lattn` layers — blocks entirely older than the OLDEST
    query's window. Skipped cells clamp their index map to the last pool
    block and predicate out the compute, so the fetch is a buffer revisit,
    not extra traffic;
  - per-key masking inside a live block comes from absolute positions
    (key block j covers positions [j*BS, (j+1)*BS)), matching
    `models.attention.decode_sdpa`'s `kj <= qpos` / window rules exactly.

Two variants share the online-softmax update:

  gqa  — q (B, Sq, H, hd) vs K/V pools (P, BS, KV, hd)/(P, BS, KV, vd);
         grouped heads (rep = H // KV) broadcast over each KV head.
  mla  — absorbed-form latent decode: q_abs (B, Sq, H, lora) and
         q_rope (B, Sq, H, rope) vs the SHARED cc (P, BS, lora) /
         kc (P, BS, rope) pools; the score is q_abs·cc + q_rope·kc and
         the value readout is over cc itself (vd == lora != hd), so the
         kernel returns o_lat for the caller's w_uv absorption.

Sq >= 1 supports the engine's (n_slots, spec_k+1) speculative verify
chunks; query s of row b sits at absolute position pos[b] + s. Outputs are
fp32; callers cast. Fully-masked rows (inactive slots: all-sentinel table)
produce exact zeros (l == 0 guard), mirroring the reference path's
gathered-zeros result.

Each variant also has a `_q` twin consuming the NVFP4-quantized pool
(`serve.kv_pool.PackedKV`): the packed-operand BlockSpecs DMA uint8 e2m1
code pairs (d/2 bytes) plus uint8 e4m3 scale bits (d/16 bytes) per block —
0.28125x the bf16 HBM bytes — and `_dequant_tile` decodes them block-wise
in VMEM (arithmetic e2m1/e4m3 decode, no gathers) before the SAME online
softmax sweep. Dequant is exact in f32, so `_q` kernel outputs match the
gather-then-decode reference bit-for-bit at the operand level; the shared
sweep keeps the flash numerics identical across storage modes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import formats as F

NEG_INF = -1e30  # matches models.attention.NEG_INF


def _decode_e2m1(codes):
    """E2M1 decode without a gather: value = sign * m0 * 2^e with the 3-bit
    magnitude split as (e2, m1); subnormal pair {0, 0.5} special-cased
    (same arithmetic as kernels/fp4_matmul.py)."""
    c = codes.astype(jnp.int32)
    sign = jnp.where((c >> 3) & 1, -1.0, 1.0)
    e = (c >> 1) & 0x3
    m = c & 0x1
    mag = jnp.where(e == 0, 0.5 * m,
                    (1.0 + 0.5 * m) * jnp.exp2((e - 1).astype(jnp.float32)))
    return sign * mag


def _decode_e4m3_bits(bits):
    """E4M3 (float8_e4m3fn) decode from raw uint8 bits, arithmetically:
    (1 + m/8) * 2^(e-7) for normals, m/8 * 2^-6 subnormals. Cache scales
    are absmax-derived (non-negative, <= 448), so the sign bit is 0 and
    the NaN encoding (e=15, m=7) is unreachable — no bitcast needed in
    the kernel body."""
    b = bits.astype(jnp.int32)
    e = (b >> 3) & 0xF
    m = (b & 0x7).astype(jnp.float32)
    return jnp.where(e == 0, m * (0.125 * 2.0 ** -6),
                     (1.0 + m * 0.125)
                     * jnp.exp2((e - 7).astype(jnp.float32)))


def _dequant_tile(codes_ref, scales_ref):
    """Dequantize one packed pool block in VMEM: (1, BS, ..., d/2) uint8
    code pairs + (1, BS, ..., d/16) e4m3 scale bits -> (BS, ..., d) f32.
    Exact: every e2m1 x e4m3 product is f32 (and bf16) representable, so
    this sees bit-identical operands to the gather-path bf16 dequant."""
    packed = codes_ref[0]
    lo = (packed & 0xF).astype(jnp.uint8)
    hi = ((packed >> 4) & 0xF).astype(jnp.uint8)
    codes = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    scales = _decode_e4m3_bits(scales_ref[0])
    return _decode_e2m1(codes) * jnp.repeat(scales, F.GROUP, axis=-1)


def _positions(p0, sq: int, bs: int, j):
    """(Sq, BS) absolute key/query position grids for grid cell (row, j)."""
    kj = j * bs + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 1)
    qpos = p0 + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 0)
    return kj, qpos


def _online_update(s, ok, m_ref, l_ref, acc_ref, vals):
    """One flash step: fold masked scores `s` (..., Sq-ish, BS) and the block
    values into the running (m, l, acc) scratch. `vals` maps probabilities
    (..., BS) -> the block's value contribution, so the two variants share
    the numerics (exp of masked lanes is forced to exactly 0, and a block
    that changes nothing multiplies the accumulators by exactly 1.0)."""
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + vals(p)
    m_ref[...] = m_new


def _gqa_sweep(table_ref, pos_ref, q_ref, o_ref, m_ref, l_ref, acc_ref,
               load_kv, *, kv: int, vd: int, bs: int, sentinel: int,
               window: int | None, sqrt_hd: float):
    """Shared GQA flash sweep; `load_kv()` yields the cell's f32 (BS, KV,
    hd) / (BS, KV, vd) operands — a bf16 cast for the reference pool, a
    VMEM dequant for the packed one — so both storage modes run literally
    the same softmax/value arithmetic."""
    b, j = pl.program_id(0), pl.program_id(1)
    sq, h, hd = q_ref.shape[1], q_ref.shape[2], q_ref.shape[3]
    rep = h // kv

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p0 = pos_ref[b]
    pmax = p0 + sq - 1                      # newest query position in the row
    live = (table_ref[b, j] < sentinel) & (j * bs <= pmax)
    if window is not None:
        # skip blocks whose newest key predates even the OLDEST query's
        # window (older queries admit older keys, so p0 — not pmax — is
        # the skip horizon; partial overlap is masked per key below)
        live &= (j + 1) * bs - 1 > p0 - window

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)    # (Sq, H, hd)
        k, v = load_kv()                    # (BS, KV, hd), (BS, KV, vd) f32
        # grouped scores: (KV, Sq*rep, hd) x (KV, hd, BS) -> (KV, Sq*rep, BS)
        qg = q.reshape(sq, kv, rep, hd).transpose(1, 0, 2, 3)
        qg = qg.reshape(kv, sq * rep, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) / sqrt_hd
        s = s.reshape(kv, sq, rep, bs)
        kj, qpos = _positions(p0, sq, bs, j)
        ok = kj <= qpos
        if window is not None:
            ok &= kj > qpos - window
        ok = ok[None, :, None, :]           # (1, Sq, 1, BS)

        def vals(p):                        # (KV, Sq, rep, BS) -> value sum
            pv = jax.lax.dot_general(
                p.reshape(kv, sq * rep, bs), v.transpose(1, 0, 2),
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)
            return pv.reshape(kv, sq, rep, vd)

        _online_update(s, ok, m_ref, l_ref, acc_ref, vals)

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = o.transpose(1, 0, 2, 3).reshape(sq, h, vd)


def _gqa_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                m_ref, l_ref, acc_ref, *, bs: int, sentinel: int,
                window: int | None, sqrt_hd: float):
    _gqa_sweep(table_ref, pos_ref, q_ref, o_ref, m_ref, l_ref, acc_ref,
               lambda: (k_ref[0].astype(jnp.float32),
                        v_ref[0].astype(jnp.float32)),
               kv=k_ref.shape[2], vd=v_ref.shape[3], bs=bs,
               sentinel=sentinel, window=window, sqrt_hd=sqrt_hd)


def _gqa_q_kernel(table_ref, pos_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, bs: int, sentinel: int,
                  window: int | None, sqrt_hd: float):
    """Packed-operand twin: K/V arrive as e2m1 code pairs + e4m3 scale bits
    and dequantize in VMEM only for live cells."""
    _gqa_sweep(table_ref, pos_ref, q_ref, o_ref, m_ref, l_ref, acc_ref,
               lambda: (_dequant_tile(kc_ref, ks_ref),
                        _dequant_tile(vc_ref, vs_ref)),
               kv=kc_ref.shape[2], vd=vc_ref.shape[3] * 2, bs=bs,
               sentinel=sentinel, window=window, sqrt_hd=sqrt_hd)


def _mla_sweep(table_ref, pos_ref, qa_ref, qr_ref, o_ref,
               m_ref, l_ref, acc_ref, load_cc_kc, *, bs: int, sentinel: int,
               scale: float):
    """Shared MLA flash sweep; `load_cc_kc()` yields the cell's f32
    (BS, lora) / (BS, rope) latent operands (bf16 cast or VMEM dequant)."""
    b, j = pl.program_id(0), pl.program_id(1)
    sq, h, lora = qa_ref.shape[1], qa_ref.shape[2], qa_ref.shape[3]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p0 = pos_ref[b]
    live = (table_ref[b, j] < sentinel) & (j * bs <= p0 + sq - 1)

    @pl.when(live)
    def _block():
        qa = qa_ref[0].astype(jnp.float32).reshape(sq * h, lora)
        qr = qr_ref[0].astype(jnp.float32).reshape(sq * h, -1)
        cc, kc = load_cc_kc()               # (BS, lora), (BS, rope) f32
        s = (jnp.dot(qa, cc.T, preferred_element_type=jnp.float32)
             + jnp.dot(qr, kc.T, preferred_element_type=jnp.float32)) * scale
        s = s.reshape(sq, h, bs)
        kj, qpos = _positions(p0, sq, bs, j)
        ok = (kj <= qpos)[:, None, :]       # (Sq, 1, BS)

        def vals(p):                        # (Sq, H, BS) -> latent readout
            return jnp.dot(p.reshape(sq * h, bs), cc,
                           preferred_element_type=jnp.float32
                           ).reshape(sq, h, lora)

        _online_update(s, ok, m_ref, l_ref, acc_ref, vals)

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]


def _mla_kernel(table_ref, pos_ref, qa_ref, qr_ref, cc_ref, kc_ref, o_ref,
                m_ref, l_ref, acc_ref, *, bs: int, sentinel: int,
                scale: float):
    _mla_sweep(table_ref, pos_ref, qa_ref, qr_ref, o_ref,
               m_ref, l_ref, acc_ref,
               lambda: (cc_ref[0].astype(jnp.float32),
                        kc_ref[0].astype(jnp.float32)),
               bs=bs, sentinel=sentinel, scale=scale)


def _mla_q_kernel(table_ref, pos_ref, qa_ref, qr_ref, ccc_ref, ccs_ref,
                  kcc_ref, kcs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bs: int, sentinel: int, scale: float):
    """Packed-operand twin: both latent pools arrive as NVFP4 bytes."""
    _mla_sweep(table_ref, pos_ref, qa_ref, qr_ref, o_ref,
               m_ref, l_ref, acc_ref,
               lambda: (_dequant_tile(ccc_ref, ccs_ref),
                        _dequant_tile(kcc_ref, kcs_ref)),
               bs=bs, sentinel=sentinel, scale=scale)


def _table_spec_index(sentinel):
    """Index map resolving the physical pool block from the prefetched table
    (the whole point: the pipeline fetches `table[b, j]`, never a view).
    Sentinel entries clamp to the LAST pool block (sentinel - 1) — the
    cell's compute is predicated off, so the clamped fetch is a buffer
    revisit, not extra traffic."""
    def index(b, j, table_ref, pos_ref):
        return (jnp.minimum(table_ref[b, j], sentinel - 1), 0, 0, 0)
    return index


def paged_gqa_call(q, k_pool, v_pool, table, pos, *, window: int | None,
                   interpret: bool):
    b, sq, h, hd = q.shape
    n_blocks, bs, kv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    vd = v_pool.shape[3]
    maxb = table.shape[1]
    rep = h // kv
    sqrt_hd = float(np.sqrt(np.float32(hd)))  # matches decode_sdpa's divisor
    idx = _table_spec_index(n_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxb),
        in_specs=[
            pl.BlockSpec((1, sq, h, hd), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, kv, hd), idx),
            pl.BlockSpec((1, bs, kv, vd), idx),
        ],
        out_specs=pl.BlockSpec((1, sq, h, vd), lambda i, j, t, p: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, sq, rep), jnp.float32),
            pltpu.VMEM((kv, sq, rep), jnp.float32),
            pltpu.VMEM((kv, sq, rep, vd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gqa_kernel, bs=bs, sentinel=n_blocks,
                          window=window, sqrt_hd=sqrt_hd),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, vd), jnp.float32),
        interpret=interpret,
    )(table, pos, q, k_pool, v_pool)


def paged_gqa_q_call(q, k_codes, k_scales, v_codes, v_scales, table, pos, *,
                     window: int | None, interpret: bool):
    """GQA flash-decode over the NVFP4-packed pool: same grid, same index
    maps, but each pool operand is a (codes, scale-bits) uint8 pair whose
    BlockSpecs move 0.28125x the bf16 bytes per cell."""
    b, sq, h, hd = q.shape
    n_blocks, bs, kv = k_codes.shape[0], k_codes.shape[1], k_codes.shape[2]
    vd = v_codes.shape[3] * 2
    maxb = table.shape[1]
    rep = h // kv
    sqrt_hd = float(np.sqrt(np.float32(hd)))  # matches decode_sdpa's divisor
    idx = _table_spec_index(n_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxb),
        in_specs=[
            pl.BlockSpec((1, sq, h, hd), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, kv, hd // 2), idx),
            pl.BlockSpec((1, bs, kv, hd // F.GROUP), idx),
            pl.BlockSpec((1, bs, kv, vd // 2), idx),
            pl.BlockSpec((1, bs, kv, vd // F.GROUP), idx),
        ],
        out_specs=pl.BlockSpec((1, sq, h, vd), lambda i, j, t, p: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, sq, rep), jnp.float32),
            pltpu.VMEM((kv, sq, rep), jnp.float32),
            pltpu.VMEM((kv, sq, rep, vd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gqa_q_kernel, bs=bs, sentinel=n_blocks,
                          window=window, sqrt_hd=sqrt_hd),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, vd), jnp.float32),
        interpret=interpret,
    )(table, pos, q, k_codes, k_scales, v_codes, v_scales)


def paged_mla_call(q_abs, q_rope, cc_pool, kc_pool, table, pos, *,
                   scale: float, interpret: bool):
    b, sq, h, lora = q_abs.shape
    rope = q_rope.shape[3]
    n_blocks, bs = cc_pool.shape[0], cc_pool.shape[1]
    maxb = table.shape[1]
    idx = _table_spec_index(n_blocks)

    def pool_idx3(i, j, t, p):
        return idx(i, j, t, p)[:3]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxb),
        in_specs=[
            pl.BlockSpec((1, sq, h, lora), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, sq, h, rope), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, lora), pool_idx3),
            pl.BlockSpec((1, bs, rope), pool_idx3),
        ],
        out_specs=pl.BlockSpec((1, sq, h, lora),
                               lambda i, j, t, p: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sq, h), jnp.float32),
            pltpu.VMEM((sq, h), jnp.float32),
            pltpu.VMEM((sq, h, lora), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_kernel, bs=bs, sentinel=n_blocks, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, lora), jnp.float32),
        interpret=interpret,
    )(table, pos, q_abs, q_rope, cc_pool, kc_pool)


def paged_mla_q_call(q_abs, q_rope, cc_codes, cc_scales, kc_codes, kc_scales,
                     table, pos, *, scale: float, interpret: bool):
    """Absorbed-form MLA flash-decode over NVFP4-packed latent pools."""
    b, sq, h, lora = q_abs.shape
    rope = q_rope.shape[3]
    n_blocks, bs = cc_codes.shape[0], cc_codes.shape[1]
    maxb = table.shape[1]
    idx = _table_spec_index(n_blocks)

    def pool_idx3(i, j, t, p):
        return idx(i, j, t, p)[:3]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxb),
        in_specs=[
            pl.BlockSpec((1, sq, h, lora), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, sq, h, rope), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, lora // 2), pool_idx3),
            pl.BlockSpec((1, bs, lora // F.GROUP), pool_idx3),
            pl.BlockSpec((1, bs, rope // 2), pool_idx3),
            pl.BlockSpec((1, bs, rope // F.GROUP), pool_idx3),
        ],
        out_specs=pl.BlockSpec((1, sq, h, lora),
                               lambda i, j, t, p: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sq, h), jnp.float32),
            pltpu.VMEM((sq, h), jnp.float32),
            pltpu.VMEM((sq, h, lora), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_q_kernel, bs=bs, sentinel=n_blocks,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, lora), jnp.float32),
        interpret=interpret,
    )(table, pos, q_abs, q_rope, cc_codes, cc_scales, kc_codes, kc_scales)
