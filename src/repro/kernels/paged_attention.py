"""Pallas TPU kernel: block-table flash-decode over the paged KV pool.

The serving hot path used to materialize `serve.kv_pool.gather_view` — a
dense (B, MAXB*BS, ...) copy of each layer's pool — and then score against
the FULL table capacity every step. This kernel consumes the pool-shaped
leaves directly:

  - the (B, MAXB) block table and the (B,) position vector are
    SCALAR-PREFETCHED; each grid cell's BlockSpec index map resolves
    `table[b, j]` on the fly, so the pipeline DMAs exactly one physical
    pool block per (row, logical-block) cell and no gathered view ever
    exists in HBM;
  - the grid is (batch row, logical KV block) with the block axis
    innermost; a per-row online-softmax accumulator (m, l, acc) lives in
    VMEM scratch across the block sweep (flash-decode);
  - blocks that cannot contribute are SKIPPED, not masked after the fact:
    OOB-sentinel table entries (unallocated / inactive rows), blocks
    entirely beyond the row's newest query position (causal), and — for
    sliding-window `lattn` layers — blocks entirely older than the OLDEST
    query's window. Skipped cells clamp their index map to the last pool
    block and predicate out the compute, so the fetch is a buffer revisit,
    not extra traffic;
  - per-key masking inside a live block comes from absolute positions
    (key block j covers positions [j*BS, (j+1)*BS)), matching
    `models.attention.decode_sdpa`'s `kj <= qpos` / window rules exactly.

Two variants share the online-softmax update:

  gqa  — q (B, Sq, H, hd) vs K/V pools (P, BS, KV, hd)/(P, BS, KV, vd);
         grouped heads (rep = H // KV) broadcast over each KV head.
  mla  — absorbed-form latent decode: q_abs (B, Sq, H, lora) and
         q_rope (B, Sq, H, rope) vs the SHARED cc (P, BS, lora) /
         kc (P, BS, rope) pools; the score is q_abs·cc + q_rope·kc and
         the value readout is over cc itself (vd == lora != hd), so the
         kernel returns o_lat for the caller's w_uv absorption.

Sq >= 1 supports the engine's (n_slots, spec_k+1) speculative verify
chunks; query s of row b sits at absolute position pos[b] + s. Outputs are
fp32; callers cast. Fully-masked rows (inactive slots: all-sentinel table)
produce exact zeros (l == 0 guard), mirroring the reference path's
gathered-zeros result.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # matches models.attention.NEG_INF


def _positions(p0, sq: int, bs: int, j):
    """(Sq, BS) absolute key/query position grids for grid cell (row, j)."""
    kj = j * bs + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 1)
    qpos = p0 + jax.lax.broadcasted_iota(jnp.int32, (sq, bs), 0)
    return kj, qpos


def _online_update(s, ok, m_ref, l_ref, acc_ref, vals):
    """One flash step: fold masked scores `s` (..., Sq-ish, BS) and the block
    values into the running (m, l, acc) scratch. `vals` maps probabilities
    (..., BS) -> the block's value contribution, so the two variants share
    the numerics (exp of masked lanes is forced to exactly 0, and a block
    that changes nothing multiplies the accumulators by exactly 1.0)."""
    s = jnp.where(ok, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + vals(p)
    m_ref[...] = m_new


def _gqa_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                m_ref, l_ref, acc_ref, *, bs: int, sentinel: int,
                window: int | None, sqrt_hd: float):
    b, j = pl.program_id(0), pl.program_id(1)
    sq, h = q_ref.shape[1], q_ref.shape[2]
    kv, hd = k_ref.shape[2], k_ref.shape[3]
    rep, vd = h // kv, v_ref.shape[3]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p0 = pos_ref[b]
    pmax = p0 + sq - 1                      # newest query position in the row
    live = (table_ref[b, j] < sentinel) & (j * bs <= pmax)
    if window is not None:
        # skip blocks whose newest key predates even the OLDEST query's
        # window (older queries admit older keys, so p0 — not pmax — is
        # the skip horizon; partial overlap is masked per key below)
        live &= (j + 1) * bs - 1 > p0 - window

    @pl.when(live)
    def _block():
        q = q_ref[0].astype(jnp.float32)    # (Sq, H, hd)
        k = k_ref[0].astype(jnp.float32)    # (BS, KV, hd)
        v = v_ref[0].astype(jnp.float32)    # (BS, KV, vd)
        # grouped scores: (KV, Sq*rep, hd) x (KV, hd, BS) -> (KV, Sq*rep, BS)
        qg = q.reshape(sq, kv, rep, hd).transpose(1, 0, 2, 3)
        qg = qg.reshape(kv, sq * rep, hd)
        s = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0), (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) / sqrt_hd
        s = s.reshape(kv, sq, rep, bs)
        kj, qpos = _positions(p0, sq, bs, j)
        ok = kj <= qpos
        if window is not None:
            ok &= kj > qpos - window
        ok = ok[None, :, None, :]           # (1, Sq, 1, BS)

        def vals(p):                        # (KV, Sq, rep, BS) -> value sum
            pv = jax.lax.dot_general(
                p.reshape(kv, sq * rep, bs), v.transpose(1, 0, 2),
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)
            return pv.reshape(kv, sq, rep, vd)

        _online_update(s, ok, m_ref, l_ref, acc_ref, vals)

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = o.transpose(1, 0, 2, 3).reshape(sq, h, vd)


def _mla_kernel(table_ref, pos_ref, qa_ref, qr_ref, cc_ref, kc_ref, o_ref,
                m_ref, l_ref, acc_ref, *, bs: int, sentinel: int,
                scale: float):
    b, j = pl.program_id(0), pl.program_id(1)
    sq, h, lora = qa_ref.shape[1], qa_ref.shape[2], qa_ref.shape[3]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p0 = pos_ref[b]
    live = (table_ref[b, j] < sentinel) & (j * bs <= p0 + sq - 1)

    @pl.when(live)
    def _block():
        qa = qa_ref[0].astype(jnp.float32).reshape(sq * h, lora)
        qr = qr_ref[0].astype(jnp.float32).reshape(sq * h, -1)
        cc = cc_ref[0].astype(jnp.float32)  # (BS, lora)
        kc = kc_ref[0].astype(jnp.float32)  # (BS, rope)
        s = (jnp.dot(qa, cc.T, preferred_element_type=jnp.float32)
             + jnp.dot(qr, kc.T, preferred_element_type=jnp.float32)) * scale
        s = s.reshape(sq, h, bs)
        kj, qpos = _positions(p0, sq, bs, j)
        ok = (kj <= qpos)[:, None, :]       # (Sq, 1, BS)

        def vals(p):                        # (Sq, H, BS) -> latent readout
            return jnp.dot(p.reshape(sq * h, bs), cc,
                           preferred_element_type=jnp.float32
                           ).reshape(sq, h, lora)

        _online_update(s, ok, m_ref, l_ref, acc_ref, vals)

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]


def _table_spec_index(sentinel):
    """Index map resolving the physical pool block from the prefetched table
    (the whole point: the pipeline fetches `table[b, j]`, never a view).
    Sentinel entries clamp to the LAST pool block (sentinel - 1) — the
    cell's compute is predicated off, so the clamped fetch is a buffer
    revisit, not extra traffic."""
    def index(b, j, table_ref, pos_ref):
        return (jnp.minimum(table_ref[b, j], sentinel - 1), 0, 0, 0)
    return index


def paged_gqa_call(q, k_pool, v_pool, table, pos, *, window: int | None,
                   interpret: bool):
    b, sq, h, hd = q.shape
    n_blocks, bs, kv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    vd = v_pool.shape[3]
    maxb = table.shape[1]
    rep = h // kv
    sqrt_hd = float(np.sqrt(np.float32(hd)))  # matches decode_sdpa's divisor
    idx = _table_spec_index(n_blocks)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxb),
        in_specs=[
            pl.BlockSpec((1, sq, h, hd), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, kv, hd), idx),
            pl.BlockSpec((1, bs, kv, vd), idx),
        ],
        out_specs=pl.BlockSpec((1, sq, h, vd), lambda i, j, t, p: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv, sq, rep), jnp.float32),
            pltpu.VMEM((kv, sq, rep), jnp.float32),
            pltpu.VMEM((kv, sq, rep, vd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_gqa_kernel, bs=bs, sentinel=n_blocks,
                          window=window, sqrt_hd=sqrt_hd),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, vd), jnp.float32),
        interpret=interpret,
    )(table, pos, q, k_pool, v_pool)


def paged_mla_call(q_abs, q_rope, cc_pool, kc_pool, table, pos, *,
                   scale: float, interpret: bool):
    b, sq, h, lora = q_abs.shape
    rope = q_rope.shape[3]
    n_blocks, bs = cc_pool.shape[0], cc_pool.shape[1]
    maxb = table.shape[1]
    idx = _table_spec_index(n_blocks)

    def pool_idx3(i, j, t, p):
        return idx(i, j, t, p)[:3]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, maxb),
        in_specs=[
            pl.BlockSpec((1, sq, h, lora), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, sq, h, rope), lambda i, j, t, p: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, lora), pool_idx3),
            pl.BlockSpec((1, bs, rope), pool_idx3),
        ],
        out_specs=pl.BlockSpec((1, sq, h, lora),
                               lambda i, j, t, p: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sq, h), jnp.float32),
            pltpu.VMEM((sq, h), jnp.float32),
            pltpu.VMEM((sq, h, lora), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_mla_kernel, bs=bs, sentinel=n_blocks, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, lora), jnp.float32),
        interpret=interpret,
    )(table, pos, q_abs, q_rope, cc_pool, kc_pool)
