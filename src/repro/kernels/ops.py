"""Public jit'd entry points for the Pallas kernels (the `ops.py` layer of
the kernel contract: <name>.py kernel + ops.py wrapper + ref.py oracle).

On real TPU hardware pass interpret=False; this container validates in
interpret mode (the kernel bodies execute in Python on CPU).
"""

from __future__ import annotations

import jax

from repro.kernels.fp4_matmul import fp4_matmul
from repro.kernels.ms_eden_requant import ms_eden_requant
from repro.kernels.nvfp4_quant import nvfp4_fos_quant

__all__ = ["nvfp4_fos_quant", "ms_eden_requant", "fp4_matmul",
           "quartet2_backward_gemm"]


def quartet2_backward_gemm(a, b, rht_key, sr_key_a, sr_key_b, *,
                           interpret: bool = True):
    """Fused kernel-path backward GEMM a @ b^T with MS-EDEN re-quantization
    of both operands (rotations share `rht_key` and cancel in the product) —
    the kernel-level composition of paper Fig. 3's backward box:

        requant(a), requant(b)  ->  packed codes + scales  ->  fp4_matmul
    """
    ac, ascale, ag = ms_eden_requant(a, rht_key, sr_key_a, interpret=interpret)
    bc, bscale, bg = ms_eden_requant(b, rht_key, sr_key_b, interpret=interpret)
    from repro.core.formats import pack_fp4
    return fp4_matmul(pack_fp4(ac), ascale, pack_fp4(bc), bscale, ag, bg,
                      interpret=interpret)
