"""Public jit'd entry points for the Pallas kernels (the `ops.py` layer of
the kernel contract: <name>.py kernel + ops.py wrapper + ref.py oracle).

On real TPU hardware pass interpret=False; this container validates in
interpret mode (the kernel bodies execute in Python on CPU). The paged
decode kernels resolve `interpret=None` from the active backend so the
serving engine can call them unconditionally.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import paged_attention as PA
from repro.kernels.fp4_matmul import fp4_matmul
from repro.kernels.ms_eden_requant import ms_eden_requant
from repro.kernels.nvfp4_quant import nvfp4_fos_quant

__all__ = ["nvfp4_fos_quant", "ms_eden_requant", "fp4_matmul",
           "quartet2_backward_gemm", "paged_attention",
           "paged_mla_attention", "paged_attention_q",
           "paged_mla_attention_q"]


def _resolve_interpret(interpret: bool | None) -> bool:
    """Kernels compile only on TPU; anywhere else (CPU CI, the dry-run
    host mesh) they run in interpret mode."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q, k_pool, v_pool, table, pos, *, window: int | None = None,
                    interpret: bool | None = None):
    """Flash-decode GQA attention straight off the paged KV pool.

    q: (B, Sq, H, hd); k_pool: (P, BS, KV, hd); v_pool: (P, BS, KV, vd);
    table: (B, MAXB) int32 block table (OOB sentinel == P for unallocated
    entries); pos: (B,) absolute position of each row's first query token.
    Equivalent to `decode_sdpa(q, gather_view(k_pool, table),
    gather_view(v_pool, table), pos, window=window)` without ever
    materializing the gathered views. Returns (B, Sq, H, vd) in q.dtype.
    """
    out = PA.paged_gqa_call(q, k_pool, v_pool, table,
                            jnp.asarray(pos, jnp.int32), window=window,
                            interpret=_resolve_interpret(interpret))
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("qk_dim", "interpret"))
def paged_mla_attention(q_abs, q_rope, cc_pool, kc_pool, table, pos, *,
                        qk_dim: int, interpret: bool | None = None):
    """Absorbed-form MLA flash-decode over the shared latent pools.

    q_abs: (B, Sq, H, lora) — q_nope already absorbed through W_uk;
    q_rope: (B, Sq, H, rope); cc_pool: (P, BS, lora); kc_pool: (P, BS,
    rope). Scores are (q_abs·cc + q_rope·kc) / sqrt(qk_dim) and the value
    readout is over cc itself, so the fp32 result is o_lat (B, Sq, H, lora)
    for the caller's W_uv absorption (vd != hd: the whole point of MLA).
    """
    # the f32 image of mla_decode's 1/sqrt(nope+rope), so kernel and
    # reference multiply by the identical scalar
    scale = float(np.float32(1.0) / np.sqrt(np.float32(qk_dim)))
    return PA.paged_mla_call(q_abs, q_rope, cc_pool, kc_pool, table,
                             jnp.asarray(pos, jnp.int32), scale=scale,
                             interpret=_resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention_q(q, k_codes, k_scales, v_codes, v_scales, table, pos, *,
                      window: int | None = None,
                      interpret: bool | None = None):
    """Flash-decode GQA attention off the NVFP4-QUANTIZED paged pool.

    Packed-operand twin of `paged_attention`: K/V arrive as the quantized
    pool's raw leaves — e2m1 code pairs (P, BS, KV, hd/2) uint8 + e4m3
    scale bits (P, BS, KV, hd/16) uint8 per operand (the fields of
    serve.kv_pool.PackedKV, passed unbundled so this layer never imports
    serve) — and dequantize block-wise in VMEM inside the online-softmax
    sweep. Equivalent to `paged_attention` over the dequantized pools;
    the dequant is exact in f32/bf16, so parity with the gather-then-
    decode reference is the same contract as the bf16 kernel's.
    """
    out = PA.paged_gqa_q_call(q, k_codes, k_scales, v_codes, v_scales, table,
                              jnp.asarray(pos, jnp.int32), window=window,
                              interpret=_resolve_interpret(interpret))
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("qk_dim", "interpret"))
def paged_mla_attention_q(q_abs, q_rope, cc_codes, cc_scales, kc_codes,
                          kc_scales, table, pos, *, qk_dim: int,
                          interpret: bool | None = None):
    """Absorbed-form MLA flash-decode over NVFP4-QUANTIZED latent pools
    (packed-operand twin of `paged_mla_attention`; operands are the
    unbundled PackedKV leaves of the cc / kc pools)."""
    scale = float(np.float32(1.0) / np.sqrt(np.float32(qk_dim)))
    return PA.paged_mla_q_call(q_abs, q_rope, cc_codes, cc_scales, kc_codes,
                               kc_scales, table,
                               jnp.asarray(pos, jnp.int32), scale=scale,
                               interpret=_resolve_interpret(interpret))


def quartet2_backward_gemm(a, b, rht_key, sr_key_a, sr_key_b, *,
                           interpret: bool = True):
    """Fused kernel-path backward GEMM a @ b^T with MS-EDEN re-quantization
    of both operands (rotations share `rht_key` and cancel in the product) —
    the kernel-level composition of paper Fig. 3's backward box:

        requant(a), requant(b)  ->  packed codes + scales  ->  fp4_matmul
    """
    ac, ascale, ag = ms_eden_requant(a, rht_key, sr_key_a, interpret=interpret)
    bc, bscale, bg = ms_eden_requant(b, rht_key, sr_key_b, interpret=interpret)
    from repro.core.formats import pack_fp4
    return fp4_matmul(pack_fp4(ac), ascale, pack_fp4(bc), bscale, ag, bg,
                      interpret=interpret)
