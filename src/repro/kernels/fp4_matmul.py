"""Pallas TPU kernel: NVFP4 GEMM from packed 4-bit codes + E4M3 scales.

C[M, N] = (decode(Ac) * As) @ (decode(Bc) * Bs)^T * (ga * gb)

HBM traffic per element is 4 bits (packed codes) + 0.5 bits (scales) versus
16 for bf16 — on TPU (no FP4 MXU) this is exactly where the NVFP4 win lives:
the dequant runs in-VMEM on the VPU and the MXU consumes bf16 block values
(lossless: 2 + 4 significant bits, see core/linear.py), accumulating fp32.

Grid (M/bm, N/bn, K/bk), K innermost; the fp32 accumulator lives in the
output block across the K sweep (revisited blocks stay resident in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import formats as F

DEF_BM = 128
DEF_BN = 128
DEF_BK = 512


def _decode_vec(codes):
    """E2M1 decode without a gather: value = sign * m0 * 2^e with the 3-bit
    magnitude split as (e2, m1). mag = (1 + 0.5*m) * 2^(e-1), special-casing
    the subnormal pair {0, 0.5}."""
    c = codes.astype(jnp.int32)
    sign = jnp.where((c >> 3) & 1, -1.0, 1.0)
    e = (c >> 1) & 0x3
    m = c & 0x1
    mag = jnp.where(e == 0, 0.5 * m, (1.0 + 0.5 * m) * jnp.exp2((e - 1).astype(jnp.float32)))
    return sign * mag


def _kernel(ap_ref, as_ref, bp_ref, bs_ref, g_ref, o_ref, *, bk: int):
    k_idx = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def tile(p_ref, s_ref):
        packed = p_ref[...]
        lo = (packed & 0xF).astype(jnp.uint8)
        hi = ((packed >> 4) & 0xF).astype(jnp.uint8)
        codes = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
        vals = _decode_vec(codes)
        scales = jnp.repeat(s_ref[...].astype(jnp.float32), F.GROUP, axis=-1)
        return (vals * scales).astype(jnp.bfloat16)  # lossless block values

    a = tile(ap_ref, as_ref)
    b = tile(bp_ref, bs_ref)
    acc = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] += acc

    @pl.when(k_idx == nk - 1)
    def _scale():
        o_ref[...] *= g_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fp4_matmul(a_packed, a_scales, b_packed, b_scales, ga, gb,
               *, bm: int = DEF_BM, bn: int = DEF_BN, bk: int = DEF_BK,
               interpret: bool = True):
    """a_packed (M, K//2) u8, a_scales (M, K//16); b likewise (N-major).
    Returns fp32 (M, N)."""
    m, kp = a_packed.shape
    n = b_packed.shape[0]
    k = kp * 2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % F.GROUP == 0
    g = (ga * gb).astype(jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        functools.partial(_kernel, bk=bk),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk // 2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk // F.GROUP), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 2), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // F.GROUP), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a_packed, a_scales, b_packed, b_scales, g)
