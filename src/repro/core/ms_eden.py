"""MS-EDEN (paper Algorithm 1): unbiased NVFP4 quantization for micro-scaled
formats, and its ER-NVFP4 "post hoc range alignment" two-phase variant
(paper Section 7) that the Pallas kernels implement.

Direct path (Algorithm 1):
  1. blocked RHT (block 128) seeded by w_rht,
  2. Q_RTN with grid max s* = (1/0.93)*6*16/17 and FP8 scale cap 256,
  3. EDEN factor per 16-group: S_g = <x_rht, x_rht> / <x_rht, x_rtn>,
  4. merge S_g into the E4M3 group scales by stochastic rounding (w_sr).

The result is expressed in ROTATED space; unbiasedness holds after the
inverse rotation (Corollary 3.1), which in a GEMM cancels against the other
operand rotated with the same seed, so no inverse is ever materialized.

Post-hoc path (two kernels, no global-absmax barrier):
  phase 1 (full tensor, tile-local): RHT -> E8M3 pseudo-scales p_g (no global
    normalization) -> FP4 codes -> per-tile absmax partials + EDEN dots;
  phase 2 (scales only, d/16 elements): global align p_g/fp32, EDEN-correct,
    SR to E4M3.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import quant as Q
from repro.core import rht as R


class MSEdenOut(NamedTuple):
    qt: Q.QTensor      # NVFP4 triple, values live in ROTATED space
    rht_key: jax.Array  # seed needed by the GEMM peer / inverse rotation


def _eden_factors(x_rot: jax.Array, x_rtn: jax.Array) -> jax.Array:
    """Per-16-group EDEN correction S_g = <x,x>/<x,Q(x)> (1.0 for zero groups)."""
    g = F.GROUP
    xr = x_rot.reshape(*x_rot.shape[:-1], x_rot.shape[-1] // g, g)
    xq = x_rtn.reshape(*x_rtn.shape[:-1], x_rtn.shape[-1] // g, g)
    num = jnp.sum(xr * xr, axis=-1)
    den = jnp.sum(xr * xq, axis=-1)
    return jnp.where(den != 0, num / jnp.where(den == 0, 1.0, den), 1.0)


def ms_eden(
    x: jax.Array,
    rht_key: jax.Array,
    sr_key: jax.Array,
    s: float = Q.S_EDEN,
) -> MSEdenOut:
    """Algorithm 1. Returns NVFP4 QTensor in rotated space."""
    x_rot = R.rht(x, rht_key)
    qt = Q.quant_rtn(x_rot, s=s, fp8_cap=256.0)
    x_rtn = Q.dequant(qt)
    S = _eden_factors(x_rot, x_rtn)
    scales = F.fp8_sr_pos(S * qt.scales, sr_key)
    return MSEdenOut(Q.QTensor(qt.vals, scales, qt.gscale), rht_key)


def ms_eden_dequant(out: MSEdenOut, rotated: bool = True) -> jax.Array:
    """Dequantize; rotated=False additionally applies the inverse rotation
    (only used by tests — GEMMs consume the rotated representation)."""
    v = Q.dequant(out.qt)
    if rotated:
        return v
    return R.rht_inv(v, out.rht_key)


# ---------------------------------------------------------------------------
# ER-NVFP4 post-hoc range alignment (paper Section 7) — reference semantics.
# The Pallas kernel in repro/kernels/ms_eden_requant.py implements phase 1;
# phase 2 is the tiny scales-only kernel.
# ---------------------------------------------------------------------------

class Phase1Out(NamedTuple):
    codes: jax.Array         # uint8 FP4 codes (rotated space)
    pseudo_scales: jax.Array  # E8M3 pseudo-scales (bf16-exact), (..., d//16)
    absmax: jax.Array        # global absmax of the ROTATED tensor (scalar)
    eden_num: jax.Array      # <x_rht, x_rht> per group
    eden_den: jax.Array      # <x_rht, deq_pseudo> per group


def ms_eden_phase1(x: jax.Array, rht_key: jax.Array, s: float = Q.S_EDEN) -> Phase1Out:
    """Kernel-1 semantics: everything computable without the global absmax."""
    x_rot = R.rht(x, rht_key)
    gmax = Q._group_absmax(x_rot)
    pseudo = F.e8m3_rtn(gmax / s)                     # extended-range scales
    denom = jnp.repeat(jnp.where(pseudo == 0, 1.0, pseudo), F.GROUP, axis=-1)
    q = F.fp4_rtn(x_rot / denom)
    deq = q * denom
    g = F.GROUP
    xr = x_rot.reshape(*x_rot.shape[:-1], x_rot.shape[-1] // g, g)
    xq = deq.reshape(*deq.shape[:-1], deq.shape[-1] // g, g)
    return Phase1Out(
        codes=F.fp4_code(q),  # wire format (kernel parity); hot path unused
        pseudo_scales=pseudo,
        absmax=jnp.max(jnp.abs(x_rot)),
        eden_num=jnp.sum(xr * xr, axis=-1),
        eden_den=jnp.sum(xr * xq, axis=-1),
    )


def ms_eden_phase2(p1: Phase1Out, sr_key: jax.Array, s: float = Q.S_EDEN) -> Q.QTensor:
    """Kernel-2 semantics: scales-only global alignment + EDEN + SR->E4M3.

    Touches d/16 elements — mirrors the paper's >10x latency asymmetry.
    """
    gscale = p1.absmax / (s * 256.0)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    S = jnp.where(p1.eden_den != 0,
                  p1.eden_num / jnp.where(p1.eden_den == 0, 1.0, p1.eden_den),
                  1.0)
    scales = F.fp8_sr_pos(S * p1.pseudo_scales / gscale, sr_key)
    return Q.QTensor(F.fp4_decode(p1.codes), scales, gscale)
