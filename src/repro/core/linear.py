"""QuartetLinear: the fully-NVFP4 linear-layer computation graph (paper Fig. 3)
as a jax.custom_vjp, parameterized by a Scheme.

Simulated-NVFP4 GEMM semantics (TPU adaptation, see DESIGN.md Section 2):
the MXU consumes bf16 "block values" (fp4_code * e4m3_scale, exactly
representable in bf16 because 2 + 4 significant bits < 8), accumulates in
fp32, and the two per-tensor FP32 scales multiply the GEMM output — precisely
what a Blackwell NVFP4 tensor core computes, so results are bit-faithful to
hardware NVFP4 up to fp32 accumulation order.

Backward orientation (inner dims):
    Y  = X  @ W^T    inner K   (forward quantizers, groups along K)
    dX = E  @ W      inner N   (E rows and W^T rows quantized along N)
    dW = E^T @ X     inner M   (E^T and X^T quantized along M = batch*seq)

Activations are saved for the backward pass as *packed NVFP4* (uint8 nibble
pairs + e4m3 scales = 4.5 bits/element) whenever the forward quantizes them —
the memory-roofline lever on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import ms_eden as ME
from repro.core import quant as Q
from repro.core import rht as R
from repro.core import schemes as S


# --------------------------------------------------------------------------
# sharding hints (set by launch/dryrun before lowering; None on single-host)
#
# Perf iteration 1 (EXPERIMENTS.md §Perf): without these, GSPMD loses the
# token-dim sharding at the RHT block reshape whenever the inner-dim shard is
# not a multiple of 128 (e.g. d_ff=11008 over 16 devices = 688), and falls
# back to REPLICATING the (tokens x d) gradient operands on every device —
# ~5x redundant compute and memory traffic. Constraining rows(tokens)->DP,
# weight-rows->TP and keeping the quantization/rotation axis local fixes the
# partitioning for every backward GEMM.
# --------------------------------------------------------------------------

# {"dp": ("pod","data") | ("data",), "tp": "model", "dp_size": int, "tp_size": int}
MESH_AXES: dict | None = None

import contextlib


@contextlib.contextmanager
def no_hints():
    """Trace-time hint suppression: vmapped per-expert GEMMs already live in
    the EP-optimal (E->model, capacity, d) layout; the token-level hints
    would force a reshard of every dispatch buffer (measured 18x collective
    blow-up on deepseek-v3 — Perf iteration 6)."""
    global MESH_AXES
    old = MESH_AXES
    MESH_AXES = None
    try:
        yield
    finally:
        MESH_AXES = old


def _hint(x: jax.Array, spec: tuple) -> jax.Array:
    if MESH_AXES is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


def _dp(dim: int):
    """DP axes if the dim divides evenly, else None (replicate)."""
    if MESH_AXES is None or dim % max(MESH_AXES["dp_size"], 1):
        return None
    return MESH_AXES["dp"]


def _tp(dim: int):
    if MESH_AXES is None or dim % max(MESH_AXES["tp_size"], 1):
        return None
    return MESH_AXES["tp"]


def _tp_inner(dim: int, block: int):
    """TP for a quantization/rotation axis only if every shard holds whole
    blocks (RHT 128-blocks / scale 16-groups stay device-local). Perf
    iteration 3: keeps E (tokens x N) model-sharded through the dX GEMM
    instead of all-gathering it every layer."""
    if MESH_AXES is None or dim % (max(MESH_AXES["tp_size"], 1) * block):
        return None
    return MESH_AXES["tp"]


UNC = jax.sharding.PartitionSpec.UNCONSTRAINED


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _key(seed: jax.Array, tag: int) -> jax.Array:
    """Derive a typed PRNG key from a uint32[2] seed and an integer tag."""
    k = jax.random.wrap_key_data(seed.astype(jnp.uint32))
    return jax.random.fold_in(k, tag)


def _block_values(qt: Q.QTensor) -> jax.Array:
    """fp4 * e4m3 block values in bf16 (lossless), without the fp32 gscale."""
    s = jnp.repeat(qt.scales, F.GROUP, axis=-1)
    return (qt.vals * s).astype(jnp.bfloat16)


def _qmm(qa: Q.QTensor, qb: Q.QTensor) -> jax.Array:
    """Simulated NVFP4 GEMM: (Ma, D) x (Mb, D) -> (Ma, Mb) in fp32."""
    a = _block_values(qa)
    b = _block_values(qb)
    out = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    return out * (qa.gscale * qb.gscale)


def _mm(a: jax.Array, b: jax.Array) -> jax.Array:
    """bf16 GEMM (Ma, D) x (Mb, D) -> (Ma, Mb), fp32 accumulation."""
    return jax.lax.dot_general(
        a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def _fwd_quant(x: jax.Array, kind: str) -> Q.QTensor:
    if kind == "rtn":
        return Q.quant_rtn(x, s=Q.S_EDEN)
    if kind == "fos":
        return Q.quant_four_over_six(x)
    if kind == "square":
        return Q.quant_square_block(x)
    raise ValueError(f"unknown forward quantizer {kind}")


def quant_sr_fos(x: jax.Array, key: jax.Array) -> Q.QTensor:
    """FourOverSix backward quantizer: deterministic min-MSE branch choice
    (between the absmax->s* and absmax->s**4/6 clipping grids, same
    placements as the RTN 4/6 — reproduces the paper's 17.5e-3 Table-1 row)
    followed by SR. Both the branch choice AND the SR-through-clipping
    introduce bias (paper Sec. 4.2, App. A Fig. 9)."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    gscale = absmax / ((Q.S_EDEN * 4.0 / 6.0) * F.FP8_MAX)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    gmax = Q._group_absmax(xf)

    def branch(div):
        scales = F.fp8_rtn(gmax / (gscale * div))
        denom = jnp.repeat(scales, F.GROUP, axis=-1) * gscale
        xs = Q._safe_div(xf, denom)
        deq_rtn = F.fp4_rtn(xs) * denom
        g = (deq_rtn - xf).reshape(*xf.shape[:-1], xf.shape[-1] // F.GROUP, F.GROUP)
        return scales, xs, jnp.sum(g * g, axis=-1)

    s6, xs6, m6 = branch(Q.S_EDEN)
    s4, xs4, m4 = branch(Q.S_EDEN * 4.0 / 6.0)
    use4 = m4 < m6
    scales = jnp.where(use4, s4, s6)
    xs = jnp.where(jnp.repeat(use4, F.GROUP, axis=-1), xs4, xs6)
    q = F.fp4_sr(xs, key)
    return Q.QTensor(q, scales, gscale)


def _pad_rows_to(x: jax.Array, mult: int) -> jax.Array:
    """Zero-pad the last axis to a multiple of `mult` (safe for GEMM sums)."""
    d = x.shape[-1]
    pad = (-d) % mult
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])


def _bwd_gemm(
    a: jax.Array,            # (Ma, D)
    b: jax.Array,            # (Mb, D)
    bwd: str,                # sr | sr_fos | ms_eden
    quant_a: bool,
    quant_b: bool,
    use_rht: bool,
    seed: jax.Array,
    tag: int,
    specs: tuple | None = None,  # ((rows_a, cols_a), (rows_b, cols_b)) hints
) -> jax.Array:
    """One backward GEMM a @ b^T with per-scheme quantization on inner dim D."""
    if not (quant_a or quant_b):
        return _mm(a, b)

    d = a.shape[-1]
    mult = 128 if (d % 128) else 16  # pad target for grouping/rotation
    a = _pad_rows_to(a, 16 if not use_rht else mult)
    b = _pad_rows_to(b, 16 if not use_rht else mult)
    # fp32 BEFORE the hints: counterintuitively measured better — bf16-domain
    # hints made GSPMD re-gather post-cast (iter 4/5 refuted, +75% wire);
    # fp32-domain constraints keep one gather per operand (iter 2, best).
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if specs is not None:
        a = _hint(a, specs[0])
        b = _hint(b, specs[1])

    k_rht = _key(seed, tag)
    k_a = _key(seed, tag + 1)
    k_b = _key(seed, tag + 2)

    if bwd == "ms_eden":
        assert quant_a and quant_b and use_rht, "MS-EDEN requires re-quantizing both operands"
        qa = ME.ms_eden(a, k_rht, k_a).qt
        qb = ME.ms_eden(b, k_rht, k_b).qt
        return _qmm(qa, qb)  # rotations cancel along D

    quantizer = Q.quant_sr if bwd == "sr" else quant_sr_fos
    ar = R.rht(a, k_rht) if (use_rht and quant_a and quant_b) else a
    br = R.rht(b, k_rht) if (use_rht and quant_a and quant_b) else b
    if quant_a and quant_b:
        return _qmm(quantizer(ar, k_a), quantizer(br, k_b))
    if quant_a:
        return _mm(Q.dequant(quantizer(ar, k_a), jnp.bfloat16), br)
    return _mm(ar, Q.dequant(quantizer(br, k_b), jnp.bfloat16))


# --------------------------------------------------------------------------
# packed NVFP4 residuals (activation memory: 4.5 bits/element)
# --------------------------------------------------------------------------

def _pack_qt(qt: Q.QTensor):
    packed = F.pack_fp4(qt.codes)
    scales8 = jnp.clip(qt.scales, 0, F.FP8_MAX).astype(jnp.float8_e4m3fn)
    return packed, scales8, qt.gscale


def _unpack_qt(res) -> Q.QTensor:
    packed, scales8, gscale = res
    return Q.QTensor(F.fp4_decode(F.unpack_fp4(packed)),
                     scales8.astype(jnp.float32), gscale)


# --------------------------------------------------------------------------
# quantize-once weights (serving): the deterministic forward quantizers make
# W's NVFP4 image a pure function of W, so inference packs it ONCE and decode
# never re-runs weight quantization (serve/prequant.py builds these).
# --------------------------------------------------------------------------

import typing


class PackedQWeight(typing.NamedTuple):
    """An offline-packed NVFP4 weight: 4.5 bits/element at rest.

    Bit-exact round trip: `packed` holds E2M1 codes (2/byte), `scales8` the
    e4m3 group scales (both produced by the same `_fwd_quant` the per-step
    path runs), so unpacking reproduces the per-step QTensor exactly.
    A NamedTuple => a pytree: stacked-layer stacks scan/vmap transparently.
    """

    packed: jax.Array   # uint8 (..., N, K // 2)
    scales8: jax.Array  # float8_e4m3fn (..., N, K // 16)
    gscale: jax.Array   # float32 (...,) per-tensor scale

    @property
    def out_features(self) -> int:
        return self.packed.shape[-2]


def pack_weight(w: jax.Array, kind: str) -> PackedQWeight:
    """Quantize one 2D weight with forward quantizer `kind` and pack it."""
    packed, scales8, gscale = _pack_qt(_fwd_quant(w, kind))
    return PackedQWeight(packed, scales8, gscale)


def _qlinear_packed(x: jax.Array, w: PackedQWeight, scheme: str) -> jax.Array:
    """Inference forward against a prequantized weight.

    Bit-identical to `_qlinear_fwd` on the raw weight: the activation side
    still quantizes per call (activations change every step; weights don't).
    """
    sch = S.get(scheme)
    assert sch.fwd_w != "none", \
        f"scheme {scheme} does not quantize weights; pass the raw array"
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    qw = _unpack_qt((w.packed, w.scales8, w.gscale))
    if sch.fwd_x != "none":
        y = _qmm(_fwd_quant(xf, sch.fwd_x), qw)
    else:
        y = _mm(xf, Q.dequant(qw, jnp.bfloat16))
    return y.astype(x.dtype).reshape(*lead, -1)


# --------------------------------------------------------------------------
# the custom-vjp linear
# --------------------------------------------------------------------------

def qlinear(x: jax.Array, w, seed: jax.Array, scheme: str = "quartet2"):
    """y = x @ w^T under the given quantization scheme.

    x: (..., K) activations; w: (N, K) weight — raw array (training) or
    PackedQWeight (quantize-once serving); seed: uint32[2] per-step/site
    randomness (ignored by deterministic schemes).
    """
    if isinstance(w, PackedQWeight):
        return _qlinear_packed(x, w, scheme)
    return _qlinear_cvjp(x, w, seed, scheme)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _qlinear_cvjp(x: jax.Array, w: jax.Array, seed: jax.Array,
                  scheme: str = "quartet2"):
    y, _ = _qlinear_fwd(x, w, seed, scheme)
    return y


def _qlinear_fwd(x, w, seed, scheme):
    sch = S.get(scheme)
    lead = x.shape[:-1]
    k = x.shape[-1]
    xf = x.reshape(-1, k)

    if not sch.is_quantized:
        y = _mm(xf, w).astype(x.dtype)
        return y.reshape(*lead, -1), (x, w, seed)

    # Megatron-style fwd layout: tokens -> DP, weight out-dim -> TP, the
    # quantization axis K local. (Perf iter 3 tried UNCONSTRAINED K — refuted:
    # +75% all-gather wire; the explicit column layout measures best.)
    xf = _hint(xf, (_dp(xf.shape[0]), None))
    w = _hint(w, (_tp(w.shape[0]), None))
    qx = _fwd_quant(xf, sch.fwd_x) if sch.fwd_x != "none" else None
    qw = _fwd_quant(w, sch.fwd_w) if sch.fwd_w != "none" else None

    if qx is not None and qw is not None:
        y = _qmm(qx, qw)
    elif qx is not None:
        y = _mm(Q.dequant(qx, jnp.bfloat16), w)
    elif qw is not None:
        y = _mm(xf, Q.dequant(qw, jnp.bfloat16))
    else:
        y = _mm(xf, w)
    y = y.astype(x.dtype).reshape(*lead, -1)

    # Save activations as packed NVFP4 when the forward quantized them
    # (paper Sec. 5: backward re-quantizes the SAVED quantized activations).
    x_res = _pack_qt(qx) if qx is not None else x
    return y, (x_res, w, seed)


def _qlinear_bwd(scheme, res, e):
    sch = S.get(scheme)
    x_res, w, seed = res
    n, k = w.shape
    lead = e.shape[:-1]
    ef = e.reshape(-1, n)  # stays bf16 until after sharding hints
    m = ef.shape[0]

    if isinstance(x_res, tuple):
        xf = Q.dequant(_unpack_qt(x_res))          # (M, K) fp32, NVFP4-exact
    else:
        xf = x_res.reshape(-1, k).astype(jnp.float32)

    if not sch.is_quantized or sch.bwd == "none":
        dx = _mm(ef, w.T)                          # (M, K)
        dw = _mm(ef.T, xf.T)                       # (N, K)
    else:
        m_pad = m + ((-m) % 128)
        # dX operands: tokens -> DP, W^T rows (K) -> TP, inner dim N local.
        # (Perf iter 3 tried keeping N TP-sharded — refuted: the row-parallel
        # dX partial-sum all-reduces cost 2x more wire than the bf16 E
        # gather; see EXPERIMENTS.md §Perf.)
        dx_specs = ((_dp(m_pad), None), (_tp(k), None))
        # dW operands: E^T rows = N -> TP; X^T rows follow; inner dim M
        # (tokens) stays DP-sharded — XLA reduces partial dW with a single
        # all-reduce, and 128-token RHT blocks stay shard-local.
        # X^T rows pinned replicated (UNC let GSPMD model-gather X — refuted
        # in Perf iter 4; explicit None keeps X purely DP-sharded on tokens)
        dw_specs = ((_tp(n), _dp(m_pad)), (None, _dp(m_pad)))

        # ---- dX = E @ W (inner dim N) ----
        if sch.quant_dx_e:
            if sch.dx_w_mode == "requant":
                # de-quantize saved W, re-quantize along N with shared RHT
                w_saved = (Q.dequant(_fwd_quant(w, sch.fwd_w))
                           if sch.fwd_w != "none" else w.astype(jnp.float32))
                dx = _bwd_gemm(ef, w_saved.T, sch.bwd, True, True,
                               use_rht=True, seed=seed, tag=1, specs=dx_specs)
            elif sch.dx_w_mode == "reuse":
                assert sch.fwd_w == "square", "scale reuse needs square blocks"
                wq = Q.dequant(_fwd_quant(w, "square"), jnp.bfloat16)
                dx = _bwd_gemm(ef, wq.T, sch.bwd, True, False,
                               use_rht=False, seed=seed, tag=1, specs=dx_specs)
            else:  # "bf16"
                dx = _bwd_gemm(ef, w.T.astype(jnp.float32), sch.bwd, True, False,
                               use_rht=False, seed=seed, tag=1, specs=dx_specs)
        else:
            dx = _mm(ef, w.T)

        # ---- dW = E^T @ X (inner dim M) ----
        if sch.quant_dw_e or sch.quant_dw_x:
            dw = _bwd_gemm(ef.T, xf.T, sch.bwd, sch.quant_dw_e, sch.quant_dw_x,
                           use_rht=sch.rht_dw, seed=seed, tag=4, specs=dw_specs)
        else:
            dw = _mm(ef.T, xf.T)

    dx = dx.reshape(*lead, k).astype(e.dtype)
    dw = dw.astype(w.dtype)
    return dx, dw, None


_qlinear_cvjp.defvjp(_qlinear_fwd, _qlinear_bwd)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain bf16 linear (router / frontends / optionally LM head)."""
    out = jax.lax.dot_general(
        x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
        (((x.ndim - 1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    return out.astype(x.dtype)
