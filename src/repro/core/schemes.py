"""Recipe registry: which quantizer touches which tensor of which GEMM.

A `Scheme` describes the full linear-layer computation graph of one training
recipe (paper Section 5 + Figure 1 ablations + Section 2 baselines):

forward  Y = Qf(X) @ Qf(W)^T                      (inner dim K)
backward dX = Qb(E) @ Qb(W^T)^T                   (inner dim N)
         dW = Qb(E^T) @ Qb(X^T)^T                 (inner dim M)

RHT is applied on the inner dimension of a backward GEMM whenever BOTH of its
operands are (re)quantized (paper Section 6.1), with a shared seed so the
rotations cancel inside the dot product.
"""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Scheme:
    name: str
    # forward quantizers: "none" | "rtn" | "fos" (four-over-six) | "square"
    fwd_x: str = "none"
    fwd_w: str = "none"
    # backward quantizer family: "none" | "sr" | "sr_fos" | "ms_eden"
    bwd: str = "none"
    # dX GEMM: quantize E? and how to treat W^T:
    #   "bf16"    - keep W in bf16 (Fig. 1 b/d)
    #   "reuse"   - reuse the forward QTensor without re-quantization (NVIDIA;
    #               requires fwd_w == "square" for orientation-correct scales)
    #   "requant" - de-quantize the saved forward W and re-quantize along N
    quant_dx_e: bool = False
    dx_w_mode: str = "requant"
    # dW GEMM: quantize E^T / X^T?
    quant_dw_e: bool = False
    quant_dw_x: bool = False

    @property
    def is_quantized(self) -> bool:
        return self.fwd_x != "none" or self.fwd_w != "none" or self.bwd != "none"

    @property
    def rht_dx(self) -> bool:
        """RHT on the dX GEMM iff both operands are freshly quantized."""
        return self.quant_dx_e and self.dx_w_mode == "requant" and self.bwd != "none"

    @property
    def rht_dw(self) -> bool:
        return self.quant_dw_e and self.quant_dw_x and self.bwd != "none"


_REGISTRY: dict[str, Scheme] = {}


def register(s: Scheme) -> Scheme:
    _REGISTRY[s.name] = s
    return s


def get(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scheme '{name}'; have {sorted(_REGISTRY)}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


# --- end-to-end recipes ----------------------------------------------------

BF16 = register(Scheme(name="bf16"))

# NVIDIA et al. (2025): square-block W on forward (reused un-re-quantized in
# the dX GEMM, hence no RHT there), RHT+SR on the dW GEMM.
NVIDIA = register(Scheme(
    name="nvidia", fwd_x="rtn", fwd_w="square", bwd="sr",
    quant_dx_e=True, dx_w_mode="reuse", quant_dw_e=True, quant_dw_x=True,
))

# TetraJet-v2 as operationalized by the paper (Section 2): native-1x16 RTN
# forward, SR + inner-dim RHT with re-quantization on both backward GEMMs.
TETRAJET_V2 = register(Scheme(
    name="tetrajet_v2", fwd_x="rtn", fwd_w="rtn", bwd="sr",
    quant_dx_e=True, dx_w_mode="requant", quant_dw_e=True, quant_dw_x=True,
))

# FourOverSix (Cook et al. 2025): 4/6 forward; their backward combines 4/6
# grid selection with SR -> biased (paper Section 4.2 / Appendix A).
FOUR_OVER_SIX = register(Scheme(
    name="four_over_six", fwd_x="fos", fwd_w="fos", bwd="sr_fos",
    quant_dx_e=True, dx_w_mode="requant", quant_dw_e=True, quant_dw_x=True,
))

# Quartet II (this paper): 4/6 RTN forward with native scales; MS-EDEN with
# weight re-quantization on both backward GEMMs.
QUARTET2 = register(Scheme(
    name="quartet2", fwd_x="fos", fwd_w="fos", bwd="ms_eden",
    quant_dx_e=True, dx_w_mode="requant", quant_dw_e=True, quant_dw_x=True,
))

# Forward-pass-only ablations (paper Figure 2).
register(Scheme(name="fwd_rtn_1x16", fwd_x="rtn", fwd_w="rtn"))
register(Scheme(name="fwd_rtn_1x16_fos", fwd_x="fos", fwd_w="fos"))
register(Scheme(name="fwd_square", fwd_x="rtn", fwd_w="square"))
# 4/6 on activations only: square W scales don't benefit from 4/6 (Table 1).
register(Scheme(name="fwd_square_fos", fwd_x="fos", fwd_w="square"))

# Backward-pass-only ablations (paper Figure 1 (a)-(e)); forward stays bf16.
# "sr_fos" (4/6 + SR) is included for the App.-A bias demonstration (Fig. 9).
for q in ("sr", "ms_eden", "sr_fos"):
    register(Scheme(  # (a) dW GEMM only
        name=f"abl_a_{q}", bwd=q, quant_dw_e=True, quant_dw_x=True))
    if q == "sr":  # (b)/(d) keep W in bf16 -> MS-EDEN inapplicable (Sec. 6.1)
        register(Scheme(  # (b) dX only, W in bf16
            name=f"abl_b_{q}", bwd=q, quant_dx_e=True, dx_w_mode="bf16"))
        register(Scheme(  # (d) both GEMMs, W in bf16
            name=f"abl_d_{q}", bwd=q, quant_dx_e=True, dx_w_mode="bf16",
            quant_dw_e=True, quant_dw_x=True))
    register(Scheme(  # (c) dX only, W re-quantized
        name=f"abl_c_{q}", bwd=q, quant_dx_e=True, dx_w_mode="requant"))
    register(Scheme(  # (e) both GEMMs, W re-quantized
        name=f"abl_e_{q}", bwd=q, quant_dx_e=True, dx_w_mode="requant",
        quant_dw_e=True, quant_dw_x=True))


def variant(base: str, **kw) -> Scheme:
    """Derive an unregistered one-off scheme from a registered one."""
    return replace(get(base), name=f"{base}:{','.join(f'{k}={v}' for k, v in kw.items())}", **kw)
