"""Numeric formats for NVFP4 micro-scaled quantization.

NVFP4 represents a tensor as
  - FP4 E2M1 element codes (grid {0, .5, 1, 1.5, 2, 3, 4, 6} x sign),
  - one FP8 E4M3 scale per group of 16 contiguous inner-dim elements,
  - one FP32 scale per tensor.

This module provides the scalar format primitives shared by every quantizer:
E2M1 encode/decode (RTN and stochastic), E4M3 round-to-nearest and stochastic
rounding via uint8 bit manipulation, the E8M3 extended-range pseudo-scale proxy
(paper Section 7, represented in bf16), and 4-bit code (un)packing.

Everything is pure jnp and dtype-exact: values produced here are bit-exactly
representable in the target formats, so the simulated-NVFP4 GEMMs on the bf16
MXU see exactly the numbers a Blackwell FP4 tensor core would.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# E2M1 (FP4) grid
# --------------------------------------------------------------------------

# Non-negative representable magnitudes of E2M1, ascending.
FP4_GRID = np.asarray([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32)
FP4_MAX = 6.0
# Midpoints between adjacent grid magnitudes (round-to-nearest-even thresholds;
# E2M1 ties round to even mantissa, i.e. 0.25->0.5? No: tie at 0.25 rounds to
# 0.0 (even). We implement round-half-to-even per the IEEE-style rule used by
# hardware casts).
_FP4_MID = (FP4_GRID[:-1] + FP4_GRID[1:]) / 2.0  # [.25, .75, 1.25, 1.75, 2.5, 3.5, 5]
# grid index parity: even-mantissa grid points win ties.
# index:      0    1    2    3    4    5    6    7
# value:      0   .5    1  1.5    2    3    4    6
# mantissa:   0    1    0    1    0    1    0    1   (M1 bit)
_FP4_EVEN = np.asarray([True, False, True, False, True, False, True, False])

# E4M3 (float8_e4m3fn) constants
FP8_MAX = 448.0
# Largest relative increase RTN_FP8 can apply to a positive value: for e4m3 the
# mantissa step is 2^-3, so the worst case is rounding up from just above a
# power of two: x -> x * (1 + 1/16) at most, hence the paper's 16/17 margin.
FP8_RTN_MARGIN = 16.0 / 17.0

GROUP = 16  # NVFP4 micro-scaling group size
RHT_BLOCK = 128  # rotation block size (paper App. A: d=128)


def fp4_rtn(x: jax.Array) -> jax.Array:
    """Round-to-nearest(-even) onto the E2M1 grid. Values beyond +-6 clip.

    Pure arithmetic (nested selects, round-half-even thresholds baked in):
    no searchsorted/argmin/int32 intermediates — this is the training
    hot path, executed on every GEMM operand (Perf iteration 2,
    EXPERIMENTS.md §Perf).
    """
    xf = x.astype(jnp.float32)
    m = jnp.abs(xf)
    q = jnp.where(m <= 0.25, 0.0,
        jnp.where(m < 0.75, 0.5,
        jnp.where(m <= 1.25, 1.0,
        jnp.where(m < 1.75, 1.5,
        jnp.where(m <= 2.5, 2.0,
        jnp.where(m < 3.5, 3.0,
        jnp.where(m <= 5.0, 4.0, 6.0)))))))
    return jnp.sign(xf) * q


def fp4_code(x: jax.Array) -> jax.Array:
    """Encode FP4-grid values into 4-bit codes (uint8 in [0,15]).

    Layout: bit3 = sign, bits2..0 = grid index. Assumes x already on grid.
    """
    xf = x.astype(jnp.float32)
    m = jnp.abs(xf)
    idx = (jnp.where(m < 0.25, 0,
           jnp.where(m < 0.75, 1,
           jnp.where(m < 1.25, 2,
           jnp.where(m < 1.75, 3,
           jnp.where(m < 2.5, 4,
           jnp.where(m < 3.5, 5,
           jnp.where(m < 5.0, 6, 7)))))))).astype(jnp.uint8)
    sign = (xf < 0).astype(jnp.uint8)
    return (sign << 3) | idx


def fp4_decode(code: jax.Array) -> jax.Array:
    """Decode 4-bit codes back to float32 grid values."""
    grid = jnp.asarray(FP4_GRID)
    idx = (code & 0x7).astype(jnp.int32)
    sign = jnp.where((code >> 3) & 1, -1.0, 1.0)
    return sign * grid[idx]


def fp4_sr(x: jax.Array, key: jax.Array) -> jax.Array:
    """Stochastic rounding onto the E2M1 grid.

    P(round up) = (x - lo) / (hi - lo). UNBIASED ONLY FOR |x| <= 6: beyond
    the grid edge the value saturates deterministically to +-6, which is a
    (silent) bias. That saturation is deliberate — matching hardware
    converts — so the unbiasedness contract is the CALLER's to uphold via
    the scale chain: `s = fp8_rtn(absmax_g / (FP4_MAX * FP8_RTN_MARGIN))`
    bounds every normalized magnitude by exactly 6 (the 16/17 margin
    absorbs the worst-case e4m3 round-down), so no in-contract caller ever
    lands in the saturating branch. `fp4_overflow_fraction` is the debug
    probe for that invariant (tests/test_quant.py pins the boundary).
    """
    xf = x.astype(jnp.float32)
    mag = jnp.clip(jnp.abs(xf), 0.0, FP4_MAX)
    grid = jnp.asarray(FP4_GRID)
    # lo index: largest grid point <= mag
    idx_lo = jnp.clip(jnp.searchsorted(grid, mag, side="right") - 1, 0, 7)
    idx_hi = jnp.clip(idx_lo + 1, 0, 7)
    lo = grid[idx_lo]
    hi = grid[idx_hi]
    span = jnp.maximum(hi - lo, 1e-30)
    p_up = jnp.clip((mag - lo) / span, 0.0, 1.0)
    u = jax.random.uniform(key, shape=xf.shape, dtype=jnp.float32)
    q = jnp.where(u < p_up, hi, lo)
    return jnp.sign(xf) * q


def fp4_overflow_fraction(x: jax.Array) -> jax.Array:
    """Fraction of elements whose magnitude exceeds the E2M1 grid edge.

    Debug probe for the fp4_sr / fp4_rtn saturation contract: any caller
    that normalizes with the 16/17-margin scale chain must see exactly 0.0
    here. Nonzero means the silent-clip bias fp4_sr documents is active.
    """
    return jnp.mean((jnp.abs(x.astype(jnp.float32)) > FP4_MAX)
                    .astype(jnp.float32))


# --------------------------------------------------------------------------
# E4M3 (float8_e4m3fn)
# --------------------------------------------------------------------------

def fp8_rtn(x: jax.Array) -> jax.Array:
    """Round-to-nearest-even to float8_e4m3fn, returned as float32.

    Saturates at +-448 (e4m3fn has no inf; casting overflow yields NaN, so we
    clip first, matching hardware saturating converts).
    """
    xf = jnp.clip(x.astype(jnp.float32), -FP8_MAX, FP8_MAX)
    return xf.astype(jnp.float8_e4m3fn).astype(jnp.float32)


def _fp8_bits(x8: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x8, jnp.uint8)


def _bits_fp8(u8: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(u8, jnp.float8_e4m3fn)


def fp8_sr_pos(x: jax.Array, key: jax.Array) -> jax.Array:
    """Stochastic rounding of NON-NEGATIVE values to float8_e4m3fn (as f32).

    Used for merging EDEN correction factors into group scales (Alg. 1 last
    loop). Implementation walks the e4m3 lattice via uint8 bit arithmetic:
    for positive e4m3fn, adjacent representable values differ by +-1 ulp in
    the bit pattern (0x00=0 ... 0x7E=448; 0x7F=NaN).

    Subnormal underflow: the paper (App. A, item 3) skips SR on underflowing
    scales; values below the smallest subnormal round deterministically via
    RTN, matching that simplification.
    """
    xf = jnp.clip(x.astype(jnp.float32), 0.0, FP8_MAX)
    near = xf.astype(jnp.float8_e4m3fn)           # RNE neighbour
    near_f = near.astype(jnp.float32)
    bits = _fp8_bits(near)
    # Other neighbour: one ulp toward x.
    up_bits = jnp.minimum(bits + 1, jnp.uint8(0x7E))
    down_bits = jnp.where(bits > 0, bits - 1, jnp.uint8(0))
    other_bits = jnp.where(near_f < xf, up_bits, down_bits)
    other_f = _bits_fp8(other_bits).astype(jnp.float32)
    lo = jnp.minimum(near_f, other_f)
    hi = jnp.maximum(near_f, other_f)
    span = hi - lo
    p_up = jnp.where(span > 0, (xf - lo) / jnp.maximum(span, 1e-30), 0.0)
    p_up = jnp.clip(p_up, 0.0, 1.0)
    u = jax.random.uniform(key, shape=xf.shape, dtype=jnp.float32)
    out = jnp.where(u < p_up, hi, lo)
    # exactly representable -> keep
    return jnp.where(near_f == xf, near_f, out)


# --------------------------------------------------------------------------
# E8M3: extended-range FP8 proxy (paper Section 7), emulated in bf16.
# Same 3 mantissa bits as e4m3 but full 8-bit exponent range -> never
# overflows for pseudo-scales computed before global range alignment.
# --------------------------------------------------------------------------

def e8m3_rtn(x: jax.Array) -> jax.Array:
    """Round positive values to 3 mantissa bits with unbounded exponent.

    This is the ER-NVFP4 pseudo-scale format: bf16-representable (bf16 has
    7 mantissa bits >= 3, and 8 exponent bits), so storing the result in bf16
    is exact — exactly the paper's 'E8M3 represented in BF16'.
    """
    xf = x.astype(jnp.float32)
    m, e = jnp.frexp(jnp.maximum(xf, 1e-38))
    # m in [0.5, 1); quantize m to 4 bits after the point (1+3 mantissa bits
    # once renormalized: m = 0.1xxx_2): step 2^-4.
    mq = jnp.round(m * 16.0) / 16.0
    out = jnp.ldexp(mq, e)
    return jnp.where(xf <= 0, 0.0, out).astype(jnp.float32)


# --------------------------------------------------------------------------
# 4-bit packing (2 codes per byte) — the wire/HBM layout used by kernels and
# by NVFP4 gradient compression.
# --------------------------------------------------------------------------

def pack_fp4(codes: jax.Array) -> jax.Array:
    """Pack uint8 codes in [0,15] pairwise along the last axis (even size)."""
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_fp4(packed: jax.Array) -> jax.Array:
    """Inverse of pack_fp4."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# --------------------------------------------------------------------------
# NVFP4 cache codec — the storage format of the quantized paged KV pool
# (serve/kv_pool.py `quantized=True`).
#
# Per-token, per-16-group along the LAST (feature) axis, deterministic RTN,
# unit per-tensor scale. Determinism matters twice over: a token's packed
# image is a pure function of its bf16 value, so (a) prefix-cache re-runs
# produce byte-identical blocks (hot == cold), and (b) tokens can be
# quantized independently at scatter time — no cross-token state, no
# "retire the block first" staging.
#
# Storage is uint8 twice: e2m1 codes packed two per byte (d/2 bytes) and
# e4m3 scales as RAW BITS (d/16 bytes), so a cached feature dim d costs
# d/2 + d/16 = 0.5625 d bytes vs 2 d for bf16 — a 0.28125x ratio.
#
# Dequant is EXACT in bf16: an e2m1 magnitude (<= 2 significand bits) times
# an e4m3 scale (<= 4) has <= 6 significand bits and magnitude <= 2688,
# both within bf16 — so a bf16 gather-path dequant and an f32 in-kernel
# dequant see bit-identical operands (tests/test_kv_quant.py pins this).
# --------------------------------------------------------------------------

def nvfp4_cache_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantize cache values to NVFP4 packed bytes (deterministic RTN).

    Groups of 16 along the last axis (which must divide by GROUP and be
    even). Returns `(codes, scale_bits)`: uint8 packed e2m1 pairs of shape
    (..., d/2) and uint8 e4m3 scale bits of shape (..., d/16). The 16/17
    scale margin guarantees normalized magnitudes never exceed 6, so
    `fp4_rtn` never saturates on this path (`fp4_overflow_fraction == 0`).
    """
    xf = x.astype(jnp.float32)
    g = xf.reshape(*xf.shape[:-1], -1, GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1)
    scale = fp8_rtn(gmax / (FP4_MAX * FP8_RTN_MARGIN))
    q = fp4_rtn(g / jnp.where(scale > 0, scale, 1.0)[..., None])
    codes = fp4_code(q).reshape(xf.shape)
    return pack_fp4(codes), _fp8_bits(scale.astype(jnp.float8_e4m3fn))


def nvfp4_cache_decode(codes: jax.Array, scale_bits: jax.Array,
                       dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of nvfp4_cache_encode (exact in bf16 and wider)."""
    vals = fp4_decode(unpack_fp4(codes))
    scales = _bits_fp8(scale_bits).astype(jnp.float32)
    return (vals * jnp.repeat(scales, GROUP, axis=-1)).astype(dtype)


def nvfp4_cache_overflow(x: jax.Array) -> jax.Array:
    """Debug-mode overflow detector for the cache-quantization path.

    Replays the encode scale chain and reports the fraction of normalized
    magnitudes beyond the E2M1 edge — the quantity the 16/17 margin pins
    to zero. Wired behind `KVPool(debug=True)`; never on the hot path.
    """
    xf = x.astype(jnp.float32)
    g = xf.reshape(*xf.shape[:-1], -1, GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1)
    scale = fp8_rtn(gmax / (FP4_MAX * FP8_RTN_MARGIN))
    return fp4_overflow_fraction(g / jnp.where(scale > 0, scale, 1.0)[..., None])
