"""NVFP4 quantizers: Q_SR, Q_RTN(s), Four-over-Six, square-block (16x16).

All quantizers operate along the LAST axis (the GEMM inner dimension) with
micro-scaling groups of 16, an E4M3 scale per group, and one FP32 scale per
tensor. They return a `QTensor`; `dequant` reconstructs the represented
values exactly (bit-exact NVFP4 arithmetic: fp4 * fp8 * fp32).

Conventions follow the paper Section 3.1/3.3:
  Q_SR:   x_fp32 = absmax / (6 * 16/17 * 448)
          s_g    = RTN_FP8(absmax_g / (x_fp32 * 6 * 16/17))
          q_i    = SR_FP4(x_i / (s_g * x_fp32))            (never clips)
  Q_RTN:  x_fp32 = absmax / (s * 256)                      (FP8 cap 256)
          s_g    = RTN_FP8(absmax_g / (x_fp32 * s))
          q_i    = RTN_FP4(x_i / (s_g * x_fp32))           (may clip)
          with s* = (1/0.93) * 6 * 16/17 minimizing N(0,1) MSE.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import formats as F

# MSE-optimal clipping grid max for Q_RTN over N(0,1) (paper Section 3.3).
S_EDEN = (1.0 / 0.93) * 6.0 * F.FP8_RTN_MARGIN
# Non-clipping grid max (classic NVFP4 RTN / SR).
S_NOCLIP = 6.0 * F.FP8_RTN_MARGIN


class QTensor(NamedTuple):
    """An NVFP4-represented tensor (values = vals * scales * gscale).

    `vals` holds the E2M1 grid VALUES (f32) — the training hot path never
    encodes/decodes 4-bit integers (Perf iteration 2); `codes` derives the
    uint8 wire format lazily for packing / kernels / gradient compression.
    """

    vals: jax.Array    # f32 on the E2M1 grid, same shape as the source tensor
    scales: jax.Array  # float32 on the E4M3 grid, shape (..., d // 16)
    gscale: jax.Array  # float32 scalar, per-tensor

    @property
    def codes(self) -> jax.Array:
        return F.fp4_code(self.vals)

    @property
    def values(self) -> jax.Array:
        return dequant(self)


def dequant(qt: QTensor, dtype=jnp.float32) -> jax.Array:
    s = jnp.repeat(qt.scales, F.GROUP, axis=-1)
    return (qt.vals * s * qt.gscale).astype(dtype)


def _group_absmax(x: jax.Array) -> jax.Array:
    """(..., d) -> (..., d//16) group absolute maxima."""
    g = x.reshape(*x.shape[:-1], x.shape[-1] // F.GROUP, F.GROUP)
    return jnp.max(jnp.abs(g), axis=-1)


def _safe_div(a: jax.Array, b: jax.Array) -> jax.Array:
    return a / jnp.where(b == 0, 1.0, b)


def quant_sr(x: jax.Array, key: jax.Array) -> QTensor:
    """Element-wise stochastic rounding NVFP4 (unbiased; paper Section 3.1)."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    gscale = absmax / (6.0 * F.FP8_RTN_MARGIN * F.FP8_MAX)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    scales = F.fp8_rtn(_group_absmax(xf) / (gscale * 6.0 * F.FP8_RTN_MARGIN))
    denom = jnp.repeat(scales, F.GROUP, axis=-1) * gscale
    q = F.fp4_sr(_safe_div(xf, denom), key)
    return QTensor(q, scales, gscale)


def quant_rtn(
    x: jax.Array,
    s: float = S_NOCLIP,
    fp8_cap: float = F.FP8_MAX,
) -> QTensor:
    """Deterministic RTN NVFP4 with grid max `s` and FP8 scale cap (Sec. 3.3).

    fp8_cap=256 leaves headroom for the EDEN correction to scale groups up.
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    gscale = absmax / (s * fp8_cap)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    scales = F.fp8_rtn(_group_absmax(xf) / (gscale * s))
    denom = jnp.repeat(scales, F.GROUP, axis=-1) * gscale
    q = F.fp4_rtn(_safe_div(xf, denom))  # clips at +-6 when s > 6*16/17
    return QTensor(q, scales, gscale)


def quant_four_over_six(x: jax.Array, s: float = S_EDEN) -> QTensor:
    """Four-over-Six (Cook et al. 2025): per 16-group, evaluate the absmax->6
    and absmax->4 grid placements and keep the lower-MSE branch.

    Both branches use the MSE-optimal slightly-clipping grid placement (the
    "6" branch puts absmax at s* ~= 6.07, the "4" branch at s* * 4/6); this
    reproduces the paper's Table-1 value of 7.6e-3 (we measure 7.5e-3),
    whereas naive non-clipping {6,4} branches only reach ~9.1e-3.

    Deterministic (RTN inside each branch); the branch choice makes the
    overall map biased, so this is a FORWARD-pass quantizer only (Sec. 4.2).
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf))
    # Global scale sized for the /4 branch (scales 1.5x larger than /6).
    gscale = absmax / ((s * 4.0 / 6.0) * F.FP8_MAX)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    gmax = _group_absmax(xf)

    def branch(div: float):
        scales = F.fp8_rtn(gmax / (gscale * div))
        denom = jnp.repeat(scales, F.GROUP, axis=-1) * gscale
        q = F.fp4_rtn(_safe_div(xf, denom))
        deq = q * denom
        g = (deq - xf).reshape(*xf.shape[:-1], xf.shape[-1] // F.GROUP, F.GROUP)
        mse = jnp.sum(g * g, axis=-1)
        return scales, q, mse

    s6, q6, m6 = branch(s)
    s4, q4, m4 = branch(s * 4.0 / 6.0)
    use4 = m4 < m6
    scales = jnp.where(use4, s4, s6)
    q = jnp.where(jnp.repeat(use4, F.GROUP, axis=-1), q4, q6)
    return QTensor(q, scales, gscale)


def quant_square_block(x: jax.Array) -> QTensor:
    """NVIDIA-recipe square-block quantization: one E4M3 scale per 16x16 tile
    (weights only; makes the scale orientation-agnostic so W^T can be reused
    on the backward pass without re-quantization). x must be 2D (N, K) with
    both dims divisible by 16.
    """
    assert x.ndim == 2, "square-block quantization is defined for 2D weights"
    xf = x.astype(jnp.float32)
    n, k = xf.shape
    absmax = jnp.max(jnp.abs(xf))
    gscale = absmax / (6.0 * F.FP8_RTN_MARGIN * F.FP8_MAX)
    gscale = jnp.where(gscale == 0, 1.0, gscale)
    tiles = xf.reshape(n // F.GROUP, F.GROUP, k // F.GROUP, F.GROUP)
    tmax = jnp.max(jnp.abs(tiles), axis=(1, 3))  # (n//16, k//16)
    tscales = F.fp8_rtn(tmax / (gscale * 6.0 * F.FP8_RTN_MARGIN))
    denom = jnp.repeat(jnp.repeat(tscales, F.GROUP, 0), F.GROUP, 1) * gscale
    q = F.fp4_rtn(_safe_div(xf, denom))
    # expose per-row group scales (rows within a tile share the tile scale)
    scales = jnp.repeat(tscales, F.GROUP, axis=0)  # (n, k//16)
    return QTensor(q, scales, gscale)


def mse(x: jax.Array, qt: QTensor) -> jax.Array:
    d = dequant(qt) - x.astype(jnp.float32)
    return jnp.mean(d * d)
