"""Seeded blocked Randomized Hadamard Transform (RHT).

The paper (App. A) uses rotation blocks of d=128 so the rotation can be
expressed as a plain GEMM (mma.m16n8k16 on Blackwell; the 128x128 MXU tile on
TPU — the same reformulation, which is why this maps 1:1 onto TPU hardware).
One random sign diagonal is drawn per (tensor, micro-batch) and shared across
all rotation blocks of the tensor, exactly matching the paper's
"identical rotations for every rotation group within a tensor per micro-batch".

RHT(x) = reshape(x, (..., d/b, b)) @ (diag(sign) @ H_b / sqrt(b))

Block size: 128 when the inner dim allows, otherwise the largest power-of-two
multiple of 16 dividing d (all model inner dims here are multiples of 16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F


@functools.lru_cache(maxsize=None)
def hadamard(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix of power-of-two size n, normalized 1/sqrt(n)."""
    assert n & (n - 1) == 0 and n > 0, f"Hadamard size must be a power of 2, got {n}"
    h = np.ones((1, 1), dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(n)).astype(np.float32)


def block_size(d: int) -> int:
    """Largest power-of-two block in {16,32,64,128} dividing d (prefer 128)."""
    for b in (F.RHT_BLOCK, 64, 32, 16):
        if d % b == 0:
            return b
    raise ValueError(f"inner dim {d} is not a multiple of 16")


def sign_vector(key: jax.Array, b: int) -> jax.Array:
    """Random +-1 diagonal of length b."""
    return jax.random.rademacher(key, (b,), dtype=jnp.float32)


def rht(x: jax.Array, key: jax.Array, b: int | None = None) -> jax.Array:
    """Apply the blocked RHT along the last axis. Orthogonal; self-inverse up
    to the sign diagonal (inverse = rht_inv)."""
    d = x.shape[-1]
    b = b or block_size(d)
    s = sign_vector(key, b)
    hm = jnp.asarray(hadamard(b))
    xf = x.astype(jnp.float32).reshape(*x.shape[:-1], d // b, b)
    out = (xf * s) @ hm
    return out.reshape(x.shape)


def rht_inv(x: jax.Array, key: jax.Array, b: int | None = None) -> jax.Array:
    """Inverse blocked RHT (H^T then undo the sign diagonal)."""
    d = x.shape[-1]
    b = b or block_size(d)
    s = sign_vector(key, b)
    hm = jnp.asarray(hadamard(b))
    xf = x.astype(jnp.float32).reshape(*x.shape[:-1], d // b, b)
    out = (xf @ hm.T) * s
    return out.reshape(x.shape)
