"""MXFP4: the OCP micro-scaling alternative to NVFP4 (E2M1 values, one
power-of-two E8M0 scale per 32 elements, no per-tensor FP32 scale).

The paper cites MXFP4 as the weaker format (NVFP4 "was shown to yield
superior accuracy", Sec. 3.1, citing NVIDIA et al. 2025 / Egiazarian et al.
2025); we implement it so the claim is checkable inside this framework:
benchmarks/table1_mse.py reports both formats side by side, and the
`fwd_mxfp4` scheme lets any experiment swap formats.

MXFP4 quantization (per 32-group):
    scale_g = 2^round-down(log2(absmax_g / 6))   (E8M0: power of two)
    q_i     = RTN_FP4(x_i / scale_g)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import formats as F
from repro.core import quant as Q

MX_GROUP = 32


def quant_mxfp4(x: jax.Array) -> Q.QTensor:
    """RTN MXFP4 along the last axis (multiple of 32). Returned in the same
    QTensor container (scales are powers of two; gscale fixed at 1)."""
    xf = x.astype(jnp.float32)
    d = xf.shape[-1]
    assert d % MX_GROUP == 0, f"inner dim {d} not a multiple of 32"
    g = xf.reshape(*xf.shape[:-1], d // MX_GROUP, MX_GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1)
    # E8M0: floor power-of-two of absmax/6 (OCP MX spec rounding)
    e = jnp.floor(jnp.log2(jnp.where(gmax > 0, gmax, 1.0) / 6.0))
    scales = jnp.where(gmax > 0, jnp.exp2(e), 1.0)
    denom = jnp.repeat(scales, MX_GROUP, axis=-1).reshape(xf.shape)
    q = F.fp4_rtn(xf / denom)
    # repack into 16-wide scale slots for QTensor compatibility (each MX
    # scale covers two 16-slots)
    scales16 = jnp.repeat(scales, 2, axis=-1)
    return Q.QTensor(q, scales16, jnp.float32(1.0))


def quant_mxfp4_sr(x: jax.Array, key: jax.Array) -> Q.QTensor:
    """Stochastic-rounding MXFP4 (the Tseng et al. 2025 backward primitive).
    Power-of-two scales never clip after the ceil adjustment below."""
    xf = x.astype(jnp.float32)
    d = xf.shape[-1]
    assert d % MX_GROUP == 0
    g = xf.reshape(*xf.shape[:-1], d // MX_GROUP, MX_GROUP)
    gmax = jnp.max(jnp.abs(g), axis=-1)
    e = jnp.ceil(jnp.log2(jnp.where(gmax > 0, gmax, 1.0) / 6.0))  # no clip
    scales = jnp.where(gmax > 0, jnp.exp2(e), 1.0)
    denom = jnp.repeat(scales, MX_GROUP, axis=-1).reshape(xf.shape)
    q = F.fp4_sr(xf / denom, key)
    scales16 = jnp.repeat(scales, 2, axis=-1)
    return Q.QTensor(q, scales16, jnp.float32(1.0))
