"""Mesh-elastic, async-capable checkpointing.

Format: one .npy file per pytree leaf (logical, unsharded arrays) + a JSON
manifest (step, tree structure, data-pipeline cursor, rng). Because leaves
are saved as logical arrays, a checkpoint written on one mesh restores onto
ANY mesh shape — the elasticity requirement for rescaling a 1000-node job.

Fault-tolerance contract used by the trainer:
  - atomic commit (write to tmp dir, rename) — a crash mid-save never
    corrupts the latest checkpoint;
  - `save(..., blocking=False)` hands the host copy to a background thread
    (compute continues; matches async-checkpoint practice at scale);
  - emergency_save() is called from exception handlers / signal hooks.

In a true multi-host deployment each process saves only its addressable
shards; on this single-process container that degenerates to process 0
saving everything, but the API keeps the shard loop explicit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None
        self._lock = threading.Lock()

    # ---- save -------------------------------------------------------------

    def save(self, step: int, state: dict, extra: dict | None = None,
             blocking: bool = True):
        """state: pytree of arrays. extra: JSON-serializable metadata."""
        self.wait()  # one in-flight save at a time
        # device -> host copy happens NOW (consistent snapshot) ...
        leaves, treedef = _flatten(state)
        # numpy can't serialize ml_dtypes (bf16/f8): upcast to f32 on disk;
        # restore() casts back to the target leaf dtype (exactly invertible)
        host = [np.asarray(x, np.float32)
                if x.dtype in (jnp.bfloat16, jnp.float8_e4m3fn, jnp.float8_e5m2)
                else np.asarray(x) for x in leaves]
        meta = {"step": int(step), "extra": extra or {},
                "n_leaves": len(host)}

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            for i, arr in enumerate(host):
                np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            # ... while the actual disk write overlaps with compute.
            with self._lock:
                self._pending = self._pool.submit(_write)

    def emergency_save(self, step: int, state: dict, extra=None):
        """Called from failure paths; always blocking, never raises."""
        try:
            self.save(step, state, {**(extra or {}), "emergency": True},
                      blocking=True)
            return True
        except Exception:
            return False

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.all_steps()
        return s[-1] if s else None

    def restore(self, state_like, step: int | None = None):
        """Restore into the structure (and shardings) of `state_like`.

        Works across mesh shapes: leaves are logical arrays; jax.device_put
        against the target sharding re-shards on load.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        leaves, treedef = _flatten(state_like)
        assert len(leaves) == meta["n_leaves"], \
            f"structure mismatch: {len(leaves)} vs {meta['n_leaves']}"
        out = []
        for i, like in enumerate(leaves):
            arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            assert arr.shape == like.shape, (i, arr.shape, like.shape)
            target = like.sharding if hasattr(like, "sharding") else None
            out.append(jax.device_put(jnp.asarray(arr, like.dtype), target))
        return jax.tree_util.tree_unflatten(treedef, out), meta
