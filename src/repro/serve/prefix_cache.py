"""Radix-tree prefix cache over the paged KV pool.

Real serving fleets see enormous shared-prompt overlap (system prompts,
few-shot preambles, multi-turn histories). The block tables of
serve/kv_pool.py already decouple logical from physical blocks, so sharing
is purely an allocator problem: this module keeps a token-level radix tree
whose nodes OWN refcounted physical blocks, and on admission the engine

  1. matches the longest cached prefix of the new prompt,
  2. aliases the fully-matched blocks READ-ONLY into the new slot's table
     (`KVPool.adopt_prefix` — their prefill is skipped entirely),
  3. copy-on-writes the block holding the first divergent token or the
     partial tail (`KVPool.cow_block` — the matched part of that block is
     reused bit-for-bit too, so the WHOLE matched prefix costs zero
     prefill forward passes).

On retirement the completed stream's full blocks are inserted; when the
pool runs out of blocks, unpinned nodes are evicted leaf-first in LRU
order (a node whose block any live slot still aliases is pinned by its
`refs` count, and a node with referenced descendants is transitively
pinned because adoption refs the whole path).

Tree shape: children are keyed by the `block_size`-token tuple a child's
block covers, so every node owns exactly ONE full physical block and the
tree needs no edge splitting. Matching is still TOKEN-level: a prompt that
diverges inside a block gets the in-block common prefix via COW. Exactness
(docs/CONVENTIONS.md §3-5): the decode forward is row-local and
deterministic, so under `bf16` a cached block's K/V equals what the new
request's own prefill would have written, bit for bit; quantizing schemes
share an activation absmax across the batch, so quartet2 hot runs are
deterministic but not bit-comparable to cold runs (the same caveat as
spec-decode chunks and the sharded engine).

Exclusions (`supported`): dense pools have no block tables; sliding-window
pools (`reclaim_window`) free out-of-window blocks mid-sequence, so a
cached prefix is not fully resident past the window and must not be
shared; recurrent-state archs (wkv / lru) integrate the whole prefix into
O(1) slot state that blocks cannot reconstruct. With the slot-affine
sharded pool (PR 4), a prefix is only reusable by slots homed on its
shard: every node records the shard its block lives on, and insertion
never extends a path across shards.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.serve.kv_pool import KVPool


class _Node:
    """One cached full block: `tokens` (block_size ids) -> physical block."""

    __slots__ = ("parent", "children", "tokens", "block", "shard", "refs",
                 "last_used")

    def __init__(self, parent, tokens: tuple[int, ...], block: int,
                 shard: int, clock: int):
        self.parent = parent
        self.children: dict[tuple[int, ...], _Node] = {}
        self.tokens = tokens
        self.block = block
        self.shard = shard
        self.refs = 0          # live slots currently aliasing this block
        self.last_used = clock


@dataclass
class Match:
    """Longest cached prefix of a prompt.

    `nodes` — path of fully-matched nodes (len(nodes) * block_size tokens);
    `partial_node` / `partial` — a child whose block matches `partial` more
    tokens (0 < partial < block_size) before diverging; `tokens` — total
    matched token count. The engine caps `tokens` at len(prompt) - 1 (the
    last prompt token must be computed to produce first-token logits) and
    re-derives the alias/COW split from the capped value via `plan`.
    """
    nodes: list[_Node] = field(default_factory=list)
    partial_node: _Node | None = None
    partial: int = 0

    @property
    def tokens(self) -> int:
        return sum(len(n.tokens) for n in self.nodes) + self.partial

    @property
    def shard(self) -> int | None:
        if self.nodes:
            return self.nodes[0].shard
        if self.partial_node is not None:
            return self.partial_node.shard
        return None

    def plan(self, cap: int, block_size: int):
        """(m, adopt_nodes, tail_node) for a match capped at `cap` tokens:
        adopt_nodes' blocks alias read-only (full blocks below m), and
        tail_node (if any) supplies the COW source for m's partial block."""
        m = min(self.tokens, cap)
        full = m // block_size
        adopt = self.nodes[:full]
        tail = None
        if m % block_size:
            tail = (self.nodes[full] if full < len(self.nodes)
                    else self.partial_node)
        return m, adopt, tail


class PrefixCache:
    """Host-side radix cache bound to one KVPool (the engine's main pool).

    Pool-level laws it maintains (tests/test_kv_pool.py):
      - a cached node holds exactly ONE pool reference on its block
        (taken at insertion, dropped at eviction);
      - a node is evictable iff no slot aliases it (`refs == 0`) — pinned
        nodes (and, transitively, their ancestors) never free blocks a
        live slot still reads;
      - eviction is leaf-first LRU and feeds the pool's free list through
        `KVPool._decref`, so conservation (free + referenced == n_blocks)
        holds at every step.
    """

    def __init__(self, pool: KVPool):
        if not self.supported(pool):
            raise ValueError(
                "PrefixCache requires a paged pool without a sliding-window "
                "reclaim horizon and without recurrent state kinds "
                "(dense layouts have no block table; windowed prefixes are "
                "not fully resident; wkv/lru state is not block-addressed)")
        self.pool = pool
        self.block_size = pool.block_size
        self.root = _Node(None, (), -1, -1, 0)
        self._clock = 0
        # bumped whenever the TREE changes (insert/evict) — matching is
        # topology-only, so callers may reuse a Match until the epoch moves
        # (the engine memoizes per queued request instead of re-walking the
        # radix tree every scheduler tick)
        self.epoch = 0
        self.stats = {"lookups": 0, "hits": 0, "hit_tokens": 0,
                      "inserted_blocks": 0, "evicted_blocks": 0}
        # observability hook (set by the engine with EngineConfig(obs=...));
        # mirrors the stats events into registry counters
        self.obs = None
        pool.evict_hook = self.evict

    @staticmethod
    def supported(pool: KVPool) -> bool:
        # Quantized (PackedKV) pools are supported with no special casing:
        # sharing is by PHYSICAL BLOCK, and a shared quantized block is
        # shared packed bytes — immutable once written (per-token
        # deterministic RTN), so aliasing/COW semantics are unchanged and
        # hot-vs-cold streams stay identical per storage mode
        # (docs/CONVENTIONS.md §7).
        return pool.paged and pool.window is None and not pool.has_state_kinds

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ---- lookup ----------------------------------------------------------

    def record(self, match: Match | None) -> None:
        """Book one lookup (and its hit) in the stats. Called by the engine
        ONCE per successful admission — not from `match`, which may run
        several times for the same queued request (placement retries each
        tick, scheduler hint scans) and would inflate the hit rate. Pass
        None for an admission that did not USE its match (e.g. the cached
        prefix homed on a shard with no usable slot): books a miss."""
        self.stats["lookups"] += 1
        hit = match is not None and match.tokens
        if hit:
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += match.tokens
        if self.obs is not None:
            self.obs.on_cache_record(bool(hit), match.tokens if hit else 0)

    def match(self, prompt: list[int]) -> Match:
        """Longest cached prefix of `prompt` (token-level; may end inside a
        block). Does NOT pin anything (call `acquire` on the planned nodes
        before allocating against the pool) and does NOT book stats (the
        engine calls `record` once per admission)."""
        bs = self.block_size
        node, nodes = self.root, []
        d = 0
        while (d + 1) * bs <= len(prompt):
            child = node.children.get(tuple(prompt[d * bs:(d + 1) * bs]))
            if child is None:
                break
            nodes.append(child)
            node = child
            d += 1
        # partial tail: the child sharing the longest in-block prefix with
        # the remaining tokens (children are few; a linear scan is fine)
        rest = prompt[d * bs:]
        best, best_len = None, 0
        for child in node.children.values():
            n = 0
            for a, b in zip(rest, child.tokens):
                if a != b:
                    break
                n += 1
            if n > best_len:
                best, best_len = child, n
        return Match(nodes=nodes, partial_node=best, partial=best_len)

    # ---- pinning ---------------------------------------------------------

    def acquire(self, nodes: list[_Node]) -> None:
        """Pin `nodes` (a slot now aliases / is copying their blocks)."""
        clock = self._tick()
        for n in nodes:
            n.refs += 1
            n.last_used = clock

    def release(self, nodes: list[_Node]) -> None:
        clock = self._tick()
        for n in nodes:
            assert n.refs > 0, "prefix-cache release without acquire"
            n.refs -= 1
            n.last_used = clock

    # ---- insertion (request retirement) ----------------------------------

    def insert(self, tokens: list[int], slot: int) -> int:
        """Cache the FULL blocks of a retiring slot's token stream.

        Walks/extends the tree block by block: an existing node dedups (the
        slot's physical block — aliased or independently prefilled — is
        simply dropped by the slot's subsequent `release`); a missing node
        adopts the slot's block with one cache reference, which survives
        the release. Paths never mix shards: extension stops at the first
        shard mismatch (that prefix stays cached for its own shard only).
        Returns the number of newly cached blocks. Call BEFORE
        `pool.release(slot)`."""
        pool = self.pool
        shard = pool.shard_of_slot(slot)
        clock = self._tick()
        node, added = self.root, 0
        bs = self.block_size
        for d in range(len(tokens) // bs):
            key = tuple(tokens[d * bs:(d + 1) * bs])
            child = node.children.get(key)
            if child is not None:
                if child.shard != shard:
                    break
                child.last_used = clock
                node = child
                continue
            blk = int(pool._table[slot, d])
            if blk == pool.sentinel:
                break
            pool.incref(blk)
            child = _Node(node, key, blk, shard, clock)
            node.children[key] = child
            node = child
            added += 1
        self.stats["inserted_blocks"] += added
        if self.obs is not None:
            self.obs.on_cache_insert(added)
        if added:
            self.epoch += 1
        return added

    # ---- eviction --------------------------------------------------------

    def _evictable_leaves(self, shard: int | None):
        out = []

        def walk(n):
            for c in n.children.values():
                if c.children:
                    walk(c)
                elif c.refs == 0 and (shard is None or c.shard == shard):
                    out.append(c)

        walk(self.root)
        return out

    def evict(self, shard: int | None, need: int) -> int:
        """Free >= `need` blocks homed on `shard` by LRU leaf eviction
        (best effort — returns the number actually freed). Also the pool's
        `evict_hook`, so an `ensure`/COW that finds the free list empty
        reclaims cache-held blocks transparently."""
        freed = 0
        while freed < need:
            leaves = self._evictable_leaves(shard)
            if not leaves:
                break
            leaves.sort(key=lambda n: n.last_used)
            for n in leaves:
                del n.parent.children[n.tokens]
                self.pool._decref(n.block)
                freed += 1
                if freed >= need:
                    break
        self.stats["evicted_blocks"] += freed
        if self.obs is not None:
            self.obs.on_cache_evict(freed)
        if freed:
            self.epoch += 1
        return freed

    # ---- introspection ---------------------------------------------------

    def cached_blocks(self) -> int:
        n = 0

        def walk(node):
            nonlocal n
            for c in node.children.values():
                n += 1
                walk(c)

        walk(self.root)
        return n
