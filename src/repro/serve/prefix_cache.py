"""Radix-tree prefix cache over the paged KV pool, with a host spill tier.

Real serving fleets see enormous shared-prompt overlap (system prompts,
few-shot preambles, multi-turn histories). The block tables of
serve/kv_pool.py already decouple logical from physical blocks, so sharing
is purely an allocator problem: this module keeps a token-level radix tree
whose nodes OWN refcounted physical blocks, and on admission the engine

  1. matches the longest cached prefix of the new prompt,
  2. aliases the fully-matched blocks READ-ONLY into the new slot's table
     (`KVPool.adopt_prefix` — their prefill is skipped entirely),
  3. copy-on-writes the block holding the first divergent token or the
     partial tail (`KVPool.cow_block` — the matched part of that block is
     reused bit-for-bit too, so the WHOLE matched prefix costs zero
     prefill forward passes).

On retirement the completed stream's full blocks are inserted; when the
pool runs out of blocks, unpinned nodes are evicted leaf-first in LRU
order (a node whose block any live slot still aliases is pinned by its
`refs` count, and a node with referenced descendants is transitively
pinned because adoption refs the whole path).

HIERARCHICAL MODE (`spill=True`): eviction under pool pressure becomes a
device->host copy instead of a drop. The evicted node keeps an IMMUTABLE
host snapshot of its block bytes (`KVPool.read_block_host` — raw PackedKV
packed bytes for quantized pools, bf16 otherwise), and a later match on
the spilled path swaps the blocks back in (`materialize`) by allocating a
fresh block and DISPATCHING the host->device write without blocking — the
copy overlaps subsequent decode ticks, and any step that reads the pool
is ordered after it by the cache pytree data dependence. A spill-hot
request therefore still skips every prefill forward over the matched
prefix, and under bf16 its stream is bitwise-equal to cold
(host->device->host is the identity). Nodes also become MULTI-SHARD: a
node may hold one device copy per shard (`blocks` maps shard -> block),
so hot prefixes past a hit-count threshold are proactively replicated
into peer shards' pools through the host tier (`replicate_hot`), and a
cross-shard match admits hot instead of cold. Host copies are immutable
snapshots and only the engine thread initiates swap-in
(docs/CONVENTIONS.md §9).

Tree shape: children are keyed by the `block_size`-token tuple a child's
block covers, so every node owns exactly ONE full physical block per
resident shard and the tree needs no edge splitting. Matching is still
TOKEN-level: a prompt that diverges inside a block gets the in-block
common prefix via COW. Exactness (docs/CONVENTIONS.md §3-5): the decode
forward is row-local and deterministic, so under `bf16` a cached block's
K/V equals what the new request's own prefill would have written, bit for
bit; quantizing schemes share an activation absmax across the batch, so
quartet2 hot runs are deterministic but not bit-comparable to cold runs
(the same caveat as spec-decode chunks and the sharded engine).

Exclusions (`supported`): dense pools have no block tables; sliding-window
pools (`reclaim_window`) free out-of-window blocks mid-sequence, so a
cached prefix is not fully resident past the window and must not be
shared; recurrent-state archs (wkv / lru) integrate the whole prefix into
O(1) slot state that blocks cannot reconstruct. With the slot-affine
sharded pool (PR 4) and `spill=False`, a prefix is only reusable by slots
homed on its shard: every node records the shard its block lives on, and
insertion never extends a path across shards (spill mode lifts both
limits via the host tier).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.serve.kv_pool import KVPool, OutOfBlocks


class _Node:
    """One cached full block: `tokens` (block_size ids) -> physical copies.

    `blocks` maps shard -> device block id (one refcounted copy per shard;
    single-copy in non-spill mode). `host` holds the immutable host-tier
    snapshot (None while never spilled/replicated); `hits` counts admission
    matches through this node (replication trigger)."""

    __slots__ = ("parent", "children", "tokens", "blocks", "host",
                 "host_bytes", "hits", "refs", "last_used")

    def __init__(self, parent, tokens: tuple[int, ...], block: int,
                 shard: int, clock: int):
        self.parent = parent
        self.children: dict[tuple[int, ...], _Node] = {}
        self.tokens = tokens
        self.blocks: dict[int, int] = {} if block < 0 else {shard: block}
        self.host = None       # immutable host payload (spill tier)
        self.host_bytes = 0
        self.hits = 0
        self.refs = 0          # live slots currently aliasing this path
        self.last_used = clock

    @property
    def shard(self) -> int:
        """Home shard of the (single) device copy — non-spill introspection."""
        return next(iter(self.blocks), -1)

    @property
    def block(self) -> int:
        return next(iter(self.blocks.values()), -1)

    def available(self) -> bool:
        """Matchable: at least one device copy or a host snapshot."""
        return bool(self.blocks) or self.host is not None


@dataclass
class Match:
    """Longest cached prefix of a prompt.

    `nodes` — path of fully-matched nodes (len(nodes) * block_size tokens);
    `partial_node` / `partial` — a child whose block matches `partial` more
    tokens (0 < partial < block_size) before diverging; `tokens` — total
    matched token count. The engine caps `tokens` at len(prompt) - 1 (the
    last prompt token must be computed to produce first-token logits) and
    re-derives the alias/COW split from the capped value via `plan`.
    """
    nodes: list[_Node] = field(default_factory=list)
    partial_node: _Node | None = None
    partial: int = 0

    @property
    def tokens(self) -> int:
        return sum(len(n.tokens) for n in self.nodes) + self.partial

    @property
    def shard(self) -> int | None:
        if self.nodes:
            return self.nodes[0].shard
        if self.partial_node is not None:
            return self.partial_node.shard
        return None

    def plan(self, cap: int, block_size: int):
        """(m, adopt_nodes, tail_node) for a match capped at `cap` tokens:
        adopt_nodes' blocks alias read-only (full blocks below m), and
        tail_node (if any) supplies the COW source for m's partial block."""
        m = min(self.tokens, cap)
        full = m // block_size
        adopt = self.nodes[:full]
        tail = None
        if m % block_size:
            tail = (self.nodes[full] if full < len(self.nodes)
                    else self.partial_node)
        return m, adopt, tail


class PrefixCache:
    """Host-side radix cache bound to one KVPool (the engine's main pool).

    Pool-level laws it maintains (tests/test_kv_pool.py,
    tests/test_prefix_tiers.py):
      - a cached node holds exactly ONE pool reference per device copy
        (taken at insertion / swap-in / replication, dropped at eviction);
      - a node is evictable iff no slot aliases its path (`refs == 0`) —
        pinned nodes (and, transitively, their ancestors) never free
        blocks a live slot still reads;
      - eviction is leaf-first LRU and feeds the pool's free list through
        `KVPool._decref`, so conservation (free + referenced == n_blocks)
        holds at every step; with `spill=True` the bytes move to the host
        tier first and `host_bytes` equals the sum of every node's held
        snapshot (the extended conservation invariant).
    """

    def __init__(self, pool: KVPool, *, spill: bool = False,
                 host_budget_bytes: int | None = None,
                 replicate_hits: int | None = None, clock=None):
        if not self.supported(pool):
            raise ValueError(
                "PrefixCache requires a paged pool without a sliding-window "
                "reclaim horizon and without recurrent state kinds "
                "(dense layouts have no block table; windowed prefixes are "
                "not fully resident; wkv/lru state is not block-addressed)")
        self.pool = pool
        self.block_size = pool.block_size
        self.spill = spill
        self.host_budget_bytes = host_budget_bytes
        self.replicate_hits = replicate_hits
        self.wall = clock if clock is not None else time.perf_counter
        self.host_bytes = 0
        # swap-in writes dispatched this tick, not yet at a tick boundary:
        # their blocks are cache-held but counted separately by the
        # extended conservation invariant (engine clears via complete_swaps)
        self._inflight: list[int] = []
        self.root = _Node(None, (), -1, -1, 0)
        self._clock = 0
        # bumped whenever MATCHABILITY changes (node added/removed, host
        # snapshot dropped) — matching is topology-only, so callers may
        # reuse a Match until the epoch moves (the engine memoizes per
        # queued request instead of re-walking the radix tree every
        # scheduler tick). A spill that keeps the node available does NOT
        # bump: the memoized plan stays valid and materializes on use.
        self.epoch = 0
        self.stats = {"lookups": 0, "hits": 0, "hit_tokens": 0,
                      "inserted_blocks": 0, "evicted_blocks": 0,
                      "spilled_blocks": 0, "swapped_in_blocks": 0,
                      "replicated_blocks": 0, "swapin_s": 0.0}
        # observability hook (set by the engine with EngineConfig(obs=...));
        # mirrors the stats events into registry counters
        self.obs = None
        pool.evict_hook = self.evict

    @staticmethod
    def supported(pool: KVPool) -> bool:
        # Quantized (PackedKV) pools are supported with no special casing:
        # sharing is by PHYSICAL BLOCK, and a shared quantized block is
        # shared packed bytes — immutable once written (per-token
        # deterministic RTN), so aliasing/COW semantics are unchanged and
        # hot-vs-cold streams stay identical per storage mode
        # (docs/CONVENTIONS.md §7). The host tier spills those same packed
        # bytes verbatim, so spill-hot == device-hot byte-for-byte too.
        return pool.paged and pool.window is None and not pool.has_state_kinds

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ---- lookup ----------------------------------------------------------

    def record(self, match: Match | None) -> None:
        """Book one lookup (and its hit) in the stats. Called by the engine
        ONCE per successful admission — not from `match`, which may run
        several times for the same queued request (placement retries each
        tick, scheduler hint scans) and would inflate the hit rate. Pass
        None for an admission that did not USE its match (e.g. the cached
        prefix homed on a shard with no usable slot): books a miss. Hits
        bump the path's `hits` counters — the replication trigger."""
        self.stats["lookups"] += 1
        hit = match is not None and match.tokens
        if hit:
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += match.tokens
            for n in match.nodes:
                n.hits += 1
            if match.partial_node is not None:
                match.partial_node.hits += 1
        if self.obs is not None:
            self.obs.on_cache_record(bool(hit), match.tokens if hit else 0)

    def hint_tokens(self, match: Match) -> int:
        """Scheduler admission hint for a match (Request.cached_hint,
        serve/scheduler.py cache-aware ordering): device-resident matched
        tokens count in full, host-only (spilled) tokens half — a swap-in
        is far cheaper than prefill but still costs an allocation and a
        host->device copy, so among equals the fully resident prefix should
        admit first. Non-spill caches hold only resident nodes: the hint is
        exactly `match.tokens`, the original behavior."""
        if not self.spill:
            return match.tokens
        t = 0
        for n in match.nodes:
            t += len(n.tokens) if n.blocks else len(n.tokens) // 2
        if match.partial_node is not None:
            t += (match.partial if match.partial_node.blocks
                  else match.partial // 2)
        return t

    def match(self, prompt: list[int]) -> Match:
        """Longest cached prefix of `prompt` (token-level; may end inside a
        block). Does NOT pin anything (call `acquire` on the planned nodes
        before allocating against the pool) and does NOT book stats (the
        engine calls `record` once per admission). Spilled (host-only)
        nodes match like resident ones — adoption materializes them."""
        bs = self.block_size
        node, nodes = self.root, []
        d = 0
        while (d + 1) * bs <= len(prompt):
            child = node.children.get(tuple(prompt[d * bs:(d + 1) * bs]))
            if child is None or not child.available():
                break
            nodes.append(child)
            node = child
            d += 1
        # partial tail: the child sharing the longest in-block prefix with
        # the remaining tokens (children are few; a linear scan is fine)
        rest = prompt[d * bs:]
        best, best_len = None, 0
        for child in node.children.values():
            if not child.available():
                continue
            n = 0
            for a, b in zip(rest, child.tokens):
                if a != b:
                    break
                n += 1
            if n > best_len:
                best, best_len = child, n
        return Match(nodes=nodes, partial_node=best, partial=best_len)

    # ---- pinning ---------------------------------------------------------

    def acquire(self, nodes: list[_Node]) -> None:
        """Pin `nodes` (a slot now aliases / is copying their blocks)."""
        clock = self._tick()
        for n in nodes:
            n.refs += 1
            n.last_used = clock

    def release(self, nodes: list[_Node]) -> None:
        clock = self._tick()
        for n in nodes:
            assert n.refs > 0, "prefix-cache release without acquire"
            n.refs -= 1
            n.last_used = clock

    # ---- host tier -------------------------------------------------------

    def _snapshot(self, node: _Node):
        """Node's immutable host payload, reading a resident device copy on
        first use. Idempotent: bytes never change once a block's positions
        are written (docs/CONVENTIONS.md §7/§9), so one snapshot serves
        every later swap-in and replication of the node."""
        if node.host is None:
            src = next(iter(node.blocks.values()))
            node.host, node.host_bytes = self.pool.read_block_host(src)
            self.host_bytes += node.host_bytes
        return node.host

    def materialize(self, nodes: list[_Node], shard: int) -> int:
        """Ensure every node has a device copy on `shard`, swapping spilled
        blocks back in from the host tier (or sideloading from a peer
        shard's copy via a fresh snapshot — the on-demand half of
        cross-shard replication). Writes are DISPATCHED, not awaited: the
        host->device copies overlap decode ticks, and the next step's pool
        reads are ordered after them by the cache data dependence. Pin the
        nodes (`acquire`) BEFORE calling — the allocations may evict, and
        unpinned path nodes could be reclaimed from under the swap-in.
        Engine-thread-only (docs/CONVENTIONS.md §9). Returns blocks
        swapped in; raises OutOfBlocks when the shard cannot hold the path.
        """
        missing = [n for n in nodes if shard not in n.blocks]
        if not missing:
            return 0
        t0 = self.wall()
        pool = self.pool
        for n in missing:
            payload = self._snapshot(n)
            blk = pool.alloc_cache_block(shard)
            pool.write_block_host(blk, payload)
            n.blocks[shard] = blk
            self._inflight.append(blk)
        dt = self.wall() - t0
        self.stats["swapped_in_blocks"] += len(missing)
        self.stats["swapin_s"] += dt
        if self.obs is not None:
            self.obs.on_cache_swap_in(len(missing), dt)
        self._trim_host()
        return len(missing)

    def complete_swaps(self) -> None:
        """Tick-boundary accounting: in-flight swap-ins become plain cached
        blocks (the device write is ordered before any dependent step read,
        so no host sync happens here). Called by the engine at the end of
        each step."""
        self._inflight.clear()

    def replicate_hot(self, budget: int = 1) -> int:
        """Proactively copy up to `budget` blocks of HOT nodes (hits past
        `replicate_hits`) into shards missing them, through the host tier.
        Opportunistic: only genuinely free blocks are used (replication
        never evicts), so a loaded shard is left alone. Bounded per tick by
        `budget` — the engine amortizes replication across ticks."""
        if (not self.spill or self.replicate_hits is None
                or self.pool.n_shards == 1 or budget <= 0):
            return 0
        pool, done = self.pool, 0
        targets = [s for s in range(pool.n_shards) if pool._frees[s]]
        if not targets:
            return 0

        def walk(node):
            nonlocal done
            for c in node.children.values():
                if done >= budget:
                    return
                if c.hits >= self.replicate_hits and c.available():
                    for s in targets:
                        if done >= budget:
                            break
                        if s in c.blocks or not pool._frees[s]:
                            continue
                        payload = self._snapshot(c)
                        blk = pool.alloc_cache_block(s)
                        pool.write_block_host(blk, payload)
                        c.blocks[s] = blk
                        self._inflight.append(blk)
                        done += 1
                walk(c)

        walk(self.root)
        if done:
            self.stats["replicated_blocks"] += done
            if self.obs is not None:
                self.obs.on_cache_replicate(done)
            self._trim_host()
        return done

    def _trim_host(self) -> None:
        """Best-effort host-tier budget: drop LRU snapshots, preferring
        nodes that keep a device copy (the snapshot is re-readable); a
        host-ONLY childless node is removed outright. Host-only INNER nodes
        keep their snapshot — dropping it would orphan a cached subtree."""
        if self.host_budget_bytes is None:
            return
        while self.host_bytes > self.host_budget_bytes:
            resident, sole = [], []

            def walk(node):
                for c in node.children.values():
                    if c.host is not None and c.refs == 0:
                        if c.blocks:
                            resident.append(c)
                        elif not c.children:
                            sole.append(c)
                    walk(c)

            walk(self.root)
            pick = min(resident, key=lambda n: n.last_used) if resident \
                else min(sole, key=lambda n: n.last_used) if sole else None
            if pick is None:
                return
            self.host_bytes -= pick.host_bytes
            pick.host, pick.host_bytes = None, 0
            if not pick.blocks and not pick.children:
                del pick.parent.children[pick.tokens]
                self.epoch += 1

    # ---- insertion (request retirement) ----------------------------------

    def insert(self, tokens: list[int], slot: int) -> int:
        """Cache the FULL blocks of a retiring slot's token stream.

        Walks/extends the tree block by block: an existing node with a copy
        on the slot's shard dedups (the slot's physical block — aliased or
        independently prefilled — is simply dropped by the slot's
        subsequent `release`); a missing node adopts the slot's block with
        one cache reference, which survives the release. In spill mode an
        existing node MISSING this shard's copy adopts the slot's block as
        an additional per-shard replica (the retiring slot just proved the
        bytes exist on this shard); without spill, paths never mix shards —
        extension stops at the first shard mismatch. Returns the number of
        newly cached blocks. Call BEFORE `pool.release(slot)`."""
        pool = self.pool
        shard = pool.shard_of_slot(slot)
        clock = self._tick()
        node, added = self.root, 0
        bs = self.block_size
        for d in range(len(tokens) // bs):
            key = tuple(tokens[d * bs:(d + 1) * bs])
            child = node.children.get(key)
            if child is not None and child.available():
                child.last_used = clock
                if shard in child.blocks:
                    node = child
                    continue
                if not self.spill:
                    break
                blk = int(pool._table[slot, d])
                if blk == pool.sentinel:
                    break
                pool.incref(blk)
                child.blocks[shard] = blk
                node = child
                added += 1
                continue
            blk = int(pool._table[slot, d])
            if blk == pool.sentinel:
                break
            pool.incref(blk)
            if child is not None:  # dead husk (trimmed): revive in place
                child.blocks = {shard: blk}
                child.last_used = clock
            else:
                child = _Node(node, key, blk, shard, clock)
                node.children[key] = child
            node = child
            added += 1
        self.stats["inserted_blocks"] += added
        if self.obs is not None:
            self.obs.on_cache_insert(added)
        if added:
            self.epoch += 1
        return added

    # ---- eviction --------------------------------------------------------

    def _evictable_leaves(self, shard: int | None):
        """Nodes whose shard-`shard` copy may be dropped: unpinned, and no
        child holds a copy on that shard (leaf-first per shard — a parent
        copy outlives its resident descendants, so an adoptable path is
        always contiguous). `shard=None` considers every resident copy."""
        out = []

        def blocked(c, sh):
            # a HOST-ONLY descendant does not pin its ancestors: in spill
            # mode an evicted child stays in the tree (matchable via its
            # snapshot), and treating it as blocking would freeze eviction
            # at the leaf fringe forever
            return any((sh in g.blocks if sh is not None else bool(g.blocks))
                       or blocked(g, sh) for g in c.children.values())

        def walk(n):
            for c in n.children.values():
                if (c.refs == 0 and not blocked(c, shard)
                        and (shard is None or shard in c.blocks)):
                    if c.blocks:
                        out.append(c)
                else:
                    walk(c)

        walk(self.root)
        return out

    def evict(self, shard: int | None, need: int) -> int:
        """Free >= `need` blocks homed on `shard` by LRU leaf eviction
        (best effort — returns the number actually freed). Also the pool's
        `evict_hook`, so an `ensure`/COW that finds the free list empty
        reclaims cache-held blocks transparently. In spill mode the bytes
        are snapshotted to the host tier FIRST (device->host copy; packed
        bytes for quantized pools) and the node stays matchable — a later
        hit swaps back in instead of re-prefilling."""
        freed = 0
        while freed < need:
            leaves = self._evictable_leaves(shard)
            if not leaves:
                break
            leaves.sort(key=lambda n: n.last_used)
            for n in leaves:
                drop = ([shard] if shard is not None
                        else sorted(n.blocks))
                for sh in drop:
                    if self.spill:
                        self._snapshot(n)
                        self.stats["spilled_blocks"] += 1
                        if self.obs is not None:
                            self.obs.on_cache_spill(1, n.host_bytes)
                    blk = n.blocks.pop(sh)
                    self.pool._decref(blk)
                    freed += 1
                    if freed >= need:
                        break
                if not n.available():
                    del n.parent.children[n.tokens]
                    self.epoch += 1
                if freed >= need:
                    break
        self.stats["evicted_blocks"] += freed
        if self.obs is not None and freed:
            self.obs.on_cache_evict(freed)
        if self.spill and freed:
            # spilling grew the host tier: enforce the budget here too, so
            # `host_bytes <= host_budget_bytes` holds after EVERY operation
            # (pinned paths are exempt from trimming, so a mid-materialize
            # eviction cannot drop the snapshot being swapped in)
            self._trim_host()
        return freed

    # ---- introspection ---------------------------------------------------

    def cached_blocks(self) -> int:
        """Device blocks the cache holds, EXCLUDING in-flight swap-ins
        (their dispatched writes complete at the next tick boundary — the
        extended conservation invariant counts them separately)."""
        n = 0

        def walk(node):
            nonlocal n
            for c in node.children.values():
                n += len(c.blocks)
                walk(c)

        walk(self.root)
        return n - len(self._inflight)

    @property
    def inflight_swaps(self) -> int:
        return len(self._inflight)

    def host_nodes(self) -> int:
        """Nodes currently holding a host-tier snapshot."""
        n = 0

        def walk(node):
            nonlocal n
            for c in node.children.values():
                n += c.host is not None
                walk(c)

        walk(self.root)
        return n
