"""Quantize-once NVFP4 weight cache.

The paper's forward quantizers (RTN, 4/6) are deterministic, so a weight's
NVFP4 image is a pure function of the weight: serving can quantize + pack
every linear weight ONCE offline and reuse the packed tensors forever,
instead of re-running weight quantization inside every decode step. The
packed form (`core.linear.PackedQWeight`) stores 4-bit codes two-per-byte
plus e4m3 group scales — 4.5 bits/element at rest, the memory-bandwidth
lever NVFP4 serving exists for — and round-trips bit-exactly, so prequant
decode logits are IDENTICAL to per-step quantization (tests/test_serve.py
asserts this).

Selection is by leaf name: exactly the weights the decode path feeds through
`qlinear` get packed. Deliberately excluded:

  - `wkv_b` (MLA): absorbed-form decode consumes it as a raw matrix
    (models/mla.py) — packing it would change decode numerics.
  - `router` (MoE), RWKV token-shift/decay LoRA (`mix_w1`, `mix_w2`, `ww1`,
    `ww2`), RG-LRU gates (`wa`, `wx`) and convs: fp32 non-quantized matmuls.
  - embeddings, norms, biases: not GEMM weights.
  - `head`: packed only when cfg.quantize_lm_head (paper keeps it bf16).

Stacked leaves — (layers, N, K) scan stacks and (layers, E, f, d) expert
stacks — are packed per-matrix via vmap over the leading axes, matching the
per-layer / per-expert scale granularity of the per-step path.
"""

from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.core import linear as L
from repro.core import schemes as S

# leaf names that flow through qlinear on the decode path
QUANT_KEYS = frozenset({
    "wq", "wk", "wv", "wo",            # gqa / lattn projections, rwkv r/k/v
    "wi", "wg",                        # mlp / moe experts, rwkv gate
    "wr",                              # rwkv receptance
    "wq_a", "wq_b", "wkv_a",           # mla down/up projections (not wkv_b!)
    "w_in", "w_gate", "w_out",         # griffin recurrent block
    "cm_wr", "cm_wk", "cm_wv",         # rwkv channel-mix
})


def _leaf_key(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "name", last)))


def _pack_stacked(leaf: jax.Array, kind: str) -> L.PackedQWeight:
    """Pack a (..., N, K) stack as independent 2D matrices."""
    lead = leaf.shape[:-2]
    flat = leaf.reshape((-1, *leaf.shape[-2:]))
    packed = jax.vmap(lambda m: L.pack_weight(m, kind))(flat)
    return L.PackedQWeight(*(a.reshape(*lead, *a.shape[1:]) for a in packed))


def prequantize(params, cfg: ArchConfig, scheme: str, probe=None):
    """Return a params pytree with decode-path weights replaced by
    PackedQWeight stacks. No-op for non-weight-quantizing schemes.

    `probe` (obs/quant_probe.py QuantProbe, optional) samples the RAW
    weights' quantization health — per-site MSE, scale saturation, clip
    fraction — before packing, so the one-time weight quantization every
    serving run depends on is observable, not assumed."""
    sch = S.get(scheme)
    if sch.fwd_w == "none":
        return params
    if probe is not None:
        probe.probe_params(params, phase="prequant")
    kind = sch.fwd_w

    def maybe_pack(path, leaf):
        if isinstance(leaf, L.PackedQWeight):
            raise ValueError("params already prequantized")
        if leaf.ndim < 2 or _leaf_key(path) not in QUANT_KEYS:
            return leaf
        return _pack_stacked(leaf, kind)

    out = dict(params)
    out["stages"] = jax.tree_util.tree_map_with_path(
        maybe_pack, params["stages"])
    if cfg.quantize_lm_head and "head" in params:
        out["head"] = L.pack_weight(params["head"], kind)
    return out


def prequantize_specs(param_specs, cfg: ArchConfig, scheme: str):
    """Shape-struct image of `prequantize` (zero allocation): what the
    packed serving params LOOK like, for mesh lowering / memory analysis —
    launch/dryrun's sharded decode cells price the 4.5-bit weight residency
    the serving engine actually deploys with."""
    return jax.eval_shape(lambda p: prequantize(p, cfg, scheme), param_specs)
