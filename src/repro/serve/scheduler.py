"""Pluggable serving scheduler policies (admission order + prefill pick).

The engine's tick loop (serve/engine.py) is throughput-shaped: admit from
the queue, prefill ONE chunk, run one batched decode step. What used to be
hard-coded FIFO is now a policy object consulted at exactly two points —
neither of which changes any compiled step shape:

  admission_order(queue, now)  — the order in which queued requests are
      OFFERED a slot this tick, plus `head_of_line`: whether a request
      that cannot be placed blocks everything behind it (FIFO semantics)
      or is skipped (latency semantics; aging below prevents starvation).
  pick_prefill(candidates, now) — which PREFILL-state slot receives this
      tick's single prefill chunk: latency-critical admissions can preempt
      an older request's remaining prompt chunks.

`FifoPolicy` (the default) reproduces the pre-policy engine EXACTLY:
queue order with head-of-line blocking, lowest-index prefill slot. The
whole pre-existing serving test suite runs under it unchanged.

`LatencyPolicy` adds per-request `priority` (higher = more urgent) and
`deadline_s` (seconds after arrival), ordering by

  (effective priority desc, deadline slack asc, arrival order)

where effective priority = priority + waited_ticks // aging_ticks. The
aging term is TICK-based (deterministic — tests can assert the bound
exactly): any request's effective priority grows without bound while it
waits, so after at most (max_priority_gap + 1) * aging_ticks ticks it
outranks every fixed-priority competitor — the starvation-freedom bound
tests/test_scheduler.py asserts. Cache-aware ordering: among otherwise
equal requests, a larger cached prefix sorts first (it is cheaper to
admit — its prefill is mostly skipped), which both drains the queue
faster and reuses cached blocks before they age out. The hint is
tier-aware: `PrefixCache.hint_tokens` counts device-resident tokens at
full weight and host-spilled tokens at half (a spill-hot admission still
skips its prefill, but pays block re-allocation and the host→device
copy), so the ordering prefers truly-resident prefixes without treating
spilled ones as cold.

Determinism: policies are pure functions of (queue snapshot, tick
counters, request fields); `now` is only consulted for deadline slack,
and requests submitted before `run()` share one arrival-clock origin, so
orderings are reproducible run-to-run. Policies never read a wall clock
themselves: every `now` they see is `engine.clock()` (the injectable
monotonic clock from `EngineConfig.clock`), so deadline-slack and aging
behavior is drivable by a fake clock in tests — no real sleeps.
"""

from __future__ import annotations

from dataclasses import dataclass


class SchedulerPolicy:
    """Base policy = FIFO. Subclass and override to change ordering only;
    the engine owns placement (shard occupancy, cache affinity) and all
    pool interactions."""

    #: a request that cannot be placed blocks those behind it (strict FIFO)
    head_of_line: bool = True

    def admission_order(self, queue, now: float):
        """Queue snapshot -> iteration order for this tick's admissions."""
        return list(queue)

    def pick_prefill(self, candidates, now: float) -> int:
        """candidates: [(slot_index, slot), ...] in slot order, all in
        PREFILL state and non-empty. Returns the slot index to advance."""
        return candidates[0][0]

    def observe(self, obs, queue, now: float) -> None:
        """Per-tick scheduler telemetry (queue depth + aging), reported
        through the engine's Instrumentation at the tick boundary — the
        policy knows its own urgency model, so subclasses extend this
        (LatencyPolicy adds deadline slack). Host-side only; never called
        from inside a jitted body (docs/CONVENTIONS.md §6)."""
        obs.queue_depth.set(len(queue))
        obs.queue_age.set(max((r.queued_ticks for r in queue), default=0))


class FifoPolicy(SchedulerPolicy):
    """Today's behavior, exactly: submission order, head-of-line blocking,
    lowest-index prefill slot."""


@dataclass
class LatencyPolicy(SchedulerPolicy):
    """Latency-aware admission + prefill preemption with starvation-free
    aging. See module docstring for the ordering law."""

    #: queue ticks per +1 effective priority while waiting (aging)
    aging_ticks: int = 8

    head_of_line = False

    def _slack(self, req, now: float) -> float:
        if req.deadline_s is None:
            return float("inf")
        return (req.arrival_s + req.deadline_s) - now

    def effective_priority(self, req) -> int:
        return req.priority + req.queued_ticks // max(self.aging_ticks, 1)

    def admission_order(self, queue, now: float):
        return sorted(
            queue,
            key=lambda r: (-self.effective_priority(r), self._slack(r, now),
                           -getattr(r, "cached_hint", 0), r.req_id))

    def observe(self, obs, queue, now: float) -> None:
        super().observe(obs, queue, now)
        slacks = [self._slack(r, now) for r in queue
                  if r.deadline_s is not None]
        if slacks:  # finite only: +Inf would poison the JSON exposition
            obs.queue_slack.set(min(slacks))

    def pick_prefill(self, candidates, now: float) -> int:
        """Preemption point: the most urgent PREFILL slot gets the chunk
        (a freshly admitted latency-critical request overtakes the
        remaining prompt chunks of earlier, lower-priority admissions).
        Starvation-free here too: the engine ages the slots NOT picked
        (queued_ticks keeps growing mid-prefill), so a passed-over prompt
        eventually outranks any fixed-priority stream."""
        def key(item):
            i, slot = item
            r = slot.req
            return (-self.effective_priority(r), self._slack(r, now), i)
        return min(candidates, key=key)[0]
