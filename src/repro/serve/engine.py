"""ServeEngine: continuous-batching NVFP4 serving.

The engine owns a fixed set of decode SLOTS (the batch dimension of every
jitted step), a request queue with admission control, a paged KV pool, and a
quantize-once weight cache. The scheduler loop interleaves:

  1. ADMIT   — move queued requests into free slots in the order the
               scheduler POLICY dictates (serve/scheduler.py; the default
               FifoPolicy is exactly the original FIFO). Admission checks
               the pool can back prompt + max_new tokens; placement is
               shard-occupancy-aware, and with the prefix cache enabled
               (serve/prefix_cache.py) the longest cached prompt prefix is
               aliased read-only into the slot — its prefill is skipped.
  2. PREFILL — one chunk of ONE prefilling slot per iteration (bounded work
               per tick keeps decode latency flat while prompts stream in);
               the policy picks WHICH slot (latency preemption point).
               Chunks run through the same decode-mode forward as decoding
               (S=chunk tokens, per-sequence start position); other slots are
               masked inactive, so their caches are untouched bit-for-bit.
  3. DECODE  — one batched step over all slots in DECODE state; new requests
               join as finished ones retire, never restarting the batch.

Slot states: FREE -> PREFILL -> DECODE -> FREE. Exactly two compiled step
shapes exist per engine: (n_slots, prefill_chunk) and (n_slots, 1); a
trailing partial prompt chunk is fed token-by-token through the (n_slots, 1)
step so recurrent-state archs (rwkv / griffin) never consume pad tokens.

Everything the forward needs about raggedness travels as data (per-slot
position vector, active mask, block table), so one compilation serves every
admission pattern.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.obs.instrumentation import NULL, legacy_stats_dict
from repro.serve import decode as serve_decode
from repro.serve import spec_decode
from repro.serve.kv_pool import KVPool, OutOfBlocks
from repro.serve.prequant import prequantize
from repro.serve.sampling import (SamplingParams, sample_tokens,
                                  speculative_resample)

FREE, PREFILL, DECODE = "free", "prefill", "decode"


class QueueFull(RuntimeError):
    """Admission control: the request queue is at capacity.

    Structured rejection: carries machine-readable fields (`reason`,
    `queue_depth`, `retry_after_s`) so the HTTP frontend can build a 429 +
    Retry-After — and the obs layer a reason-labelled rejection counter —
    from the exception itself instead of parsing a message string.
    `info()` is the JSON-safe dict both consume."""

    reason = "queue_full"

    def __init__(self, msg: str = "", *, reason: str | None = None,
                 queue_depth: int = 0, retry_after_s: float | None = None):
        super().__init__(msg)
        if reason is not None:
            self.reason = reason
        self.queue_depth = queue_depth
        self.retry_after_s = retry_after_s

    def info(self) -> dict:
        return {"reason": self.reason, "queue_depth": self.queue_depth,
                "retry_after_s": self.retry_after_s}


class Unservable(QueueFull, ValueError):
    """A request no pool state can ever back (rejected at submit so it never
    head-of-line blocks). ValueError-compatible for legacy callers; carries
    the same structured fields as QueueFull with `retry_after_s=None` —
    retrying an unservable request is pointless by definition. (QueueFull
    leads the MRO so its keyword-aware __init__ wins over ValueError's
    C-level one.)"""

    reason = "unservable"


@dataclass
class Request:
    prompt: list[int]
    max_new: int
    sampling: SamplingParams = field(default_factory=SamplingParams)
    req_id: int = -1  # assigned by submit()
    arrival_s: float = 0.0        # stamped by submit()
    # latency-aware scheduling (serve/scheduler.py LatencyPolicy; the
    # default FifoPolicy ignores all of these)
    priority: int = 0             # higher = more urgent
    deadline_s: float | None = None  # seconds after arrival
    queued_ticks: int = 0         # scheduler aging counter (engine-owned)
    cached_hint: int = 0          # prefix-cache matched tokens (engine-owned)


@dataclass
class RequestResult:
    req_id: int
    prompt: list[int]
    tokens: list[int]
    arrival_s: float = 0.0
    finish_s: float = 0.0
    deadline_s: float | None = None
    # filled from the request's trace when observability is enabled
    # (EngineConfig.obs); None otherwise
    queue_wait_s: float | None = None   # submit -> slot admission
    ttft_s: float | None = None         # submit -> first sampled token
    decode_tok_s: float | None = None   # mean per-token decode latency

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def deadline_met(self) -> bool | None:
        if self.deadline_s is None:
            return None
        return self.latency_s <= self.deadline_s


@dataclass
class Handoff:
    """Finished prefill leaving a prefill-role engine for a decode-role one.

    Carries the ORIGINAL Request object (req_id intact — the frontend
    bridge keeps routing streamed tokens by id across the role boundary),
    the tokens generated so far (the first sampled token — its logits came
    from the prompt's last position on the prefill worker), and the
    prompt's KV as host-tier payloads: `(logical_block, payload)` pairs in
    `KVPool.read_block_host` format. Payloads are immutable snapshots
    (docs/CONVENTIONS.md §9); a partial tail block rides along whole —
    bytes past `length` are stale-behind-the-position-mask, exactly like
    any other partially filled block. bf16 payloads import bit-exactly, so
    a disaggregated greedy stream equals the single-engine stream."""

    req: Request
    generated: list[int]
    length: int                       # prompt tokens backed by the payloads
    blocks: list                      # [(logical_idx, payload), ...]


@dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4
    max_len: int = 256            # per-sequence capacity (prompt + generated)
    block_size: int = 16
    n_blocks: int | None = None   # pool size; default n_slots * max_len / bs
    prefill_chunk: int = 16
    paged: bool = True
    prequant: bool = True
    scheme: str = "quartet2"
    max_queue: int = 256
    base_seed: int = 0
    # self-speculative decoding (serve/spec_decode.py): propose spec_k tokens
    # per round with the first draft_layers blocks, verify in one chunk
    spec_k: int = 0               # 0 disables speculation
    draft_layers: int = 0         # truncated-stack draft depth
    # block-table flash-decode kernel (kernels/paged_attention.py): None
    # resolves per backend — the Pallas kernel on TPU (the body is
    # pltpu-specific), the gather_view reference path everywhere else
    # (where the kernel would only ever run interpreted). Explicit True
    # forces the kernel (interpret mode off-TPU — how the parity tests
    # drive it); requires paged=True.
    paged_kernel: bool | None = None
    # NVFP4-quantized KV cache (serve/kv_pool.py PackedKV): token-kind pool
    # leaves stored as packed e2m1 codes + e4m3 group scales (0.28125x the
    # bf16 HBM bytes), quantized per token at scatter time with
    # deterministic RTN and dequantized in-kernel (paged_kernel) or exactly
    # on the gather path. Requires paged=True; the bf16 pool stays the
    # bitwise reference mode. Incompatible with spec_k > 0: exact
    # speculative verification is specified against the bf16 cache image,
    # and a quantized target cache would silently change acceptance.
    kv_quant: bool = False
    # mesh-sharded serving (launch.mesh.make_serve_mesh): decode slots + the
    # slot-affine KV pool shard over the mesh's "data" axis (manual
    # shard_map — no pool collectives), packed weights + LM head over
    # "model" (GSPMD auto). None = single-host (all steps unwrapped).
    # Requires n_slots and the pool's n_blocks divisible by the "data" size.
    mesh: Any = None
    # radix-tree prefix cache (serve/prefix_cache.py): retired sequences'
    # blocks stay cached; a new request aliases its longest cached prefix
    # read-only (COW at the divergence) and skips that prefill entirely.
    # Silently inactive where sharing is unsound — dense caches, sliding-
    # window (pure-lattn) pools, recurrent-state archs; `engine.cache` is
    # None there.
    prefix_cache: bool = False
    # hierarchical prefix cache (requires prefix_cache=True): eviction under
    # pool pressure spills block bytes to a host-RAM tier instead of
    # dropping them, and a later match swaps them back in asynchronously —
    # a spill-hot request still skips every prefill forward over its
    # matched prefix, bitwise-equal to cold under bf16. Also lifts the
    # shard-affinity limit: spilled/hot prefixes become reachable from any
    # shard via host-tier copies (serve/prefix_cache.py module docstring).
    prefix_spill: bool = False
    # optional cap on host-tier bytes (LRU snapshot trim); None = unbounded
    host_budget_bytes: int | None = None
    # proactive cross-shard replication: nodes matched this many times get
    # their blocks copied into peer shards' pools through the host tier
    # (bounded to one block per engine tick; free blocks only — replication
    # never evicts). None disables; meaningless with n_shards == 1.
    replicate_hits: int | None = None
    # disaggregated prefill/decode (serve/frontend.py EnginePair): "both"
    # is the classic single engine; a "prefill" worker runs admission +
    # chunked prefill only and exports finished KV as host-tier Handoffs;
    # a "decode" worker admits Handoffs into DECODE slots (zero prefill
    # forwards) and runs only decode ticks — prefill chunks never steal
    # decode ticks. Split roles require a paged pool without sliding-window
    # reclamation or recurrent state (whole resident blocks must travel)
    # and spec_k == 0 (the draft pool does not travel with the handoff).
    role: str = "both"
    # scheduler policy object (serve/scheduler.py). None -> FifoPolicy,
    # which reproduces the pre-policy engine exactly; LatencyPolicy adds
    # priority/deadline admission, prefill preemption, and aging.
    scheduler: Any = None
    # observability hook (obs/instrumentation.py Instrumentation). None
    # disables ALL instrumentation beyond the legacy stats dict — the
    # engine hot path then costs one `.enabled` attribute read per hook
    # site and token streams are bitwise identical to the uninstrumented
    # engine (tests/test_obs.py).
    obs: Any = None
    # injectable monotonic clock (() -> float seconds). None resolves to
    # time.perf_counter. EVERY engine timestamp flows through it — arrival
    # stamps, scheduler `now`, trace span boundaries, step timers — so
    # deadline-slack, aging-bound, and the frontend's visibility-timeout
    # logic are testable with a fake clock instead of real sleeps.
    clock: Any = None
    # per-token emission hook: callable(req, new_tokens, result) invoked
    # from host tick boundaries whenever a slot's generated stream grows
    # (result is the RequestResult at retirement, None otherwise). This is
    # what the streaming frontend (serve/frontend.py) rides — without it
    # tokens only surface at retirement. Reassignable post-construction via
    # `engine.token_hook`; called on whichever thread steps the engine.
    token_hook: Any = None

    def resolved_paged_kernel(self) -> bool:
        if self.paged_kernel is None:
            return self.paged and jax.default_backend() == "tpu"
        return self.paged_kernel


@dataclass
class _Slot:
    state: str = FREE
    req: Request | None = None
    cursor: int = 0               # prompt tokens already prefilled
    length: int = 0               # tokens currently in the cache
    last_tok: int = 0
    generated: list[int] = field(default_factory=list)
    emitted: int = 0              # generated tokens already flushed through
    #                               the per-token hook (engine.token_hook)
    draft_len: int = 0            # tokens the spec draft has consumed
    prefix_len: int = 0           # prompt tokens adopted from the prefix
    #                               cache (cursor starts here; their prefill
    #                               is skipped)
    cache_nodes: list = field(default_factory=list)  # pinned radix nodes


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, econf: EngineConfig | None = None):
        if cfg.enc_dec:
            raise NotImplementedError("enc-dec serving: use the explicit "
                                      "encoder path (examples)")
        self.cfg = cfg
        self.econf = econf or EngineConfig()
        e = self.econf
        # one monotonic clock for every engine timestamp (EngineConfig.clock;
        # the frontend bridge shares it for visibility-timeout bookkeeping)
        self.clock = e.clock if e.clock is not None else time.perf_counter
        self.token_hook = e.token_hook
        # observability: resolved FIRST so prequantization can report its
        # weight-quantization health through the probe
        self.obs = e.obs if e.obs is not None else NULL
        probe = self.obs.quant_probe if self.obs.enabled else None
        self.params = (prequantize(params, cfg, e.scheme, probe=probe)
                       if e.prequant else params)
        self.paged_kernel = e.resolved_paged_kernel()
        if self.paged_kernel and not e.paged:
            raise ValueError("paged_kernel=True requires paged=True (the "
                             "kernel consumes pool-shaped leaves + a block "
                             "table; dense caches have neither)")
        self.mesh = e.mesh
        self.data_shards = 1
        if self.mesh is not None:
            self.data_shards = dict(self.mesh.shape).get("data", 1)
            if e.n_slots % self.data_shards:
                raise ValueError(
                    f"n_slots={e.n_slots} must divide over the mesh 'data' "
                    f"axis ({self.data_shards}): shard_map splits the slot "
                    "batch evenly")
        if e.kv_quant and not e.paged:
            raise ValueError("kv_quant=True requires paged=True: the NVFP4 "
                             "cache format is a property of pool blocks "
                             "(the dense cache is the bitwise reference)")
        if e.kv_quant and e.spec_k > 0:
            raise ValueError("kv_quant=True is incompatible with spec_k > 0: "
                             "exact speculative verification is defined "
                             "against the bf16 cache image")
        self.pool = KVPool(cfg, e.n_slots, e.max_len, paged=e.paged,
                           block_size=e.block_size, n_blocks=e.n_blocks,
                           n_shards=self.data_shards, quantized=e.kv_quant)
        if e.role not in ("both", "prefill", "decode"):
            raise ValueError(
                f"role must be 'both', 'prefill' or 'decode', got {e.role!r}")
        if e.role != "both":
            if (not e.paged or self.pool.window is not None
                    or self.pool.has_state_kinds):
                raise ValueError(
                    "disaggregated roles require a paged pool without "
                    "sliding-window reclamation or recurrent state kinds: "
                    "the KV handoff moves whole resident blocks")
            if e.spec_k > 0:
                raise ValueError(
                    "disaggregated roles are incompatible with spec_k > 0 "
                    "(the draft pool does not travel with the handoff)")
        self.role = e.role
        self.handoffs: deque[Handoff] = deque()       # prefill: exported
        self.handoff_queue: deque[Handoff] = deque()  # decode: awaiting slot
        if self.mesh is not None:
            # commit the hot state to its serving layout up front: packed
            # weights + head over "model", cache block/slot homes over
            # "data" — the jitted steps then never reshard
            from repro.dist import sharding as SH
            self.params = jax.device_put(
                self.params, SH.serve_param_shardings(self.params, self.mesh))
            self.pool.caches = jax.device_put(
                self.pool.caches,
                SH.serve_cache_shardings(self.pool.caches, self.mesh))
        if e.spec_k > 0:
            if e.draft_layers <= 0:
                raise ValueError("spec_k > 0 requires draft_layers >= 1")
            if cfg.rwkv is not None and e.spec_k + 1 >= cfg.rwkv.chunk:
                # the (n_slots, spec_k+1) verify chunk must stay on the
                # per-token WKV tail path — the chunk-parallel form's
                # accumulation order differs from S=1 steps, which would
                # silently break bitwise equality with the non-spec engine
                raise ValueError(
                    f"spec_k={e.spec_k} needs spec_k + 1 < rwkv.chunk "
                    f"({cfg.rwkv.chunk}) for exact verification")
            self.draft = spec_decode.DraftStack(cfg, self.params, e)
            # one compiled resampler serves every stochastic slot (shapes
            # are fixed per engine: (spec_k,) drafts, (spec_k+1, V) logits;
            # temperature/top_k are traced scalars, so no per-value
            # recompiles) — spec_round would otherwise dispatch the whole
            # sort/softmax/categorical chain eagerly per slot per round
            self._resample = jax.jit(
                lambda drafts, target_logits, key, temp, tk:
                speculative_resample(drafts, None, target_logits, key,
                                     temperature=temp, top_k=tk))
        else:
            self.draft = None
        # a verify chunk writes up to spec_k positions past a sequence's
        # final token; admission reserves that overshoot margin up front
        self._margin = e.spec_k
        # largest per-ensure growth any engine path performs (prefill chunk,
        # spec verify chunk, single decode token) — lets window-reclaimed
        # pools admit sequences against their LIVE-block bound instead of
        # blocks_for(total), so long lattn requests fit O(window) pools
        self._max_growth = max(e.prefill_chunk, e.spec_k + 1)
        self.slots = [_Slot() for _ in range(e.n_slots)]
        self.queue: deque[Request] = deque()
        self._ids = itertools.count()
        self._step_fns: dict[int, object] = {}
        self._sampler = jax.jit(sample_tokens)
        self._key = jax.random.PRNGKey(e.base_seed)
        self._tick = 0
        # radix prefix cache: only where sharing is exact (paged layout, no
        # sliding-window reclamation, no recurrent state) — excluded
        # configurations run with cache=None, bit-identically to
        # prefix_cache=False (serve/prefix_cache.py module docstring)
        self.cache = None
        self._matches: dict[int, tuple[int, Any]] = {}  # req_id -> (epoch, Match)
        if e.prefix_spill and not e.prefix_cache:
            raise ValueError("prefix_spill=True requires prefix_cache=True "
                             "(the host tier is a property of the cache)")
        if e.prefix_cache:
            from repro.serve.prefix_cache import PrefixCache
            if PrefixCache.supported(self.pool):
                self.cache = PrefixCache(
                    self.pool, spill=e.prefix_spill,
                    host_budget_bytes=e.host_budget_bytes,
                    replicate_hits=e.replicate_hits, clock=self.clock)
        from repro.serve.scheduler import FifoPolicy
        self.sched = e.scheduler if e.scheduler is not None else FifoPolicy()
        # stats store: a plain dict when observability is off (the legacy
        # layout, zero overhead), registry-backed counters behind the same
        # MutableMapping surface when on — `engine.stats` is a property so
        # every existing caller (`stats[k] += n`, bench reset loops,
        # snapshot comparisons) works against either
        if self.obs.enabled:
            self._stats = self.obs.stats_view()
            self.pool.obs = self.obs
            if self.cache is not None:
                self.cache.obs = self.obs
        else:
            self._stats = legacy_stats_dict()

    @property
    def stats(self):
        """Engine counters (legacy dict surface; see __init__)."""
        return self._stats

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> int:
        """Queue a request; raises QueueFull (structured: reason / queue
        depth / suggested retry_after_s) at capacity, Unservable (a
        QueueFull AND ValueError) when no pool state can ever back it."""
        if self.role == "decode":
            # a decode worker never prefills: plain submissions would wedge
            # in PREFILL forever. Work arrives as Handoffs (submit_handoff);
            # the EnginePair facade routes submits to the prefill worker.
            self.stats["rejected"] += 1
            exc = Unservable("decode-role engine takes Handoffs, not "
                             "prompts (submit to the prefill worker)",
                             reason="wrong_role",
                             queue_depth=len(self.handoff_queue))
            if self.obs.enabled:
                self.obs.on_reject(request, exc.reason, self.clock())
            raise exc
        total = len(request.prompt) + request.max_new + self._margin
        if not self.pool.can_ever_admit(total, self._max_growth):
            # reject now: an unservable request would head-of-line block the
            # FIFO forever (can_admit never becomes true)
            self.stats["rejected"] += 1
            bound = (f"{self.pool.blocks_per_shard} blocks per shard "
                     f"(slot-affine, {self.pool.n_shards} shards)"
                     if self.pool.n_shards > 1
                     else f"{self.pool.n_blocks} blocks")
            exc = Unservable(
                f"request needs {total} positions "
                f"({self.pool.max_live_blocks(total, self._max_growth)} live "
                f"blocks) but the pool serves at most "
                f"max_len={self.econf.max_len} / {bound}",
                queue_depth=len(self.queue))
            if self.obs.enabled:
                self.obs.on_reject(request, exc.reason, self.clock())
            raise exc
        if len(self.queue) >= self.econf.max_queue:
            # checked AFTER unservability: a permanent rejection must not
            # masquerade as a transient queue-full when the queue happens
            # to be saturated (clients would retry forever)
            self.stats["rejected"] += 1
            exc = QueueFull(
                f"queue at capacity ({self.econf.max_queue})",
                queue_depth=len(self.queue),
                retry_after_s=self.suggested_retry_after_s())
            if self.obs.enabled:
                self.obs.on_reject(request, exc.reason, self.clock())
            raise exc
        request.req_id = next(self._ids)
        request.arrival_s = self.clock()
        self.queue.append(request)
        if self.obs.enabled:
            self.obs.on_submit(request, request.arrival_s)
        return request.req_id

    def cancel(self, req_id: int, reason: str = "cancelled") -> bool:
        """Best-effort cancellation: remove a QUEUED request, or free the
        slot of an in-flight one (its committed KV prefix is inserted into
        the prefix cache first — the tokens were paid for; a resubmission
        reuses them). `reason` labels the trace span / metrics
        ("cancelled" | "disconnected" | "requeued" — the frontend's
        lifecycle states all funnel through this one reclaim path).
        Returns False when `req_id` is unknown (already retired, rejected,
        or never submitted)."""
        t = self.clock()
        for r in self.queue:
            if r.req_id == req_id:
                self.queue.remove(r)
                self._matches.pop(req_id, None)
                self.stats["cancelled"] += 1
                if self.obs.enabled:
                    self.obs.on_cancel(r, t, reason=reason)
                return True
        for h in self.handoff_queue:
            if h.req.req_id == req_id:
                # received but not yet admitted: the prefill worker already
                # released its blocks at export, and this engine never
                # allocated — dropping the host payloads reclaims everything
                self.handoff_queue.remove(h)
                self.stats["cancelled"] += 1
                if self.obs.enabled:
                    self.obs.on_cancel(h.req, t, reason=reason)
                return True
        for i, s in enumerate(self.slots):
            if s.req is not None and s.req.req_id == req_id:
                if self.cache is not None:
                    # same order as retirement: insert while the blocks are
                    # still referenced, then drop this slot's pins
                    stream = (s.req.prompt + s.generated)[:self.pool.length(i)]
                    self.cache.insert(stream, i)
                    if s.cache_nodes:
                        self.cache.release(s.cache_nodes)
                self.pool.release(i)
                if self.draft is not None:
                    self.draft.pool.release(i)
                self.slots[i] = _Slot()
                self.stats["cancelled"] += 1
                if self.obs.enabled:
                    self.obs.on_cancel(s.req, t, reason=reason)
                return True
        return False

    def submit_handoff(self, handoff: Handoff) -> None:
        """Hand a finished prefill to this decode-role engine. The payloads
        are host memory — nothing is allocated until `_admit` finds a slot,
        so a queued Handoff cancels by simply dropping it."""
        if self.role != "decode":
            raise ValueError("submit_handoff on a non-decode-role engine")
        self.handoff_queue.append(handoff)

    def suggested_retry_after_s(self) -> float:
        """Backpressure hint for rejected clients: seconds until the engine
        has plausibly worked the backlog down. Estimated as the queued +
        in-flight generated-token backlog over the decode rate observed so
        far, clamped to [0.5, 60]; 1.0 before any decode step has run."""
        if self.stats["decode_tokens"] <= 0:
            return 1.0
        backlog = sum(r.max_new for r in self.queue)
        for s in self.slots:
            if s.req is not None:
                backlog += max(s.req.max_new - len(s.generated), 0)
        rate = self.stats["decode_tokens"] / max(self.stats["decode_s"], 1e-9)
        return float(min(max(backlog / max(rate, 1e-9), 0.5), 60.0))

    def has_work(self) -> bool:
        return (bool(self.queue) or bool(self.handoff_queue)
                or any(s.state != FREE for s in self.slots))

    def run(self) -> list[RequestResult]:
        """Drain queue + slots; returns results in completion order."""
        out: list[RequestResult] = []
        while self.has_work():
            out.extend(self.step())
        return out

    @property
    def free_slots(self) -> int:
        return sum(s.state == FREE for s in self.slots)

    # ------------------------------------------------------------------
    # scheduler iteration
    # ------------------------------------------------------------------

    def step(self) -> list[RequestResult]:
        """One scheduler tick: admit, one prefill chunk, one decode step.

        Role-split engines run half a tick each: a prefill worker never
        decodes (finished prompts leave as Handoffs instead), a decode
        worker never prefills (Handoffs admit straight into DECODE)."""
        self.stats["ticks"] += 1
        self._admit()
        if self.role != "decode":
            self._prefill_tick()
        finished = (self._handoff_tick() if self.role == "prefill"
                    else self._decode_tick())
        if self.cache is not None and self.cache.spill:
            # tick-boundary host-tier work: bounded proactive replication of
            # hot prefixes, then fold this tick's dispatched swap-ins into
            # the plain cached-block accounting (the writes are ordered
            # before any dependent step read — no sync here)
            self.cache.replicate_hot()
            self.cache.complete_swaps()
        if self.obs.enabled:
            self.obs.on_tick(self)  # occupancy / pool / cache gauges
        return finished

    def _admit(self) -> None:
        for r in self.queue:
            r.queued_ticks += 1  # scheduler aging (LatencyPolicy)
        if self.obs.enabled:
            # queue depth / aging / slack gauges — the policy object knows
            # its own urgency model, so IT reports (scheduler.py observe)
            self.sched.observe(self.obs, self.queue, self.clock())
        if self.role == "decode":
            # Handoffs admit FIFO straight into DECODE: the KV is already
            # computed, so "admission" is commit + allocate + import the
            # host payloads (zero prefill forwards on this engine)
            while (self.handoff_queue
                   and any(s.state == FREE for s in self.slots)):
                if not self._try_admit_handoff(self.handoff_queue[0]):
                    return
                self.handoff_queue.popleft()
            return
        if not self.queue:
            return
        now = self.clock()
        if self.cache is not None and not self.sched.head_of_line:
            # cache-aware admission ordering: a large cached prefix makes a
            # request cheap to admit (its prefill is mostly skipped).
            # Matches are memoized per request against the cache EPOCH
            # (prompts are immutable; the tree only changes on
            # insert/evict), so a deferred request costs one radix walk per
            # tree change, not one per tick. Tiered caches weight the hint
            # by residency (spilled tokens count half — a swap-in is far
            # cheaper than prefill but not free; scheduler.py ordering law)
            for r in self.queue:
                r.cached_hint = self.cache.hint_tokens(self._match(r))
        while self.queue and any(s.state == FREE for s in self.slots):
            admitted = False
            for req in self.sched.admission_order(self.queue, now):
                if self._try_admit(req):
                    self.queue.remove(req)
                    admitted = True
                    break
                if self.sched.head_of_line:
                    return  # FIFO: don't overtake the head request
            if not admitted:
                return

    def _match(self, req: Request):
        """Epoch-memoized cache match for a queued request (shared by the
        hint pass and `_try_admit` — the same prompt is never re-walked
        while the tree is unchanged)."""
        hit = self._matches.get(req.req_id)
        if hit is None or hit[0] != self.cache.epoch:
            hit = (self.cache.epoch, self.cache.match(req.prompt))
            self._matches[req.req_id] = hit
        return hit[1]

    def _try_admit(self, req: Request) -> bool:
        """Place `req` in a FREE slot (prefix-cache aliasing + shard-
        occupancy placement); False when no slot/shard can back it now."""
        total = len(req.prompt) + req.max_new + self._margin
        plan = pinned = None
        if self.cache is not None:
            m = self._match(req)
            # the last prompt token is always computed (its logits seed the
            # first generated token), so cap the usable match below it
            mtoks, adopt, tail = m.plan(len(req.prompt) - 1,
                                        self.pool.block_size)
            if mtoks > 0:
                # without the host tier the plan is usable only on its home
                # shard (slot affinity); with it any shard works — spilled
                # or off-shard blocks materialize on the placed shard
                plan = (mtoks, adopt, tail,
                        None if self.cache.spill else m.shard)
                # pin BEFORE any eviction below can see these nodes unpinned
                pinned = adopt + ([tail] if tail is not None else [])
                self.cache.acquire(pinned)
        i = self._place(total, plan)
        if i is None and plan is not None:
            # the prefix's home shard has no usable slot: admit cold on the
            # occupancy-best shard instead (slot affinity makes the cached
            # blocks unreachable from other shards)
            self.cache.release(pinned)
            plan = pinned = None
            i = self._place(total, None)
        if i is None:
            if pinned:
                self.cache.release(pinned)
            return False
        if plan is not None and self.cache.spill:
            # swap spilled/off-shard planned blocks onto the placed shard
            # (dispatched host->device copies overlapping later ticks); on
            # shortage fall back to a cold admission of the same slot
            try:
                self.cache.materialize(pinned, self.pool.shard_of_slot(i))
            except OutOfBlocks:
                self.cache.release(pinned)
                plan = pinned = None
        self.pool.reset_slot(i)
        self.pool.commit(i, total, self._max_growth)
        prefix_len = 0
        nodes: list = []
        if plan is not None:
            mtoks, adopt, tail, _ = plan
            sh = self.pool.shard_of_slot(i)
            if adopt:
                self.pool.adopt_prefix(i, [n.blocks[sh] for n in adopt],
                                       len(adopt) * self.pool.block_size)
            if tail is not None:
                self.pool.cow_block(i, tail.blocks[sh])
                self.cache.release([tail])  # private copy made; unpin
            self.pool.ensure(i, mtoks)
            prefix_len = mtoks
            nodes = adopt
            self.stats["prefill_skipped_tokens"] += mtoks
            self.stats["prefix_hits"] += 1
        if self.draft is not None:
            # the draft pool never aliases (serve/spec_decode.py owns no
            # cache); _prefill_tick catches its cursor up over the skipped
            # prefix with truncated-stack chunks
            self.draft.pool.reset_slot(i)
            self.draft.pool.commit(i, total, self._max_growth)
        self.slots[i] = _Slot(state=PREFILL, req=req, cursor=prefix_len,
                              prefix_len=prefix_len, cache_nodes=nodes)
        self.stats["admitted"] += 1
        if self.obs.enabled:
            self.obs.on_admit(req, i, prefix_len, self.clock())
        if self.cache is not None:
            # hit-rate stats book exactly once per ADMITTED request (a
            # deferred request re-matches every tick; recording those
            # retries would inflate the rate), and only ADOPTED matches
            # count as hits (a cross-shard match that admitted cold is a
            # miss in every way that matters)
            self.cache.record(m if plan is not None else None)
            self._matches.pop(req.req_id, None)  # left the queue
        return True

    def _place(self, total: int, plan) -> int | None:
        """Pick a FREE slot for a request needing `total` positions.

        With a prefix-cache `plan` pinned to a home shard (plan[3] set —
        the non-spill mode), only the matched shard's slots can use the
        cached blocks (slot affinity). A host-tier plan (plan[3] None)
        ranks EVERY free shard by replicated-prefix availability — how many
        planned blocks are already resident there — before effective free
        blocks, so a replica-holding shard wins over a merely-empty one and
        only the remainder swaps in. Cold placement is shard-occupancy-
        aware: shards are tried by free-block count (descending, slot id
        breaking ties) instead of first-fit — single-shard pools reduce to
        the original first-free-slot behavior exactly. When a shard is
        short, unpinned cached prefixes on it are evicted before giving
        up."""
        free_by_shard: dict[int, list[int]] = {}
        for i, s in enumerate(self.slots):
            if s.state == FREE:
                free_by_shard.setdefault(self.pool.shard_of_slot(i),
                                         []).append(i)

        def resident(sh):
            n = sum(1 for node in plan[1] if sh in node.blocks)
            if plan[2] is not None and sh in plan[2].blocks:
                n += 1
            return n

        if plan is not None and plan[3] is not None:
            shard_cached = {plan[3]: len(plan[1])} if plan[3] in free_by_shard \
                else {}
        elif plan is not None:
            # admission credit counts only blocks ALREADY resident on the
            # shard: the rest are swapped in from the host tier and draw on
            # the free list exactly like a cold allocation would
            shard_cached = {sh: sum(1 for node in plan[1]
                                    if sh in node.blocks)
                            for sh in free_by_shard}
        else:
            shard_cached = {sh: 0 for sh in free_by_shard}
        if plan is not None and plan[3] is None:
            shards = sorted(free_by_shard,
                            key=lambda sh: (-resident(sh),
                                            -self.pool.effective_free_blocks(sh),
                                            sh))
        else:
            shards = sorted(shard_cached,
                            key=lambda sh: (-self.pool.effective_free_blocks(sh)
                                            if self.pool.paged else 0, sh))
        for sh in shards:
            i = free_by_shard[sh][0]
            if self._admissible(i, total, shard_cached[sh]):
                return i
        return None

    def _admissible(self, slot: int, total: int, cached: int) -> bool:
        if self.draft is not None and not self.draft.pool.can_admit(
                total, self._max_growth, slot=slot):
            return False
        if self.pool.can_admit(total, self._max_growth, slot=slot,
                               cached_blocks=cached):
            return True
        if self.cache is not None:
            short = self.pool.admission_shortfall(
                total, self._max_growth, slot=slot, cached_blocks=cached)
            if short and self.cache.evict(self.pool.shard_of_slot(slot),
                                          short) >= short:
                return self.pool.can_admit(total, self._max_growth,
                                           slot=slot, cached_blocks=cached)
        return False

    def _prefill_tick(self) -> None:
        e = self.econf
        cands = [(i, s) for i, s in enumerate(self.slots)
                 if s.state == PREFILL]
        if not cands:
            return
        # scheduler preemption point: the policy picks WHICH prefilling
        # slot advances this tick (Fifo: lowest index, the original
        # behavior; LatencyPolicy: most urgent request first). Slots NOT
        # picked keep aging, so preemption is starvation-free too: a
        # low-priority prompt passed over by a stream of critical arrivals
        # grows its effective priority until it wins the pick.
        i = self.sched.pick_prefill(cands, self.clock())
        for j, s in cands:
            if j != i:
                s.req.queued_ticks += 1
        slot = self.slots[i]
        prompt = slot.req.prompt
        if self.draft is not None and slot.draft_len < slot.cursor:
            # prefix-cache skip left the DRAFT behind (its pool never
            # aliases): catch it up over the skipped tokens with truncated-
            # stack chunks — draft_layers/L of a full forward, still one
            # bounded chunk per tick
            gap = slot.cursor - slot.draft_len
            size = e.prefill_chunk if gap >= e.prefill_chunk else 1
            tokens = np.zeros((e.n_slots, size), np.int32)
            tokens[i] = prompt[slot.draft_len: slot.draft_len + size]
            pos = np.zeros((e.n_slots,), np.int32)
            pos[i] = slot.draft_len
            active = np.zeros((e.n_slots,), bool)
            active[i] = True
            t0 = self.clock()
            self.draft.pool.ensure(i, slot.draft_len + size)
            out = self.draft.forward(size, tokens, pos, active)
            t_disp = self.clock() - t0
            # sync the draft CACHE writes too, not just the logits — an
            # async cache write landing after the timer stops would be
            # billed to whatever step happens to sync next
            jax.block_until_ready((out, self.draft.pool.caches))
            t_sync = self.clock() - t0
            self.stats["prefill_s"] += t_sync
            if self.obs.enabled:
                self.obs.on_prefill_step(t_disp, t_sync)
            slot.draft_len += size
            return  # bounded work: one chunk per tick
        remaining = len(prompt) - slot.cursor
        size = e.prefill_chunk if remaining >= e.prefill_chunk else 1
        chunk = prompt[slot.cursor: slot.cursor + size]
        self.pool.ensure(i, slot.cursor + size)
        tokens = np.zeros((e.n_slots, size), np.int32)
        tokens[i] = chunk
        pos = np.zeros((e.n_slots,), np.int32)
        pos[i] = slot.cursor
        active = np.zeros((e.n_slots,), bool)
        active[i] = True
        t0 = self.clock()
        logits = self._forward(size, tokens, pos, active)
        if self.draft is not None:
            # the draft cache covers the prompt too: same chunk through
            # the prefix stack (its layers recompute what the first
            # draft_layers of the full forward just computed)
            self.draft.pool.ensure(i, slot.cursor + size)
            self.draft.forward(size, tokens, pos, active)
        t_disp = self.clock() - t0
        # sync logits AND the cache pytrees: blocking on logits alone lets
        # the (donated, in-place) KV scatter complete asynchronously AFTER
        # the timer stops, under-reporting prefill_s and leaking device
        # time into whichever step syncs next
        sync = [logits, self.pool.caches]
        if self.draft is not None:
            sync.append(self.draft.pool.caches)
        jax.block_until_ready(sync)
        t_sync = self.clock() - t0
        self.stats["prefill_s"] += t_sync
        self.stats["prefill_tokens"] += size
        self.stats["prefill_steps"] += 1
        if self.obs.enabled:
            self.obs.on_prefill_step(t_disp, t_sync)
        slot.cursor += size
        slot.draft_len = slot.cursor
        if slot.cursor == len(prompt):
            # prompt fully cached: sample the first generated token from
            # the logits of the prompt's last position
            tok = int(self._sample(logits[:, -1])[i])
            slot.state = DECODE
            slot.length = len(prompt)
            slot.last_tok = tok
            slot.generated.append(tok)
            if self.obs.enabled:
                self.obs.on_first_token(slot.req, self.clock())
            self._flush(i)
        return  # bounded work: one chunk per tick

    def _retire_slot(self, i: int) -> RequestResult:
        """Complete slot `i`: emit the result, cache the stream's blocks,
        release pins and pool state (cache-insert-then-release ordering:
        insertion adds the cache's own ref while the blocks are still
        referenced; release only ever decrefs)."""
        slot = self.slots[i]
        res = RequestResult(
            slot.req.req_id, list(slot.req.prompt),
            list(slot.generated), arrival_s=slot.req.arrival_s,
            finish_s=self.clock(),
            deadline_s=slot.req.deadline_s)
        if self.obs.enabled:
            # closes the trace and surfaces queue-wait / TTFT /
            # per-token decode latency on the result
            self.obs.on_retire(slot.req, res, len(slot.generated),
                               res.finish_s)
        self._flush(i, res)
        if self.cache is not None:
            self.cache.insert(slot.req.prompt + slot.generated, i)
            if slot.cache_nodes:
                self.cache.release(slot.cache_nodes)
        self.pool.release(i)
        if self.draft is not None:
            self.draft.pool.release(i)
        self.slots[i] = _Slot()
        self.stats["finished"] += 1
        return res

    def _handoff_tick(self) -> list[RequestResult]:
        """Prefill-role half-tick: every slot whose prompt just finished
        (DECODE state = prompt cached + first token sampled) leaves as a
        Handoff — its KV snapshotted block-by-block to host payloads, its
        prompt's full blocks inserted into this worker's prefix cache
        (future shared prompts skip prefill HERE too), its pool state
        released. A request its first token already completed (max_new=1)
        retires locally; there is nothing left to decode."""
        out: list[RequestResult] = []
        for i, s in enumerate(self.slots):
            if s.state != DECODE:
                continue
            if len(s.generated) >= s.req.max_new:
                out.append(self._retire_slot(i))
                continue
            blocks = []
            for j in range(self.pool._alloc_upto[i]):
                blk = int(self.pool._table[i, j])
                if blk == self.pool.sentinel:
                    continue
                payload, _ = self.pool.read_block_host(blk)
                blocks.append((j, payload))
            h = Handoff(req=s.req, generated=list(s.generated),
                        length=s.length, blocks=blocks)
            if self.cache is not None:
                # only the PROMPT is cached: the first generated token was
                # sampled but its KV was never written on this engine
                self.cache.insert(s.req.prompt, i)
                if s.cache_nodes:
                    self.cache.release(s.cache_nodes)
            self.pool.release(i)
            self.slots[i] = _Slot()
            self.handoffs.append(h)
            self.stats["handoffs"] += 1
        return out

    def _try_admit_handoff(self, h: Handoff) -> bool:
        """Import a Handoff into a FREE slot, straight into DECODE state:
        commit, allocate the prompt's blocks, dispatch the host payload
        writes (they overlap this tick's decode step — the next step's
        reads are ordered after them by the cache data dependence). The
        emitted counter starts past the handed-off tokens: the prefill
        worker already flushed them through the token hook."""
        req = h.req
        total = len(req.prompt) + req.max_new + self._margin
        i = self._place(total, None)
        if i is None:
            return False
        self.pool.reset_slot(i)
        self.pool.commit(i, total, self._max_growth)
        try:
            self.pool.ensure(i, h.length)
        except OutOfBlocks:
            self.pool.release(i)
            return False
        for j, payload in h.blocks:
            self.pool.write_block_host(int(self.pool._table[i, j]), payload)
        self.slots[i] = _Slot(state=DECODE, req=req, cursor=len(req.prompt),
                              length=h.length, last_tok=h.generated[-1],
                              generated=list(h.generated),
                              emitted=len(h.generated))
        self.stats["admitted"] += 1
        if self.obs.enabled:
            self.obs.on_admit(req, i, 0, self.clock())
        return True

    def _decode_tick(self) -> list[RequestResult]:
        e = self.econf
        dec = [i for i, s in enumerate(self.slots) if s.state == DECODE]
        finished: list[RequestResult] = []
        # retire before stepping: a slot whose request is already complete
        # (max_new reached) frees its blocks for the next admission
        for i in list(dec):
            if len(self.slots[i].generated) >= self.slots[i].req.max_new:
                finished.append(self._retire_slot(i))
                dec.remove(i)
        if not dec:
            return finished

        if e.spec_k > 0:
            t0 = self.clock()
            emitted = spec_decode.spec_round(self, dec)
            t_disp = self.clock() - t0
            # the whole cache pytree, not just the first leaf: truncate
            # rewrites tables but layer caches past leaf 0 may still have
            # in-flight scatters when the timer stops
            jax.block_until_ready(self.pool.caches)
            t_sync = self.clock() - t0
            self.stats["decode_s"] += t_sync
            self.stats["decode_tokens"] += emitted
            self.stats["decode_steps"] += 1
            if self.obs.enabled:
                self.obs.on_decode_step(t_disp, t_sync)
            for i in dec:
                self._flush(i)
            return finished

        tokens = np.zeros((e.n_slots, 1), np.int32)
        pos = np.zeros((e.n_slots,), np.int32)
        active = np.zeros((e.n_slots,), bool)
        for i in dec:
            slot = self.slots[i]
            self.pool.ensure(i, slot.length + 1)
            tokens[i, 0] = slot.last_tok
            pos[i] = slot.length
            active[i] = True
        t0 = self.clock()
        logits = self._forward(1, tokens, pos, active)
        toks = self._sample(logits[:, -1])
        t_disp = self.clock() - t0
        # sync tokens AND cache writes (same leak as prefill: the donated
        # cache scatter may outlive the token fetch)
        jax.block_until_ready((toks, self.pool.caches))
        t_sync = self.clock() - t0
        self.stats["decode_s"] += t_sync
        self.stats["decode_tokens"] += len(dec)
        self.stats["decode_steps"] += 1
        if self.obs.enabled:
            self.obs.on_decode_step(t_disp, t_sync)
        for i in dec:
            slot = self.slots[i]
            slot.length += 1
            slot.last_tok = int(toks[i])
            slot.generated.append(slot.last_tok)
            self._flush(i)
        return finished

    def _flush(self, i: int, result: RequestResult | None = None) -> None:
        """Push a slot's un-emitted generated tokens through the per-token
        hook (EngineConfig.token_hook / engine.token_hook). Called ONLY at
        host tick boundaries — after the prefill-completion sample, after a
        decode/spec round's appends, and at retirement (`result` then
        carries the final RequestResult alongside any remaining tokens) —
        so between ticks `emitted == len(generated)` always holds and a
        cancel landing between ticks never strands tokens."""
        if self.token_hook is None:
            return
        s = self.slots[i]
        new = s.generated[s.emitted:]
        if new or result is not None:
            s.emitted = len(s.generated)
            self.token_hook(s.req, new, result)

    # ------------------------------------------------------------------
    # jitted steps
    # ------------------------------------------------------------------

    def _forward(self, size: int, tokens, pos, active):
        fn = self._step_fns.get(size)
        if fn is None:
            # one engine-step builder serves both layouts (block_table=None
            # is the dense path); under a mesh the step is shard_map-wrapped:
            # manual over "data" (slots/pool/table/inputs pre-split,
            # shard-local gather/scatter), auto over "model" (GSPMD weights)
            if self.mesh is not None:
                step_fn = serve_decode.make_sharded_serve_step(
                    self.cfg, self.econf.scheme, self.mesh,
                    paged_kernel=self.paged_kernel)
            else:
                step_fn = serve_decode.make_paged_serve_step(
                    self.cfg, self.econf.scheme,
                    paged_kernel=self.paged_kernel)
            # donate the cache pytree: the pool is the dominant serving
            # allocation and the step rebinds it, so XLA may update in place
            # instead of double-buffering it
            fn = self._step_fns[size] = jax.jit(step_fn, donate_argnums=(1,))
        # stacked (n_slots, 2, max_blocks) read/write tables: scatters go
        # through the write view, whose prefix-cache-aliased entries hold
        # the sentinel — shared blocks are never written (kv_pool.py)
        logits, self.pool.caches = fn(
            self.params, self.pool.caches, self.pool.tables_device(),
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(active))
        return logits

    def _spec_key(self, slot: int):
        """Per-(round, slot) key for stochastic speculative acceptance
        (sampling.speculative_resample). Shares the engine's tick counter
        with `_sample`, so streams stay deterministic run-to-run for a
        fixed base_seed and submission order."""
        self._tick += 1
        return jax.random.fold_in(
            jax.random.fold_in(self._key, self._tick), 10_000 + slot)

    def _sample(self, last_logits):
        temps = np.zeros((self.econf.n_slots,), np.float32)
        topks = np.zeros((self.econf.n_slots,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.req is not None:
                temps[i] = slot.req.sampling.temperature
                topks[i] = slot.req.sampling.top_k
        self._tick += 1
        key = jax.random.fold_in(self._key, self._tick)
        return self._sampler(last_logits, jnp.asarray(temps),
                             jnp.asarray(topks), key)
