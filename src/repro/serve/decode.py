"""Serving step builders: prefill, decode, and the mesh-sharded decode step.

`make_paged_serve_step` is THE engine step (serve/engine.py compiles it per
chunk size; launch/dryrun lowers it for decode cells), and
`make_sharded_serve_step` is its multi-host form — the same function body
under a manual-"data" / auto-"model" `shard_map` (slot-affine pool slices,
shard-local block tables; see serve/README.md "Multi-host serving").
`make_serve_step`/`greedy_generate` remain as the legacy dense-cache
fixed-batch path (benchmarks' seed baseline, simple examples).

Role-split engines (`EngineConfig.role`) reuse these builders unchanged:
a "prefill" engine compiles only the prefill/chunk steps it runs before
exporting a `Handoff`, a "decode" engine admits handoffs through the
prefix cache and runs the same decode step as a monolithic engine — the
split is pure engine-loop policy, never a third step variant.

Forward quantization (RTN + 4/6) is deterministic, so serving needs no
per-step randomness — the seed below is a fixed constant feeding the
(unused-in-inference) backward.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm

_SEED = jnp.array([7, 7], jnp.uint32)


def make_prefill_step(cfg, scheme: str):
    def prefill_step(params, cache, batch):
        logits, cache, _ = lm.forward(params, cfg, batch, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="prefill")
        return logits, cache
    return prefill_step


def make_serve_step(cfg, scheme: str):
    def serve_step(params, cache, tokens, pos):
        logits, cache, _ = lm.forward(params, cfg, {"tokens": tokens}, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="decode", pos=pos)
        return logits, cache
    return serve_step


def make_sharded_serve_step(cfg, scheme: str, mesh, *,
                            paged_kernel: bool = False):
    """The engine's decode/prefill/verify step wrapped in a `shard_map` over
    the mesh's "data" axis — the multi-host serving hot path.

    Split of labor (see serve/README.md "Multi-host serving"):

      manual over "data" — decode slots, the KV pool (block axis of token
        kinds, slot axis of state kinds / dense caches), the block table,
        and the per-slot tokens/pos/active inputs all enter pre-split
        (`in_specs` below). The pool allocator is slot-affine
        (`KVPool(n_shards=...)`) and `table_device()` carries SHARD-LOCAL
        physical indices, so every gather/scatter the step performs resolves
        inside the local pool slice: the forward body runs UNCHANGED on
        local shapes, and no collective ever touches the pool.
      auto over every other axis ("model", "pod") — weights stay under
        GSPMD control, so `PackedQWeight` leaves placed with
        `dist.sharding.serve_param_shardings` compute row-split GEMMs with
        XLA-inserted activation reductions (activation-sized, not
        pool-sized, wire).

    Exactness: the decode forward is row-local per slot (docs/CONVENTIONS.md
    records the contract), so with model=1 the emitted greedy streams are
    BITWISE identical to the single-host engine — tests/test_serve_sharded.py
    pins this. check_rep is off: replication checking cannot see through the
    auto axes.

    When an auto axis is non-trivial (model > 1) the layer scan is fully
    UNROLLED: this XLA CHECK-fails propagating shardings into a while body
    inside a manual-subgroup region (lm._run_stages documents the failure).
    """
    return shard_serve_step(
        make_paged_serve_step(cfg, scheme, paged_kernel=paged_kernel,
                              unroll_stages=_needs_unroll(mesh)), mesh)


def _needs_unroll(mesh) -> bool:
    """True when the mesh carries a non-trivial GSPMD `auto` axis (anything
    but "data" with size > 1) — the configuration whose while-body sharding
    propagation is broken; see make_sharded_serve_step."""
    return any(ax != "data" and size > 1 for ax, size in dict(mesh.shape).items())


def shard_serve_step(step, mesh, *, out_batch_axis: int = 0):
    """shard_map-wrap any engine-step-signature function
    `(params, cache, table, tokens, pos, active) -> (out, cache)` with the
    standard serving specs: params replicated over the manual "data" axis
    (every other mesh axis auto / GSPMD), cache leaves split on axis 1
    (block / slot homes), per-slot inputs split on axis 0, and `out` split
    on `out_batch_axis` (0 for (B, S, V) logits; the speculative propose
    scan passes 1 for its (K, B) token stack)."""
    from repro import dist
    auto = frozenset(a for a in mesh.axis_names if a != "data")
    P = jax.sharding.PartitionSpec
    out_spec = P(*([None] * out_batch_axis), "data")
    return dist.shard_map(
        step, mesh=mesh,
        in_specs=(P(), P(None, "data"), P("data"), P("data"), P("data"),
                  P("data")),
        out_specs=(out_spec, P(None, "data")),
        check_rep=False, auto=auto)


def make_paged_serve_step(cfg, scheme: str, *, paged_kernel: bool = False,
                          unroll_stages: bool = False):
    """The ENGINE's decode step signature (per-slot position vector, active
    mask, block table, pool-shaped caches) — what launch/dryrun lowers for
    decode cells so the cost model prices the paged gather/scatter traffic
    instead of the legacy dense `serve_step`. `paged_kernel` switches the
    attention to the block-table flash-decode kernel (left off for cost
    analysis: the reference path's gather traffic is the thing being
    priced, and Pallas calls are opaque to the HLO cost model)."""
    def paged_serve_step(params, cache, table, tokens, pos, active):
        logits, cache, _ = lm.forward(params, cfg, {"tokens": tokens}, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="decode", pos=pos, active=active,
                                      block_table=table,
                                      paged_kernel=paged_kernel,
                                      unroll_stages=unroll_stages)
        return logits, cache
    return paged_serve_step


def greedy_generate(params, cfg, scheme, prompt_tokens, max_new: int,
                    max_len: int | None = None, prompt_lens=None):
    """Simple host-side generation loop (examples / tests / baseline).

    `prompt_tokens` is (B, S) right-padded; `prompt_lens` (B,) gives each
    row's true prompt length (default: all S). Decode runs with a
    per-sequence position vector, so ragged prompts get correct logits for
    attention-cached archs — previously a single scalar `pos` was shared
    across rows, attending pad keys for every short prompt. Recurrent-state
    archs (rwkv / griffin) integrate pad tokens during the single full-width
    prefill, so ragged batches there must go through ServeEngine (which
    prefills per sequence); this loop refuses rather than silently corrupt.

    This is the fixed-batch reference loop: it re-quantizes every weight on
    every step and restarts globally between batches. ServeEngine is the
    production path.
    """
    b, s = prompt_tokens.shape
    max_len = max_len or (s + max_new + 8)
    if cfg.enc_dec:
        raise NotImplementedError("use explicit enc-dec path in examples")
    has_recurrent_state = cfg.family == "ssm" or (
        cfg.family == "hybrid" and any(t == "rec" for t in cfg.griffin.pattern))
    if prompt_lens is not None and has_recurrent_state:
        raise NotImplementedError(
            "ragged prompts on recurrent-state archs: the full-width prefill "
            "would feed pad tokens into wkv/lru state — use serve.engine."
            "ServeEngine, which prefills each sequence at its true length")
    lens = (jnp.full((b,), s, jnp.int32) if prompt_lens is None
            else jnp.asarray(prompt_lens, jnp.int32))
    # Ragged batches need full-capacity sliding-window caches: the ring
    # prefill roll keeps the last `window` positions of the SHARED padded
    # width, which for a short row can evict real keys in favour of pads
    # that then alias earlier absolute positions. Window masking on a flat
    # cache is exact for every row.
    cache = lm.init_cache(cfg, b, max_len, lattn_ring=prompt_lens is None)
    prefill = jax.jit(make_prefill_step(cfg, scheme))
    step = jax.jit(make_serve_step(cfg, scheme))
    logits, cache = prefill(params, cache, {"tokens": prompt_tokens})
    last = logits[jnp.arange(b), lens - 1]          # each row's real last token
    tok = jnp.argmax(last, axis=-1)[:, None]
    out = [tok]
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, lens + i)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
