"""Serving entry points: prefill and single-token decode steps.

This module is the thin compatibility layer kept for the launch/dryrun cost
model and the simple examples; the production path is `serve.engine
.ServeEngine` (continuous batching, paged KV pool, quantize-once weights).

`serve_step` is what decode_32k / long_500k lower: one new token against a
pre-allocated KV/state cache at a traced position — now a PER-SEQUENCE (B,)
position vector (scalars broadcast), so ragged batches decode correctly.
Forward quantization (RTN + 4/6) is deterministic, so serving needs no
per-step randomness — the seed below is a fixed constant feeding the
(unused-in-inference) backward.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm

_SEED = jnp.array([7, 7], jnp.uint32)


def make_prefill_step(cfg, scheme: str):
    def prefill_step(params, cache, batch):
        logits, cache, _ = lm.forward(params, cfg, batch, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="prefill")
        return logits, cache
    return prefill_step


def make_serve_step(cfg, scheme: str):
    def serve_step(params, cache, tokens, pos):
        logits, cache, _ = lm.forward(params, cfg, {"tokens": tokens}, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="decode", pos=pos)
        return logits, cache
    return serve_step


def make_paged_serve_step(cfg, scheme: str, *, paged_kernel: bool = False):
    """The ENGINE's decode step signature (per-slot position vector, active
    mask, block table, pool-shaped caches) — what launch/dryrun lowers for
    decode cells so the cost model prices the paged gather/scatter traffic
    instead of the legacy dense `serve_step`. `paged_kernel` switches the
    attention to the block-table flash-decode kernel (left off for cost
    analysis: the reference path's gather traffic is the thing being
    priced, and Pallas calls are opaque to the HLO cost model)."""
    def paged_serve_step(params, cache, table, tokens, pos, active):
        logits, cache, _ = lm.forward(params, cfg, {"tokens": tokens}, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="decode", pos=pos, active=active,
                                      block_table=table,
                                      paged_kernel=paged_kernel)
        return logits, cache
    return paged_serve_step


def greedy_generate(params, cfg, scheme, prompt_tokens, max_new: int,
                    max_len: int | None = None, prompt_lens=None):
    """Simple host-side generation loop (examples / tests / baseline).

    `prompt_tokens` is (B, S) right-padded; `prompt_lens` (B,) gives each
    row's true prompt length (default: all S). Decode runs with a
    per-sequence position vector, so ragged prompts get correct logits for
    attention-cached archs — previously a single scalar `pos` was shared
    across rows, attending pad keys for every short prompt. Recurrent-state
    archs (rwkv / griffin) integrate pad tokens during the single full-width
    prefill, so ragged batches there must go through ServeEngine (which
    prefills per sequence); this loop refuses rather than silently corrupt.

    This is the fixed-batch reference loop: it re-quantizes every weight on
    every step and restarts globally between batches. ServeEngine is the
    production path.
    """
    b, s = prompt_tokens.shape
    max_len = max_len or (s + max_new + 8)
    if cfg.enc_dec:
        raise NotImplementedError("use explicit enc-dec path in examples")
    has_recurrent_state = cfg.family == "ssm" or (
        cfg.family == "hybrid" and any(t == "rec" for t in cfg.griffin.pattern))
    if prompt_lens is not None and has_recurrent_state:
        raise NotImplementedError(
            "ragged prompts on recurrent-state archs: the full-width prefill "
            "would feed pad tokens into wkv/lru state — use serve.engine."
            "ServeEngine, which prefills each sequence at its true length")
    lens = (jnp.full((b,), s, jnp.int32) if prompt_lens is None
            else jnp.asarray(prompt_lens, jnp.int32))
    # Ragged batches need full-capacity sliding-window caches: the ring
    # prefill roll keeps the last `window` positions of the SHARED padded
    # width, which for a short row can evict real keys in favour of pads
    # that then alias earlier absolute positions. Window masking on a flat
    # cache is exact for every row.
    cache = lm.init_cache(cfg, b, max_len, lattn_ring=prompt_lens is None)
    prefill = jax.jit(make_prefill_step(cfg, scheme))
    step = jax.jit(make_serve_step(cfg, scheme))
    logits, cache = prefill(params, cache, {"tokens": prompt_tokens})
    last = logits[jnp.arange(b), lens - 1]          # each row's real last token
    tok = jnp.argmax(last, axis=-1)[:, None]
    out = [tok]
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, lens + i)
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
