"""Serving entry points: prefill and single-token decode steps.

`serve_step` is what decode_32k / long_500k lower: one new token against a
pre-allocated KV/state cache at a traced position. Forward quantization
(RTN + 4/6) is deterministic, so serving needs no per-step randomness — the
seed below is a fixed constant feeding the (unused-in-inference) backward.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import lm

_SEED = jnp.array([7, 7], jnp.uint32)


def make_prefill_step(cfg, scheme: str):
    def prefill_step(params, cache, batch):
        logits, cache, _ = lm.forward(params, cfg, batch, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="prefill")
        return logits, cache
    return prefill_step


def make_serve_step(cfg, scheme: str):
    def serve_step(params, cache, tokens, pos):
        logits, cache, _ = lm.forward(params, cfg, {"tokens": tokens}, scheme,
                                      jnp.asarray(_SEED), caches=cache,
                                      mode="decode", pos=pos)
        return logits, cache
    return serve_step


def greedy_generate(params, cfg, scheme, prompt_tokens, max_new: int,
                    max_len: int | None = None):
    """Simple host-side generation loop (examples / tests)."""
    b, s = prompt_tokens.shape
    max_len = max_len or (s + max_new + 8)
    if cfg.enc_dec:
        raise NotImplementedError("use explicit enc-dec path in examples")
    cache = lm.init_cache(cfg, b, max_len)
    prefill = jax.jit(make_prefill_step(cfg, scheme))
    step = jax.jit(make_serve_step(cfg, scheme))
    logits, cache = prefill(params, cache, {"tokens": prompt_tokens})
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    out = [tok]
    for i in range(max_new - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
