"""Token sampling for the serving engine: greedy / temperature / top-k.

One batched, jittable kernel handles the whole slot batch with PER-SLOT
parameters (continuous batching mixes requests with different sampling
settings in one decode step): temperature == 0 selects greedy argmax for
that row; top_k == 0 disables the top-k filter. Stochastic rows use the
Gumbel-max trick, which keeps everything a single argmax — no categorical
resampling, no host sync.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (greedy by default)."""
    temperature: float = 0.0
    top_k: int = 0


def _topk_mask(lf: jax.Array, top_k: jax.Array) -> jax.Array:
    """Per-row top-k logit filter: entries below the k-th highest logit go to
    -inf; k == 0 keeps everything. lf (B, V) fp32, top_k (B,) int32."""
    b, v = lf.shape
    srt = jnp.sort(lf, axis=-1)[:, ::-1]                     # descending
    kidx = jnp.clip(jnp.where(top_k > 0, top_k, v) - 1, 0, v - 1)
    thresh = jnp.take_along_axis(srt, kidx[:, None], axis=-1)
    return jnp.where(lf >= thresh, lf, -jnp.inf)


def sampling_probs(logits: jax.Array, temperature: jax.Array,
                   top_k: jax.Array) -> jax.Array:
    """The engine's per-token sampling DISTRIBUTION q (B, V): softmax of the
    top-k-filtered logits at `temperature`. This is exactly the law the
    Gumbel-max trick in `sample_tokens` draws from on stochastic rows, so
    rejection-sampled speculation that preserves q token-by-token preserves
    the engine's sampling semantics. Only meaningful for temperature > 0."""
    lf = logits.astype(jnp.float32)
    masked = _topk_mask(lf, jnp.broadcast_to(jnp.asarray(top_k, jnp.int32),
                                             lf.shape[:1]))
    t = jnp.maximum(jnp.broadcast_to(
        jnp.asarray(temperature, jnp.float32), lf.shape[:1]), 1e-6)[:, None]
    return jax.nn.softmax(masked / t, axis=-1)


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, key: jax.Array) -> jax.Array:
    """logits (B, V) -> token ids (B,) under per-row sampling params.

    temperature (B,) float32: 0 => greedy argmax for that row.
    top_k (B,) int32: 0 => no filter; else keep the k highest-logit tokens.
    """
    lf = logits.astype(jnp.float32)
    masked = _topk_mask(lf, top_k)
    g = jax.random.gumbel(key, lf.shape, jnp.float32)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    stoch = masked / t + g
    z = jnp.where(temperature[:, None] > 0, stoch, lf)       # greedy rows
    return jnp.argmax(z, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------------------
# speculative acceptance (serve/spec_decode.py)
# --------------------------------------------------------------------------

def greedy_targets(logits: jax.Array) -> jax.Array:
    """Verify-chunk logits (B, S, V) -> greedy target ids (B, S).

    Chunk index j holds the model's prediction for position pos+j+1; the
    bf16 -> fp32 cast the greedy sampler applies is order-preserving, so
    argmax here selects exactly the token `sample_tokens` would at
    temperature 0."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def accept_greedy(drafts, targets) -> int:
    """Longest accepted prefix: count of leading j with draft_j == target_j.

    The emitted tokens for the round are targets[: accepted + 1] — the
    accepted drafts (which equal their targets) plus the model's own
    correction/bonus token, so the stream is exactly the full model's
    greedy output."""
    a = 0
    while a < len(drafts) and int(drafts[a]) == int(targets[a]):
        a += 1
    return a


def speculative_resample(draft_tokens, draft_logits, target_logits, key, *,
                         temperature=1.0, top_k=0):
    """Stochastic speculative acceptance: rejection-sample the drafts so the
    emitted stream preserves the target distribution EXACTLY.

    draft_tokens (K,) int32 — the proposals; draft_logits (K, V) the draft's
    logits for them, or None for a DETERMINISTIC draft (the engine's greedy
    truncated-stack proposals): a deterministic draft is a point mass, so
    the scheme degenerates to "accept d_j with prob q_j(d_j), else resample
    from q_j with d_j excluded" — still exactly q. target_logits (K+1, V) —
    row j is the full model's logits for the position draft j fills (the
    verify chunk's row layout); row K is the bonus position.

    Per position j: accept d_j with prob min(1, q_j(d)/p_j(d)). The first
    rejection at j emits one token from the renormalized residual
    max(q_j - p_j, 0) and stops; K acceptances emit a bonus token from
    q_K. Either way the round's tokens are distributed as the target model
    sampling one token at a time (Leviathan et al.'s guarantee), and —
    because q applies the SAME temperature/top-k transform as
    `sample_tokens` — as THIS engine's sampler specifically.

    Returns (tokens (K+1,) int32, count): tokens[:count] are the round's
    emissions (count-1 accepted drafts + the resample/bonus token).
    Deterministic given `key`, so stochastic streams are reproducible.
    """
    k = draft_tokens.shape[0]
    v = target_logits.shape[-1]
    q = sampling_probs(target_logits, temperature, top_k)      # (K+1, V)
    if draft_logits is None:
        p = jax.nn.one_hot(draft_tokens, v, dtype=jnp.float32)  # point mass
    else:
        p = sampling_probs(draft_logits, temperature, top_k)
    k_acc, k_fin = jax.random.split(jax.random.fold_in(key, 0))
    idx = jnp.arange(k)
    qd = q[idx, draft_tokens]
    pd = p[idx, draft_tokens]
    u = jax.random.uniform(k_acc, (k,), jnp.float32)
    accept = u * pd < qd                    # u < min(1, q/p), p-robust form
    a = jnp.where(jnp.all(accept), k, jnp.argmin(accept))  # first rejection
    # residual on rejection (guaranteed positive mass: rejection implies
    # q(d) < p(d) <= 1); bonus distribution q_K when everything was accepted
    resid = jnp.maximum(q[a] - p[a], 0.0)
    zmass = jnp.sum(resid)
    resid = resid / jnp.maximum(zmass, 1e-38)
    final_p = jnp.where(a == k, q[k], jnp.where(zmass > 0, resid, q[a]))
    final = jax.random.categorical(k_fin, jnp.log(final_p))
    base = jnp.concatenate([draft_tokens, jnp.zeros((1,), jnp.int32)])
    toks = jnp.where(jnp.arange(k + 1) == a, final, base).astype(jnp.int32)
    return toks, (a + 1).astype(jnp.int32)
