"""Token sampling for the serving engine: greedy / temperature / top-k.

One batched, jittable kernel handles the whole slot batch with PER-SLOT
parameters (continuous batching mixes requests with different sampling
settings in one decode step): temperature == 0 selects greedy argmax for
that row; top_k == 0 disables the top-k filter. Stochastic rows use the
Gumbel-max trick, which keeps everything a single argmax — no categorical
resampling, no host sync.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (greedy by default)."""
    temperature: float = 0.0
    top_k: int = 0


def sample_tokens(logits: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, key: jax.Array) -> jax.Array:
    """logits (B, V) -> token ids (B,) under per-row sampling params.

    temperature (B,) float32: 0 => greedy argmax for that row.
    top_k (B,) int32: 0 => no filter; else keep the k highest-logit tokens.
    """
    lf = logits.astype(jnp.float32)
    b, v = lf.shape
    # per-row top-k threshold (k == 0 -> keep everything)
    srt = jnp.sort(lf, axis=-1)[:, ::-1]                     # descending
    kidx = jnp.clip(jnp.where(top_k > 0, top_k, v) - 1, 0, v - 1)
    thresh = jnp.take_along_axis(srt, kidx[:, None], axis=-1)
    masked = jnp.where(lf >= thresh, lf, -jnp.inf)
    g = jax.random.gumbel(key, lf.shape, jnp.float32)
    t = jnp.maximum(temperature, 1e-6)[:, None]
    stoch = masked / t + g
    z = jnp.where(temperature[:, None] > 0, stoch, lf)       # greedy rows
    return jnp.argmax(z, axis=-1).astype(jnp.int32)


# --------------------------------------------------------------------------
# speculative acceptance (serve/spec_decode.py)
# --------------------------------------------------------------------------

def greedy_targets(logits: jax.Array) -> jax.Array:
    """Verify-chunk logits (B, S, V) -> greedy target ids (B, S).

    Chunk index j holds the model's prediction for position pos+j+1; the
    bf16 -> fp32 cast the greedy sampler applies is order-preserving, so
    argmax here selects exactly the token `sample_tokens` would at
    temperature 0."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def accept_greedy(drafts, targets) -> int:
    """Longest accepted prefix: count of leading j with draft_j == target_j.

    The emitted tokens for the round are targets[: accepted + 1] — the
    accepted drafts (which equal their targets) plus the model's own
    correction/bonus token, so the stream is exactly the full model's
    greedy output."""
    a = 0
    while a < len(drafts) and int(drafts[a]) == int(targets[a]):
        a += 1
    return a


def speculative_resample(draft_tokens, draft_logits, target_logits, key):
    """Rejection-sampling hook for stochastic speculative decoding.

    The standard scheme (accept d with prob min(1, p_target/p_draft), else
    resample from the renormalized residual) preserves the target
    distribution EXACTLY — and because this engine's forward is
    deterministic given the per-request key, even the stochastic stream
    would be reproducible. Not yet wired: the engine enforces greedy
    sampling when spec_k > 0 and routes stochastic requests here."""
    raise NotImplementedError(
        "stochastic speculative acceptance is not implemented; use "
        "temperature=0 (greedy) with spec_k > 0")
