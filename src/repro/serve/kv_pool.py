"""Block-based paged KV pool with per-sequence block tables.

Serving memory is dominated by decode caches. The pool carves each cache kind
into fixed-size blocks of `block_size` token positions and hands blocks to
sequences on demand (vLLM-style PagedAttention layout, adapted to the stacked
stage pytrees of models/lm.py):

  token kinds  ("kv", "mla"):      pool leaves (layers, n_blocks, block, ...)
  state kinds  ("wkv", "tm_prev",
                "cm_prev", "lru"): slot leaves (layers, n_slots, ...)
                                   (recurrent state is O(1) per sequence —
                                   one implicit "block" per slot)

A per-slot block table (n_slots, max_blocks) maps logical block index ->
physical pool block; unallocated entries hold the OOB sentinel `n_blocks`,
so device-side writes through them are DROPPED by the scatter and gathers
read zeros (`mode="fill"`). That single convention gives free write-masking
for inactive slots and positions beyond a sequence's allocation. (OOB-HIGH,
never -1: negative scatter indices WRAP numpy-style — docs/CONVENTIONS.md.)

With `n_shards > 1` the allocator is SLOT-AFFINE over a mesh "data" axis
(serve/engine.py multi-host mode): slots and physical blocks are both split
into `n_shards` contiguous ranges, and a slot only ever receives blocks
homed on its own shard (per-shard free lists). Every device-side index a
slot's table row can carry therefore resolves inside that slot's shard of
the pool, which is what lets the engine run the decode step under a manual
`shard_map` over "data" with NO cross-shard pool traffic — the gather /
scatter that a generically data-sharded pool plus replicated table turns
into a full pool all-gather per step (priced by launch/dryrun decode cells)
stays shard-local. `table_device()` then emits SHARD-LOCAL physical indices
(global id minus the slot's shard base; sentinel -> blocks_per_shard), so
the same gather/scatter primitives work unchanged on the shard-local leaves
shard_map hands them.

Blocks are REFCOUNTED: a physical block may back the same logical prefix of
several sequences at once (serve/prefix_cache.py aliases a cached prefix's
blocks read-only into a new slot's table — `adopt_prefix` — and copies the
first divergent / partial tail block privately — `cow_block`). A block
returns to the free list only when its last reference drops (`_decref`);
`truncate` is logical-only and never frees, so speculative rollback can
never free a block another slot still references. Aliased table entries are
masked out of the WRITE view (`tables_device` stacks a read table and a
write table whose shared-prefix entries hold the sentinel), so a scatter
through them provably drops — docs/CONVENTIONS.md §5.

The device-side primitives (`gather_view` / `scatter_tokens`) are called
from the mixer decode paths (models/attention.py, models/mla.py); the
`KVPool` class is the host-side allocator driven by the engine scheduler.

With `paged=False` the pool builds dense per-slot caches (n_slots, max_len,
...) instead — same masking conventions, bit-identical attention arithmetic —
used as the reference layout in tests and by the legacy greedy loop.

With `quantized=True` (paged only) every token-kind leaf is stored as NVFP4
`PackedKV` bytes instead of bf16 — packed e2m1 codes + e4m3 group scales,
0.28125x the HBM bytes — quantized per token at scatter time with
deterministic RTN and dequantized either in the Pallas flash-decode kernel
(kernels/paged_attention.py `*_q` entry points) or exactly in bf16 on the
gather path. The bf16 pool remains the bitwise reference mode; quantized
pools trade bit-exactness for bandwidth under an MSE-tested rounding scheme
(docs/CONVENTIONS.md §7, serve/README.md "Quantized KV cache").
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import formats as F
from repro.models import griffin as G
from repro.models import lm

TOKEN_KINDS = ("kv", "mla")
STATE_KINDS = ("wkv", "tm_prev", "cm_prev", "lru")
_TOKEN_MIXERS = ("gqa", "lattn", "mla")


class PackedKV(NamedTuple):
    """One NVFP4-quantized token-kind pool leaf (`KVPool(quantized=True)`).

    Two uint8 arrays sharing the leading (pool block, block offset) axes of
    the bf16 leaf they replace: e2m1 codes packed two per byte over the
    LAST feature axis (`..., d/2`) and e4m3 group scales stored as raw bits
    (`..., d/16`) — 0.5625 bytes per cached element vs 2 for bf16.

    A NamedTuple, hence a pytree: `jax.tree.map` descends into both leaves,
    so the jitted block copy (`KVPool._copy_block_device`), the shard_map
    in_specs of serve/decode.py, and `init_cache`'s stage broadcast all
    handle codes and scales together with no special casing — a COW copy
    moves a packed block atomically because both leaves sit in one jitted
    `jax.tree.map`. Quantization is per-token deterministic RTN
    (`core/formats.py:nvfp4_cache_encode`): a token's packed bytes are a
    pure function of its bf16 value, so a block is immutable packed bytes
    once its positions are written, and prefix-cache aliasing / COW reuse
    packed bytes bit-for-bit (hot == cold, docs/CONVENTIONS.md §7).
    """

    codes: jax.Array   # uint8, (..., d // 2): packed e2m1 pairs
    scales: jax.Array  # uint8, (..., d // GROUP): e4m3 scale bits


def reclaim_window(cfg: ArchConfig, specs=None) -> int | None:
    """Sliding window W when EVERY token-cache layer in `specs` is `lattn`.

    One block table serves every layer, so a block may only return to the
    free list mid-sequence when NO layer can ever read it again — true
    exactly when all token-cache mixers share the same sliding window
    (recurrent kinds keep O(1) slot state and own no blocks). Mixed stacks
    (any full-attention gqa/mla layer) return None: those layers attend the
    whole prefix forever."""
    specs = specs if specs is not None else lm.layer_specs(cfg)
    mixers = {m for pattern, _ in specs for m, _ in pattern
              if m in _TOKEN_MIXERS}
    if mixers == {"lattn"} and cfg.griffin is not None:
        return cfg.griffin.window
    return None


# --------------------------------------------------------------------------
# device-side primitives (used inside the jitted decode step)
# --------------------------------------------------------------------------

def gather_view(pool, table: jax.Array) -> jax.Array:
    """Materialize per-sequence logical views from the pool.

    pool: (P, BS, ...); table: (B, MAXB) with OOB sentinel for unallocated.
    Returns (B, MAXB*BS, ...): each row's blocks in logical order, zeros for
    unallocated blocks (always masked downstream — attention only admits
    key positions <= the row's current position).

    A `PackedKV` pool gathers both packed leaves and DEQUANTIZES to bf16
    (exact: e2m1 x e4m3 products fit bf16), so the dense/gather attention
    path consumes quantized pools with no mixer changes; unallocated blocks
    decode to exactly 0.0 (zero code x zero scale), preserving the fill
    convention.
    """
    if isinstance(pool, PackedKV):
        return F.nvfp4_cache_decode(gather_view(pool.codes, table),
                                    gather_view(pool.scales, table))
    v = pool.at[table].get(mode="fill", fill_value=0)
    b, mb = table.shape
    return v.reshape(b, mb * pool.shape[1], *pool.shape[2:])


def split_tables(block_table: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Resolve a block-table argument into (read_table, write_table).

    A plain (B, MAXB) table is its own write view (the pre-prefix-cache
    layout: dryrun lowering, the speculative draft pool). A stacked
    (B, 2, MAXB) table — `KVPool.tables_device()` — carries a distinct
    write view whose ALIASED-prefix entries hold the OOB sentinel, so
    scatters through shared (refcount > 1) blocks drop by construction
    while gathers still read them."""
    if block_table.ndim == 3:
        return block_table[:, 0], block_table[:, 1]
    return block_table, block_table


def scatter_tokens(pool, table: jax.Array, positions: jax.Array,
                   vals: jax.Array, valid: jax.Array):
    """Write per-token values through the block table.

    positions: (B, S) absolute token positions; vals: (B, S, ...);
    valid: (B, S) bool — rows/positions with valid=False (inactive slots,
    out-of-range positions) are routed to the OOB sentinel and dropped.
    NEGATIVE positions are folded into `valid` here: the block lookup
    clips them to 0, so a caller passing valid=True for a not-yet-started
    row (position -1) would otherwise silently corrupt block 0 / offset 0
    — bad positions must route to the sentinel like every other invalid
    write, whatever the caller's mask says.

    A `PackedKV` pool quantizes per token (NVFP4 deterministic RTN over the
    last feature axis) and scatters codes and scale bits through the same
    block/offset indices — per-token groups make each position's packed
    bytes independent, so no block-level staging is needed and a block is
    immutable packed bytes as soon as its positions are written.
    """
    if isinstance(pool, PackedKV):
        codes, scales = F.nvfp4_cache_encode(vals)
        return PackedKV(
            scatter_tokens(pool.codes, table, positions, codes, valid),
            scatter_tokens(pool.scales, table, positions, scales, valid))
    n_blocks, bs = pool.shape[0], pool.shape[1]
    b = table.shape[0]
    valid = valid & (positions >= 0)
    logical = jnp.clip(positions, 0) // bs
    blk = table.at[jnp.arange(b)[:, None], logical].get(
        mode="fill", fill_value=n_blocks)
    blk = jnp.where(valid, blk, n_blocks)  # OOB => scatter drops
    off = jnp.clip(positions, 0) % bs
    return pool.at[blk, off].set(vals.astype(pool.dtype), mode="drop")


# --------------------------------------------------------------------------
# cache construction (stage-aligned, mirrors lm.init_cache layouts)
# --------------------------------------------------------------------------

def _layer_cache(spec, cfg: ArchConfig, n_slots: int, max_len: int, *,
                 paged: bool, n_blocks: int, block_size: int,
                 quantized: bool = False):
    mixer, ff = spec
    hd = cfg.hd
    c: dict[str, Any] = {}

    def tok(*feat):
        if paged and quantized:
            d = feat[-1]
            if d % F.GROUP:
                raise ValueError(
                    f"quantized KV pool needs feature dims divisible by "
                    f"{F.GROUP} (got {d} for mixer '{mixer}'): NVFP4 groups "
                    "lie along the last cache axis")
            # zero codes x zero scale bits decode to exactly 0.0, matching
            # the bf16 pool's zero init / gather-fill convention
            return PackedKV(
                jnp.zeros((n_blocks, block_size, *feat[:-1], d // 2),
                          jnp.uint8),
                jnp.zeros((n_blocks, block_size, *feat[:-1], d // F.GROUP),
                          jnp.uint8))
        if paged:
            return jnp.zeros((n_blocks, block_size, *feat), jnp.bfloat16)
        # dense serving cache: full max_len capacity for every kind — the
        # sliding-window ring optimization is a paged-pool follow-on, and a
        # uniform layout keeps dense/paged outputs bit-comparable.
        return jnp.zeros((n_slots, max_len, *feat), jnp.bfloat16)

    if mixer in ("gqa", "lattn"):
        c["kv"] = (tok(cfg.n_kv_heads, hd), tok(cfg.n_kv_heads, hd))
    elif mixer == "mla":
        m = cfg.mla
        c["mla"] = (tok(m.kv_lora_rank), tok(m.qk_rope_head_dim))
    elif mixer == "rwkv_tm":
        h = cfg.d_model // cfg.rwkv.head_dim
        c["wkv"] = jnp.zeros((n_slots, h, cfg.rwkv.head_dim, cfg.rwkv.head_dim),
                             jnp.float32)
        c["tm_prev"] = jnp.zeros((n_slots, 1, cfg.d_model), jnp.bfloat16)
    elif mixer == "rec":
        c["lru"] = G.recurrent_state_init(cfg, n_slots)
    if ff == "rwkv_cm":
        c["cm_prev"] = jnp.zeros((n_slots, 1, cfg.d_model), jnp.bfloat16)
    return c


def init_cache(cfg: ArchConfig, n_slots: int, max_len: int, *, paged: bool,
               n_blocks: int, block_size: int, specs=None,
               quantized: bool = False):
    """Stage-aligned serving cache pytree (pool layout when paged).

    `specs` overrides lm.layer_specs(cfg) — used by the speculative DRAFT
    pool, whose cache covers only lm.prefix_specs(cfg, draft_layers).
    `quantized` stores token kinds as NVFP4 `PackedKV` leaves."""
    stages = []
    for pattern, count in (specs if specs is not None else lm.layer_specs(cfg)):
        one = {f"l{i}": _layer_cache(pattern[i], cfg, n_slots, max_len,
                                     paged=paged, n_blocks=n_blocks,
                                     block_size=block_size,
                                     quantized=quantized)
               for i in range(len(pattern))}
        stages.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (count, *x.shape)), one))
    return stages


def _map_token_kinds(caches, fn):
    """Apply fn to every token-kind leaf (kv / mla pool arrays)."""
    out = []
    for stage in caches:
        ns = {}
        for lk, kinds in stage.items():
            ns[lk] = {k: (jax.tree.map(fn, v) if k in TOKEN_KINDS else v)
                      for k, v in kinds.items()}
        out.append(ns)
    return out


def _map_state_kinds(caches, fn):
    """Apply fn to every state-kind entry (list[stage] -> dict[l] -> kinds)."""
    out = []
    for stage in caches:
        ns = {}
        for lk, kinds in stage.items():
            ns[lk] = {k: (jax.tree.map(fn, v) if k in STATE_KINDS else v)
                      for k, v in kinds.items()}
        out.append(ns)
    return out


# --------------------------------------------------------------------------
# host-side allocator
# --------------------------------------------------------------------------

class OutOfBlocks(RuntimeError):
    pass


class SlotError(RuntimeError):
    """Allocator misuse: double-free, or operating on an unbound slot."""


class KVPool:
    """Host-side block allocator + owner of the device cache pytree.

    Slot lifecycle: `reset_slot(slot)` (zero recurrent state of an UNBOUND
    slot) -> `commit(slot, total)` (bind + reserve growth) -> `ensure(slot,
    n)` before each forward so every position < n has a backing block ->
    optionally `truncate(slot, n)` (speculative rollback: logical shrink,
    no block churn) -> `release(slot)` (unbind; blocks return to the free
    list). Misuse — releasing an unbound slot (double-free), committing a
    bound slot, ensure/truncate outside a binding — raises SlotError rather
    than silently corrupting the free-list accounting. Token blocks are
    never zeroed: stale values sit behind the position mask.

    Pure sliding-window stacks (`reclaim_window`) additionally free blocks
    mid-sequence once they fall out of every future query's window (`ensure`
    runs `_reclaim` before growing), keeping live blocks O(window) per slot;
    a truncate below the reclaim floor raises SlotError because the rolled-
    back window would need keys that no longer exist.

    `n_shards > 1` makes allocation SLOT-AFFINE for mesh-sharded serving:
    shard s owns slots [s*n_slots/S, (s+1)*n_slots/S) and physical blocks
    [s*n_blocks/S, (s+1)*n_blocks/S), each shard runs its own free list, and
    a slot allocates exclusively from its shard. Admission becomes per-shard
    (`can_admit(..., slot=i)`) — one hot shard can be full while another has
    room. Single-shard behavior (the default) is bit-for-bit unchanged.
    """

    def __init__(self, cfg: ArchConfig, n_slots: int, max_len: int, *,
                 paged: bool = True, block_size: int = 16,
                 n_blocks: int | None = None, specs=None, n_shards: int = 1,
                 quantized: bool = False):
        assert max_len % block_size == 0, \
            f"max_len {max_len} must be a multiple of block_size {block_size}"
        if quantized and not paged:
            raise ValueError(
                "quantized=True requires paged=True: the NVFP4 cache format "
                "is a property of pool blocks (dense mode is the bitwise "
                "reference layout and stays bf16)")
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.paged = paged
        self.quantized = quantized
        self.block_size = block_size
        self.max_blocks = max_len // block_size
        if n_blocks is None:
            n_blocks = n_slots * self.max_blocks
        if n_shards < 1 or n_slots % n_shards or n_blocks % n_shards:
            raise ValueError(
                f"n_shards={n_shards} must divide both n_slots={n_slots} and "
                f"n_blocks={n_blocks} (equal shard extents are what keep the "
                "shard_map slot split aligned with the block homes)")
        self.n_blocks = n_blocks
        self.sentinel = n_blocks
        self.n_shards = n_shards
        self.slots_per_shard = n_slots // n_shards
        self.blocks_per_shard = n_blocks // n_shards
        self.specs = specs if specs is not None else lm.layer_specs(cfg)
        self.caches = init_cache(cfg, n_slots, max_len, paged=paged,
                                 n_blocks=n_blocks, block_size=block_size,
                                 specs=self.specs, quantized=quantized)
        self.has_state_kinds = any(
            mixer in ("rwkv_tm", "rec") or ff == "rwkv_cm"
            for pattern, _ in self.specs for mixer, ff in pattern)
        self._table = np.full((n_slots, self.max_blocks), self.sentinel,
                              np.int32)
        # per-shard free lists; pop() -> the shard's lowest block id first
        # (n_shards=1: one list over all blocks, the original behavior)
        bps = self.blocks_per_shard
        self._frees: list[list[int]] = [
            list(range((s + 1) * bps - 1, s * bps - 1, -1))
            for s in range(n_shards)]
        self._owned: list[list[int]] = [[] for _ in range(n_slots)]
        self._committed = [0] * n_slots  # reserved blocks per admitted seq
        self._bound = [False] * n_slots  # slot currently holds a sequence
        self._lengths = [0] * n_slots    # logical tokens backed per slot
        # per-block reference counts: slot table rows referencing the block
        # plus at most one prefix-cache hold (serve/prefix_cache.py). A block
        # is free iff its refcount is 0; _decref is the ONLY path back to the
        # free list, so a shared block can never be double-freed.
        self._ref = np.zeros(n_blocks, np.int32)
        # leading logical blocks of each slot that are ALIASED (read-only):
        # the write view of tables_device() masks them with the sentinel
        self._shared_upto = [0] * n_slots
        # set by the prefix cache: callable(shard, need) -> blocks freed into
        # that shard's list by evicting unpinned cached prefixes
        self.evict_hook = None
        # observability hook (obs/instrumentation.py Instrumentation), set
        # by the engine when EngineConfig(obs=...) is enabled; None costs
        # one `is not None` test per allocation event
        self.obs = None
        self._table_dev = None
        self._tables_dev = None
        self._copy_fn = None
        self._overflow_fn = None
        self._read_block_fn = None
        self._write_block_fn = None
        # sliding-window reclamation (pure-lattn stacks, paged mode only):
        # blocks whose newest key predates every future query's window go
        # back to the free list mid-sequence, so live blocks per slot stay
        # O(window) instead of O(sequence length)
        self.window = reclaim_window(cfg, self.specs) if paged else None
        self._alloc_upto = [0] * n_slots   # logical blocks ever allocated
        self._live_from = [0] * n_slots    # first logical block still owned
        self._floor = [0] * n_slots        # min sound truncate target

    # ---- block accounting ----

    def shard_of_slot(self, slot: int) -> int:
        """Mesh-"data" shard homing `slot` (contiguous split, matching how
        shard_map splits the leading slot/block axes of the device arrays)."""
        return slot // self.slots_per_shard

    def shard_of_block(self, block: int) -> int:
        return block // self.blocks_per_shard

    @property
    def _free(self) -> list[int]:
        """Flat view of every free block (invariant checks / introspection).

        Allocation goes through the per-shard `_frees` lists; this view keeps
        single-shard callers and the property-test suite working unchanged."""
        return [b for shard in self._frees for b in shard]

    @property
    def free_block_count(self) -> int:
        return sum(len(shard) for shard in self._frees)

    def free_blocks_in_shard(self, shard: int) -> int:
        return len(self._frees[shard])

    def utilization(self) -> dict:
        """Host-side occupancy snapshot for the per-tick gauges
        (obs/instrumentation.py): free blocks per shard, allocated blocks,
        and internal fragmentation — token capacity sitting in allocated
        blocks that no live position occupies (partial tail blocks plus
        window-reclaim slack). Dense pools report zero blocks."""
        if not self.paged:
            return {"free_by_shard": [0] * self.n_shards,
                    "allocated_blocks": 0, "frag_tokens": 0,
                    "frag_ratio": 0.0}
        free = [len(f) for f in self._frees]
        cap = live = 0
        for i in range(self.n_slots):
            if not self._bound[i]:
                continue
            cap += len(self._owned[i]) * self.block_size
            live += self._lengths[i] - self._live_from[i] * self.block_size
        frag = max(cap - live, 0)
        return {"free_by_shard": free,
                "allocated_blocks": self.n_blocks - sum(free),
                "frag_tokens": frag,
                "frag_ratio": frag / cap if cap else 0.0}

    def effective_free_blocks(self, shard: int) -> int:
        """Free blocks of `shard` minus outstanding commitments of its
        admitted slots — the capacity a NEW request could actually draw on.
        The engine's shard-occupancy placement ranks shards by this, so a
        freshly committed (not yet allocated) sequence already steers the
        next admission elsewhere."""
        if self.n_shards == 1:
            shard_slots = range(self.n_slots)
        else:
            shard_slots = range(shard * self.slots_per_shard,
                                (shard + 1) * self.slots_per_shard)
        outstanding = sum(self._committed[i] - len(self._owned[i])
                          for i in shard_slots)
        return len(self._frees[shard]) - outstanding

    def blocks_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.block_size)

    def max_live_blocks(self, total_tokens: int,
                        max_growth: int | None = None) -> int:
        """Most blocks a sequence of total_tokens can own SIMULTANEOUSLY.

        Without a reclaim window this is just blocks_for(total). With one,
        `ensure` reclaims before every growth step, so — provided no single
        ensure grows a slot by more than `max_growth` tokens — a slot spans
        at most window + one growth chunk of live positions (plus block-
        granularity slack at both ends). This is what makes long sequences
        admissible to pools far smaller than blocks_for(total): the whole
        point of mid-sequence reclamation."""
        need = self.blocks_for(total_tokens)
        if self.window is None or max_growth is None:
            return need
        return min(need, self.blocks_for(self.window + max_growth) + 2)

    def can_ever_admit(self, total_tokens: int,
                       max_growth: int | None = None) -> bool:
        """Is a sequence of total_tokens servable by this pool at all?

        Slot-affine pools bound a single sequence by ONE SHARD's blocks — a
        slot can never borrow from another shard's free list."""
        if total_tokens > self.max_len:
            return False
        return (not self.paged) or (
            self.max_live_blocks(total_tokens, max_growth)
            <= self.blocks_per_shard)

    def can_admit(self, total_tokens: int, max_growth: int | None = None,
                  slot: int | None = None, cached_blocks: int = 0) -> bool:
        """Admission check: can a sequence of total_tokens be fully served
        alongside every already-admitted sequence?

        Blocks are allocated lazily (`ensure`), so the check subtracts the
        outstanding COMMITMENTS of admitted sequences (reserved via
        `commit`, not yet allocated) — otherwise two growing sequences could
        both pass admission and later exhaust the pool mid-decode. With
        `n_shards > 1` pass the candidate `slot`: only its shard's free
        blocks and commitments count (slot affinity makes shards independent
        allocators). `cached_blocks` — prefix-cache blocks the candidate
        would ADOPT rather than allocate (they are already resident, outside
        the free list) — reduces its demand on the free list."""
        if total_tokens > self.max_len:
            return False
        if not self.paged:
            return True
        if self.n_shards > 1 and slot is None:
            # no target slot: "can ANY shard take it" — never whole-pool
            # accounting, which would over-admit (global free blocks can
            # span shards no single slot may draw from)
            return any(self.can_admit(total_tokens, max_growth,
                                      slot=sh * self.slots_per_shard)
                       for sh in range(self.n_shards))
        if self.n_shards == 1:
            shard_slots = range(self.n_slots)
            free = self.free_block_count
        else:
            sh = self.shard_of_slot(slot)
            shard_slots = range(sh * self.slots_per_shard,
                                (sh + 1) * self.slots_per_shard)
            free = self.free_blocks_in_shard(sh)
        outstanding = sum(self._committed[i] - len(self._owned[i])
                          for i in shard_slots)
        need = max(0, self.max_live_blocks(total_tokens, max_growth)
                   - cached_blocks)
        return free - outstanding >= need

    def admission_shortfall(self, total_tokens: int,
                            max_growth: int | None = None,
                            slot: int | None = None,
                            cached_blocks: int = 0) -> int:
        """Free blocks MISSING for `can_admit` to pass on `slot`'s shard
        (0 when it already passes) — what the engine asks the prefix cache
        to evict before admitting."""
        if not self.paged or total_tokens > self.max_len:
            return 0
        if self.n_shards == 1:
            shard_slots = range(self.n_slots)
            free = self.free_block_count
        else:
            sh = self.shard_of_slot(slot)
            shard_slots = range(sh * self.slots_per_shard,
                                (sh + 1) * self.slots_per_shard)
            free = self.free_blocks_in_shard(sh)
        outstanding = sum(self._committed[i] - len(self._owned[i])
                          for i in shard_slots)
        need = max(0, self.max_live_blocks(total_tokens, max_growth)
                   - cached_blocks)
        return max(0, need - (free - outstanding))

    def commit(self, slot: int, total_tokens: int,
               max_growth: int | None = None) -> None:
        """Bind `slot` and reserve (without allocating) its growth blocks.

        `max_growth` — the caller's bound on tokens added per `ensure`
        (the engine's max(prefill_chunk, spec_k + 1)) — caps the
        reservation of window-reclaimed slots at their live-block bound."""
        if self._bound[slot]:
            raise SlotError(f"slot {slot}: commit on a bound slot "
                            "(release it first)")
        if total_tokens > self.max_len:
            raise OutOfBlocks(f"slot {slot}: {total_tokens} > max_len")
        self._bound[slot] = True
        self._committed[slot] = self.max_live_blocks(total_tokens, max_growth)

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Allocate blocks so positions [0, n_tokens) of `slot` are backed."""
        if not self._bound[slot]:
            raise SlotError(f"slot {slot}: ensure on an unbound slot")
        if not self.paged:
            if n_tokens > self.max_len:
                raise OutOfBlocks(f"slot {slot}: {n_tokens} > max_len")
            self._lengths[slot] = max(self._lengths[slot], n_tokens)
            return
        need = self.blocks_for(n_tokens)
        owned = self._owned[slot]
        if need > self.max_blocks:
            raise OutOfBlocks(f"slot {slot}: {n_tokens} tokens exceed the "
                              f"{self.max_blocks}-entry block table")
        if self.window is not None:
            self._reclaim(slot)
        sh = self.shard_of_slot(slot)
        free = self._frees[sh]
        taken = 0
        while self._alloc_upto[slot] < need:
            if not free and not (self.evict_hook is not None
                                 and self.evict_hook(sh, 1) > 0):
                raise OutOfBlocks(
                    f"slot {slot}: pool exhausted"
                    + (f" (shard {sh})" if self.n_shards > 1 else ""))
            blk = free.pop()
            self._ref[blk] = 1
            self._table[slot, self._alloc_upto[slot]] = blk
            owned.append(blk)
            self._alloc_upto[slot] += 1
            taken += 1
            self._dirty()
        if taken and self.obs is not None:
            self.obs.on_pool_alloc(taken)
        self._lengths[slot] = max(self._lengths[slot], n_tokens)

    def _reclaim(self, slot: int) -> None:
        """Return out-of-window blocks of `slot` to the free list.

        Called from `ensure` BEFORE growth, so the basis length is the
        committed prefix: every future query sits at qpos >= cur (truncate
        back below the in-flight chunk lands at >= cur too — spec rollback
        targets the pre-ensure length). Block j (keys [j*BS, (j+1)*BS)) is
        dead once its newest key leaves the oldest such query's window:
        (j+1)*BS - 1 <= cur - window. Freed table entries become the OOB
        sentinel — gathers read zeros and the kernel skips them, both
        behind the window mask, so paged output stays bit-identical."""
        cur = self._lengths[slot]
        first_live = min(max(0, (cur + 1 - self.window) // self.block_size),
                         self._alloc_upto[slot])
        if first_live <= self._live_from[slot]:
            return
        for j in range(self._live_from[slot], first_live):
            blk = int(self._table[slot, j])
            self._table[slot, j] = self.sentinel
            self._owned[slot].remove(blk)
            self._decref(blk)
        if self.obs is not None:
            self.obs.on_pool_reclaim(first_live - self._live_from[slot])
        self._live_from[slot] = first_live
        self._dirty()
        # freed keys end at first_live*BS - 1; a truncate to n keeps windows
        # sound only while n - window >= that newest freed key
        self._floor[slot] = first_live * self.block_size + self.window - 1

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Logically shrink `slot` to n_tokens positions (spec rollback).

        Rejected draft tokens are dropped WITHOUT block churn: the slot keeps
        every block it owns (the very next rounds grow back into them), and
        stale values past n_tokens stay invisible behind the position mask
        until overwritten. Only the logical length moves."""
        if not self._bound[slot]:
            raise SlotError(f"slot {slot}: truncate on an unbound slot")
        if n_tokens < 0 or n_tokens > self._lengths[slot]:
            raise SlotError(
                f"slot {slot}: truncate to {n_tokens} outside "
                f"[0, {self._lengths[slot]}]")
        if n_tokens < self._floor[slot]:
            # sliding-window reclamation already freed keys the rolled-back
            # window would need; allowing this would silently read zeros
            raise SlotError(
                f"slot {slot}: truncate to {n_tokens} below the "
                f"window-reclaim floor {self._floor[slot]}")
        self._lengths[slot] = n_tokens

    def length(self, slot: int) -> int:
        """Logical backed length of `slot` (ensure grows it, truncate cuts)."""
        return self._lengths[slot]

    def release(self, slot: int) -> None:
        """Unbind `slot`, dropping its block references.

        Exclusively-owned blocks (refcount 1) return to the free list;
        blocks the prefix cache (or another slot) still references merely
        lose this slot's reference — never a double free."""
        if not self._bound[slot]:
            raise SlotError(f"slot {slot}: release on an unbound slot "
                            "(double-free?)")
        self._bound[slot] = False
        self._committed[slot] = 0
        self._lengths[slot] = 0
        if not self.paged:
            return
        blocks = self._owned[slot]
        if blocks:
            # slot affinity: every owned block homes on the slot's shard;
            # reversed so an exclusive slot's blocks re-enter the free list
            # in the pre-refcount order (first-allocated pops first)
            for blk in reversed(blocks):
                self._decref(blk)
            self._owned[slot] = []
        if self._alloc_upto[slot]:
            self._table[slot, :] = self.sentinel
            self._dirty()
        self._alloc_upto[slot] = 0
        self._live_from[slot] = 0
        self._floor[slot] = 0
        self._shared_upto[slot] = 0

    def _dirty(self) -> None:
        """Invalidate the cached device tables after any host-table edit."""
        self._table_dev = None
        self._tables_dev = None

    def _local_table_np(self) -> np.ndarray:
        """Host copy of the device-facing table (shard-local when sharded).

        Slot-affine pools emit SHARD-LOCAL physical indices: the decode step
        runs under a manual shard_map over "data", so each shard's rows must
        index its own (n_blocks/S)-block slice of the pool. Real entries
        subtract the slot's shard base; sentinels map to the LOCAL sentinel
        `blocks_per_shard` (still OOB-high for the local leaves — scatter
        drops, gathers fill zeros, exactly as in the single-shard layout)."""
        if self.n_shards == 1:
            return self._table.copy()
        base = (np.arange(self.n_slots, dtype=np.int32)
                // self.slots_per_shard)[:, None] * self.blocks_per_shard
        return np.where(self._table == self.sentinel,
                        self.blocks_per_shard,
                        self._table - base).astype(np.int32)

    @property
    def local_sentinel(self) -> int:
        return self.blocks_per_shard if self.n_shards > 1 else self.sentinel

    def table_device(self):
        """Device copy of the block table (None in dense mode)."""
        if not self.paged:
            return None
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self._local_table_np())
        return self._table_dev

    def tables_device(self):
        """Stacked (n_slots, 2, max_blocks) device tables (None when dense):
        [:, 0] the READ table, [:, 1] the WRITE table, in which every
        ALIASED logical block (`adopt_prefix`) holds the sentinel. The decode
        step scatters through the write view only (`split_tables` in the
        mixers), so shared prefix blocks are provably never written — the
        masking is in the data, not in a host-side argument the compiled
        step could ignore."""
        if not self.paged:
            return None
        if self._tables_dev is None:
            rt = self._local_table_np()
            wt = rt.copy()
            for s, k in enumerate(self._shared_upto):
                if k:
                    wt[s, :k] = self.local_sentinel
            self._tables_dev = jnp.asarray(
                np.stack([rt, wt], axis=1).astype(np.int32))
        return self._tables_dev

    # ---- prefix sharing (refcounted aliasing + copy-on-write) ------------

    def _decref(self, block: int) -> None:
        """Drop one reference; the last reference frees the block."""
        self._ref[block] -= 1
        if self._ref[block] < 0:
            raise SlotError(f"block {block}: decref below zero (double free)")
        if self._ref[block] == 0:
            self._frees[self.shard_of_block(block)].append(block)
            if self.obs is not None:
                self.obs.on_pool_free(1)

    def incref(self, block: int) -> None:
        """Add an external (prefix-cache) hold on an allocated block."""
        if self._ref[block] <= 0:
            raise SlotError(f"block {block}: incref on a free block")
        self._ref[block] += 1

    def refcount(self, block: int) -> int:
        return int(self._ref[block])

    def adopt_prefix(self, slot: int, blocks: list[int],
                     n_tokens: int) -> None:
        """Alias cached `blocks` READ-ONLY as `slot`'s logical prefix.

        The slot's table rows [0, len(blocks)) point at the shared physical
        blocks (each incref'd); the write view of `tables_device()` masks
        them with the sentinel, so the slot can gather the cached K/V but
        any scatter targeting those logical blocks drops. Only valid on a
        freshly committed slot (no blocks yet), with every block homed on
        the slot's shard (the slot-affine invariant the sharded decode step
        rests on), and only for unwindowed pools (a reclaimed prefix is not
        fully resident, so sharing it would read zeros)."""
        if not self.paged:
            raise SlotError("adopt_prefix on a dense pool: no block table")
        if not self._bound[slot]:
            raise SlotError(f"slot {slot}: adopt_prefix on an unbound slot")
        if self._owned[slot]:
            raise SlotError(f"slot {slot}: adopt_prefix after allocation")
        if self.window is not None:
            raise SlotError("adopt_prefix on a sliding-window pool: "
                            "reclaimed prefixes are not fully resident")
        if n_tokens > len(blocks) * self.block_size:
            raise SlotError(f"slot {slot}: {n_tokens} tokens exceed "
                            f"{len(blocks)} adopted blocks")
        sh = self.shard_of_slot(slot)
        if any(self.shard_of_block(b) != sh for b in blocks):
            raise SlotError(
                f"slot {slot} (shard {sh}): adopting off-shard blocks "
                "violates slot affinity")
        for j, blk in enumerate(blocks):
            self.incref(blk)
            self._table[slot, j] = blk
            self._owned[slot].append(blk)
        self._alloc_upto[slot] = len(blocks)
        self._shared_upto[slot] = len(blocks)
        self._lengths[slot] = max(self._lengths[slot], n_tokens)
        self._dirty()

    def cow_block(self, slot: int, src: int) -> int:
        """Copy-on-write: append a PRIVATE copy of block `src` as `slot`'s
        next logical block (the first divergent token or a partial tail
        falls inside a cached block: its contents up to the divergence are
        reused bit-for-bit, the rest is stale-behind-the-position-mask and
        overwritten by subsequent scatters). Returns the new block id."""
        if not self.paged:
            raise SlotError("cow_block on a dense pool: no block table")
        if not self._bound[slot]:
            raise SlotError(f"slot {slot}: cow_block on an unbound slot")
        sh = self.shard_of_slot(slot)
        if self.shard_of_block(src) != sh:
            raise SlotError(f"slot {slot} (shard {sh}): COW source {src} "
                            "homes on another shard")
        if self._ref[src] <= 0:
            raise SlotError(f"block {src}: COW from a free block")
        j = self._alloc_upto[slot]
        if j >= self.max_blocks:
            # checked BEFORE popping: a pop-then-raise would strand the
            # popped block at refcount 1 with no owner (unreachable leak)
            raise OutOfBlocks(f"slot {slot}: table full at COW")
        free = self._frees[sh]
        if not free and not (self.evict_hook is not None
                             and self.evict_hook(sh, 1) > 0):
            raise OutOfBlocks(f"slot {slot}: no free block for COW"
                              + (f" (shard {sh})" if self.n_shards > 1
                                 else ""))
        dst = free.pop()
        self._ref[dst] = 1
        self._table[slot, j] = dst
        self._owned[slot].append(dst)
        self._alloc_upto[slot] = j + 1
        self._copy_block_device(src, dst)
        self._dirty()
        if self.obs is not None:
            self.obs.on_pool_alloc(1)
            self.obs.on_pool_cow()
        return dst

    def _copy_block_device(self, src: int, dst: int) -> None:
        """Device copy of every token-kind leaf's block `src` -> `dst`
        (GLOBAL ids — the cache pytree lives in its committed global
        layout; the per-step shard split happens inside the jitted step).

        Multi-leaf token kinds copy ATOMICALLY: `_map_token_kinds` applies
        the copy via `jax.tree.map`, and a quantized pool's `PackedKV` is a
        NamedTuple pytree, so its codes AND scale leaves move in the same
        jitted call — a COW'd packed block can never pair fresh codes with
        stale scales (tests/test_kv_quant.py pins the round trip)."""
        if self._copy_fn is None:
            def cp(caches, s, d):
                return _map_token_kinds(
                    caches, lambda leaf: leaf.at[:, d].set(leaf[:, s]))
            self._copy_fn = jax.jit(cp, donate_argnums=(0,))
        self.caches = self._copy_fn(self.caches, jnp.int32(src),
                                    jnp.int32(dst))

    # ---- host spill tier (hierarchical prefix cache) ---------------------
    #
    # The prefix cache's host-RAM tier (serve/prefix_cache.py) stores
    # evicted blocks as IMMUTABLE host snapshots of the device bytes:
    # PackedKV pools round-trip their packed uint8 codes + scales verbatim,
    # bf16 pools round-trip bf16 — either way host->device->host is the
    # identity, which is what makes a spill-hot stream bitwise-equal to
    # cold (docs/CONVENTIONS.md §9). Only the engine thread calls these.

    def read_block_host(self, block: int):
        """Snapshot every token-kind leaf's block `block` to host memory.

        Returns `(payload, nbytes)`: a pytree of numpy arrays mirroring the
        token-kind structure of `self.caches` (PackedKV stays a PackedKV of
        uint8 arrays — packed bytes, never dequantized), plus its host
        footprint. Synchronous (one device_get), so it is an eviction-path
        facility, never called from compiled code."""
        if not self.paged:
            raise SlotError("read_block_host on a dense pool: no blocks")
        if self._read_block_fn is None:
            def rd(caches, b):
                out = []
                for stage in caches:
                    ns = {}
                    for lk, kinds in stage.items():
                        tk = {k: jax.tree.map(lambda leaf: leaf[:, b], v)
                              for k, v in kinds.items() if k in TOKEN_KINDS}
                        if tk:
                            ns[lk] = tk
                    out.append(ns)
                return out
            self._read_block_fn = jax.jit(rd)
        payload = jax.device_get(self._read_block_fn(self.caches,
                                                     jnp.int32(block)))
        nbytes = sum(a.nbytes for a in jax.tree_util.tree_leaves(payload))
        return payload, nbytes

    def write_block_host(self, block: int, payload) -> None:
        """Write a `read_block_host` payload into device block `block`.

        Dispatch-only: the jitted scatter is enqueued WITHOUT blocking, so a
        swap-in overlaps subsequent host work (decode ticks); any later step
        reading the pool sees the write because it consumes the rebound
        `self.caches` pytree — XLA orders the dependency, no host sync is
        ever needed for correctness."""
        if not self.paged:
            raise SlotError("write_block_host on a dense pool: no blocks")
        if self._write_block_fn is None:
            def wr(caches, pay, b):
                out = []
                for stage, ps in zip(caches, pay):
                    ns = {}
                    for lk, kinds in stage.items():
                        pk = ps.get(lk, {})
                        ns[lk] = {
                            k: (jax.tree.map(
                                lambda leaf, p: leaf.at[:, b].set(p), v,
                                pk[k]) if k in TOKEN_KINDS else v)
                            for k, v in kinds.items()}
                    out.append(ns)
                return out
            self._write_block_fn = jax.jit(wr, donate_argnums=(0,))
        self.caches = self._write_block_fn(self.caches, payload,
                                           jnp.int32(block))

    def alloc_cache_block(self, shard: int) -> int:
        """Allocate one block on `shard` OWNED BY THE PREFIX CACHE (ref 1,
        no slot): the target of a host-tier swap-in or a cross-shard
        replication copy. Falls back to `evict_hook` under pressure exactly
        like `ensure`; the caller must pin (acquire) any cache path it is
        materializing FIRST, or the eviction could spill the very nodes the
        swap-in is for."""
        free = self._frees[shard]
        if not free and not (self.evict_hook is not None
                             and self.evict_hook(shard, 1) > 0):
            raise OutOfBlocks(f"shard {shard}: no free block for the cache")
        blk = free.pop()
        self._ref[blk] = 1
        if self.obs is not None:
            self.obs.on_pool_alloc(1)
        return blk

    def check_quant_overflow(self, vals: jax.Array) -> float:
        """Debug-mode overflow detector for the cache-quantization path.

        Replays `nvfp4_cache_encode`'s scale chain on `vals` (anything a
        mixer would scatter into this pool) and returns the fraction of
        normalized magnitudes past the E2M1 edge — the 16/17 scale margin
        pins it to exactly 0.0, and a nonzero value means the silent
        saturation bias `core/formats.py:fp4_sr` documents is active.
        Host-side and synchronous (one device_get), so it is a debug /
        test / probe facility, NEVER called from the jitted step
        (docs/CONVENTIONS.md §6 forbids callbacks in compiled code, which
        is why this check cannot live inside `scatter_tokens` itself)."""
        if not self.quantized:
            return 0.0
        if self._overflow_fn is None:
            self._overflow_fn = jax.jit(F.nvfp4_cache_overflow)
        return float(self._overflow_fn(vals))

    # ---- slot state ----

    def reset_slot(self, slot: int) -> None:
        """Zero the recurrent state of `slot` (new sequence admitted).

        Only valid on an UNBOUND slot: resetting a live sequence's state
        would silently corrupt it, so that is a SlotError."""
        if self._bound[slot]:
            raise SlotError(f"slot {slot}: reset_slot on a bound slot")
        self.caches = _map_state_kinds(
            self.caches, lambda leaf: leaf.at[:, slot].set(0))

    # ---- speculative rollback of recurrent state -------------------------
    #
    # Token kinds truncate for free (position-masked); the recurrent kinds
    # (wkv / tm_prev / cm_prev / lru) integrate every token irreversibly, so
    # rollback is snapshot -> verify chunk -> restore for rejected slots.

    def snapshot_states(self):
        """Copies of every state-kind leaf (None if this arch has none).

        Real device copies, not references: the engine's jitted step donates
        the cache pytree, which invalidates the pre-step buffers."""
        if not self.has_state_kinds:
            return None
        out = []
        for stage in self.caches:
            ns = {}
            for lk, kinds in stage.items():
                sk = {k: v for k, v in kinds.items() if k in STATE_KINDS}
                if sk:
                    ns[lk] = jax.tree.map(lambda x: jnp.array(x, copy=True),
                                          sk)
            out.append(ns)
        return out

    def restore_states(self, snapshot, slots) -> None:
        """Write `slots`' rows of every state-kind leaf back from snapshot."""
        if snapshot is None or not slots:
            return
        idx = np.asarray(list(slots), np.int32)

        def put(cur, snap):
            return cur.at[:, idx].set(snap[:, idx])

        new = []
        for stage, sstage in zip(self.caches, snapshot):
            ns = {}
            for lk, kinds in stage.items():
                ns[lk] = {k: (jax.tree.map(put, v, sstage[lk][k])
                              if k in STATE_KINDS else v)
                          for k, v in kinds.items()}
            new.append(ns)
        self.caches = new
