"""Async streaming frontend: OpenAI-style /v1/completions over SSE, backed
by a ServeEngine ticking on a dedicated thread.

Two layers, one thread boundary:

  EngineBridge        owns the ENGINE THREAD. All engine / pool / cache /
                      scheduler state is touched exclusively from that
                      thread (docs/CONVENTIONS.md §8): the tick loop runs
                      there, and every externally-originated mutation
                      (submit, cancel, resume, drain, stats snapshot)
                      arrives as a closure on a command queue, executed
                      between ticks. Results travel back on
                      concurrent.futures.Future. Per-request StreamHandle
                      objects are the read side: internally locked, safe
                      from any thread, woken cross-thread via
                      `loop.call_soon_threadsafe`.

  CompletionFrontend  the asyncio side: a hand-rolled HTTP/1.1 server
                      (stdlib asyncio only — no framework dependency)
                      speaking `POST /v1/completions` with per-token SSE
                      streaming, plus /metrics, /healthz, /v1/stats and
                      /admin/drain. It never touches the engine directly.

Request lifecycle (serve/README.md "Frontend & request lifecycle"):

    queued ──first token──▶ streaming ──▶ retired
      │                        │ ├─ cancelled    (client asked / shutdown)
      │                        │ ├─ disconnected (client vanished mid-read)
      │                        │ └─ requeued ──resume──▶ streaming
      └─ rejected (backpressure / rate limit / budget / drain / unservable)

Robustness mechanics:

  * Disconnect: an EOF watcher on the client socket plus write-path
    exceptions both funnel into `engine.cancel(reason="disconnected")` —
    the engine's cache-insert-then-release path, so the tokens already
    paid for stay in the prefix cache and a follow-up request hot-hits
    them (tests/test_frontend.py pins this).
  * Backpressure: admission is bounded (`max_inflight`, engine
    `max_queue`); rejections are HTTP 429 with a Retry-After derived from
    live queue depth over the observed decode rate
    (ServeEngine.suggested_retry_after_s / QueueFull.retry_after_s).
  * Visibility timeout: a consumer that stops READING (unread tokens
    older than `visibility_timeout_s`) has its engine request cancelled
    (reason="requeued", prefix cached) and its handle parked — the slot
    goes to someone live. When the consumer reads again the frontend
    resumes it: resubmit prompt + generated-so-far with the remaining
    budget; the prefix cache makes the catch-up prefill nearly free and
    greedy bf16 streams continue bitwise-exactly.
  * Drain: maintenance mode finishes all in-flight work while rejecting
    new arrivals with 503 + Retry-After; `drained` is observable (event +
    trace marker) so restarts can fence on it.

Token budgets and rate limits are per-tenant (`x-tenant` header /
`user` body field): a token-bucket on request admission plus a lifetime
prompt+max_new token budget, both charged up front at admission so a
rejected request costs nothing.
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import json
import queue as queue_mod
import threading
from collections.abc import MutableMapping
from dataclasses import dataclass, field, replace

from repro.serve.engine import (EngineConfig, QueueFull, Request,
                                ServeEngine, Unservable)
from repro.serve.sampling import SamplingParams

#: StreamHandle lifecycle states (the README state diagram)
H_QUEUED, H_STREAMING, H_REQUEUED = "queued", "streaming", "requeued"
H_RETIRED, H_CANCELLED, H_REJECTED = "retired", "cancelled", "rejected"
H_ERRORED = "errored"
TERMINAL = frozenset({H_RETIRED, H_CANCELLED, H_REJECTED, H_ERRORED})


class StreamHandle:
    """Per-request seam between the engine thread (producer) and one
    consumer coroutine/thread. Internally locked; every field mutation
    happens under `_lock`, and the registered waker is invoked OUTSIDE it
    (a waker that re-enters read_new must not deadlock)."""

    def __init__(self, bridge: "EngineBridge", prompt: list[int],
                 max_new: int, sampling: SamplingParams, tenant: str,
                 track_visibility: bool):
        self._bridge = bridge
        self._lock = threading.Lock()
        self._waker = None
        self.prompt = list(prompt)
        self.max_new = max_new
        self.sampling = sampling
        self.tenant = tenant
        self.track_visibility = track_visibility
        self.req_id = -1          # CURRENT engine req id (changes on resume)
        self.tokens: list[int] = []   # everything generated, across requeues
        self._read_pos = 0
        self.state = H_QUEUED
        self.result = None        # final RequestResult (last leg's)
        self.error: BaseException | None = None
        self.last_read_s = bridge.clock()
        self.requeues = 0
        self.stream_opened = False    # `streamed` span/gauge open (engine thr)

    # ---- consumer side ---------------------------------------------------

    def read_new(self):
        """Drain un-read tokens; returns (new_tokens, state, result, error).
        Stamps `last_read_s` — the liveness signal the visibility-timeout
        reaper checks. Safe from any thread."""
        with self._lock:
            new = self.tokens[self._read_pos:]
            self._read_pos = len(self.tokens)
            self.last_read_s = self._bridge.clock()
            return new, self.state, self.result, self.error

    def set_waker(self, cb) -> None:
        """Register (replace) the callback invoked after every state/token
        update. For asyncio consumers: `loop.call_soon_threadsafe(evt.set)`
        — the waker itself must be cheap and non-blocking."""
        with self._lock:
            self._waker = cb

    @property
    def done(self) -> bool:
        with self._lock:
            return self.state in TERMINAL

    # ---- engine-thread side ----------------------------------------------

    def _push(self, new: list[int]) -> None:
        with self._lock:
            if new:
                self.tokens.extend(new)
                if self.state == H_QUEUED:
                    self.state = H_STREAMING
            waker = self._waker
        if waker is not None:
            waker()

    def _unread_age_s(self, now: float) -> float | None:
        """Seconds the oldest unread token has waited, or None when the
        consumer is fully caught up (then it is WAITING, not stalled)."""
        with self._lock:
            if (not self.track_visibility or self.state in TERMINAL
                    or self.state == H_REQUEUED
                    or self._read_pos >= len(self.tokens)):
                return None
            return now - self.last_read_s

    def _transition(self, state: str, result=None,
                    error: BaseException | None = None,
                    new: list[int] | None = None) -> None:
        with self._lock:
            if self.state in TERMINAL:
                return
            if new:
                self.tokens.extend(new)
            self.state = state
            if result is not None:
                self.result = result
            if error is not None:
                self.error = error
            if state == H_REQUEUED:
                self.requeues += 1
                self.req_id = -1
            waker = self._waker
        if waker is not None:
            waker()


class EngineBridge:
    """Thread-safe submit/poll/cancel boundary around a ServeEngine.

    Owns the engine tick thread: `start()` spawns it, after which NOTHING
    outside that thread may call engine methods directly — use `submit` /
    `cancel` / `resume` / `drain` / `call`, all of which enqueue closures
    the tick loop executes between steps and resolve a Future. This is the
    seam ROADMAP item 3 (disaggregated prefill/decode) reuses: the engine
    never learns it is being driven across a thread."""

    def __init__(self, engine: ServeEngine,
                 visibility_timeout_s: float | None = 30.0,
                 idle_wait_s: float = 0.02):
        self.engine = engine
        self.clock = engine.clock
        self.obs = engine.obs
        self.visibility_timeout_s = visibility_timeout_s
        self.idle_wait_s = idle_wait_s
        engine.token_hook = self._on_tokens
        self._cmds: queue_mod.Queue = queue_mod.Queue()
        self._by_req: dict[int, StreamHandle] = {}
        self._thread: threading.Thread | None = None
        self._stop = False
        self.draining = False
        self._drain_marked = False
        self.drained = threading.Event()
        self.error: BaseException | None = None
        #: last tick's backpressure hint (engine thread writes, any thread
        #: reads — a float rebind is atomic under the GIL)
        self.retry_hint_s = 1.0

    # ---- lifecycle -------------------------------------------------------

    def start(self) -> "EngineBridge":
        assert self._thread is None, "bridge already started"
        self._thread = threading.Thread(target=self._run,
                                        name="serve-engine", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the tick loop (in-flight handles are failed, not drained —
        use `drain()` first for a graceful shutdown)."""
        if self._thread is None:
            return
        self._stop = True
        self._cmds.put(lambda: None)  # wake an idle loop
        self._thread.join(timeout=30.0)
        self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # ---- engine thread ---------------------------------------------------

    def _run(self) -> None:
        eng = self.engine
        try:
            while not self._stop:
                self._drain_commands()
                if self._stop:
                    break
                if eng.has_work():
                    eng.step()
                    self._check_visibility(self.clock())
                    self.retry_hint_s = eng.suggested_retry_after_s()
                else:
                    if self.draining and not self._drain_marked:
                        # every in-flight request has completed; mark once
                        self._drain_marked = True
                        if self.obs.enabled:
                            self.obs.on_drain(self.clock())
                        self.drained.set()
                    try:
                        cmd = self._cmds.get(timeout=self.idle_wait_s)
                    except queue_mod.Empty:
                        continue
                    self._exec(cmd)
        except BaseException as e:  # engine-thread fault: fail everything
            self.error = e
            for h in list(self._by_req.values()):
                self._close_stream(h)
                h._transition(H_ERRORED, error=e)
            self._by_req.clear()
            # keep servicing the command queue in failed mode: each command
            # sees `self.error` and fails its future immediately, so
            # callers get the fault instead of a hung await
            while not self._stop:
                try:
                    cmd = self._cmds.get(timeout=self.idle_wait_s)
                except queue_mod.Empty:
                    continue
                cmd()

    def _drain_commands(self) -> None:
        while True:
            try:
                cmd = self._cmds.get_nowait()
            except queue_mod.Empty:
                return
            self._exec(cmd)

    @staticmethod
    def _exec(cmd) -> None:
        # commands resolve their own futures; a raising command is a bug
        # in the bridge itself, so let it propagate to the fault handler
        cmd()

    def _on_tokens(self, req, new, result) -> None:
        """EngineConfig.token_hook: runs inside engine.step() on the engine
        thread. Routes the flush to the owning handle; unknown req_ids
        (direct engine use, already-requeued legs) are ignored."""
        h = self._by_req.get(req.req_id)
        if h is None:
            return
        if new and not h.stream_opened:
            h.stream_opened = True
            if self.obs.enabled:
                self.obs.on_stream_open(req, self.clock())
        if new and self.obs.enabled:
            self.obs.on_stream_tokens(len(new))
        if result is not None:
            self._by_req.pop(req.req_id, None)
            self._close_stream(h)
            h._transition(H_RETIRED, result=result, new=new)
        else:
            h._push(new)

    def _close_stream(self, h: StreamHandle) -> None:
        if h.stream_opened:
            h.stream_opened = False
            if self.obs.enabled:
                self.obs.on_stream_close()

    def _check_visibility(self, now: float) -> None:
        """Requeue handles whose consumer stopped reading: cancel the
        engine request (prefix cached — the work is NOT thrown away) and
        park the handle. The freed slot goes to a live consumer; the
        stalled one resumes from its cached prefix if it ever returns."""
        vt = self.visibility_timeout_s
        if vt is None:
            return
        for rid, h in list(self._by_req.items()):
            age = h._unread_age_s(now)
            if age is not None and age > vt:
                self.engine.cancel(rid, reason="requeued")
                self._by_req.pop(rid, None)
                self._close_stream(h)
                h._transition(H_REQUEUED)

    # ---- commands (any thread; executed on the engine thread) -----------

    def _command(self, fn) -> concurrent.futures.Future:
        fut: concurrent.futures.Future = concurrent.futures.Future()

        def cmd():
            if self.error is not None:
                fut.set_exception(RuntimeError(
                    f"engine thread failed: {self.error!r}"))
                return
            try:
                fut.set_result(fn())
            except BaseException as e:
                fut.set_exception(e)

        self._cmds.put(cmd)
        return fut

    def call(self, fn) -> concurrent.futures.Future:
        """Run `fn(engine)` on the engine thread; Future of its result.
        The sanctioned way to read engine/pool/cache state from outside."""
        return self._command(lambda: fn(self.engine))

    def submit(self, prompt: list[int], max_new: int,
               sampling: SamplingParams | None = None,
               tenant: str = "default", priority: int = 0,
               deadline_s: float | None = None,
               track_visibility: bool = True) -> concurrent.futures.Future:
        """Future[StreamHandle]; raises (through the future) QueueFull /
        Unservable with structured retry info, or QueueFull("draining")
        while the bridge drains."""
        h = StreamHandle(self, prompt, max_new,
                         sampling or SamplingParams(), tenant,
                         track_visibility)

        def do():
            if self.draining:
                raise QueueFull("draining: not accepting new work",
                                reason="draining",
                                queue_depth=len(self.engine.queue),
                                retry_after_s=self.retry_hint_s)
            rid = self.engine.submit(Request(
                prompt=list(h.prompt), max_new=h.max_new,
                sampling=h.sampling, priority=priority,
                deadline_s=deadline_s))
            h.req_id = rid
            self._by_req[rid] = h
            return h

        return self._command(do)

    def cancel(self, h: StreamHandle,
               reason: str = "cancelled") -> concurrent.futures.Future:
        """Future[bool]: cancel a handle's engine request (prefix cached)
        and finish the handle. `reason` "disconnected" keeps its own
        terminal span; a parked (requeued) handle just finishes."""
        state = H_CANCELLED

        def do():
            if h.state in TERMINAL:
                return False
            if h.req_id >= 0:
                self.engine.cancel(h.req_id, reason=reason)
                self._by_req.pop(h.req_id, None)
            self._close_stream(h)
            h._transition(state)
            return True

        return self._command(do)

    def resume(self, h: StreamHandle) -> concurrent.futures.Future:
        """Future[StreamHandle]: resubmit a REQUEUED handle as
        prompt + generated-so-far with the remaining token budget — the
        prefix cache absorbs the catch-up prefill. No-op for non-parked
        handles; finishes the handle directly when nothing remains."""

        def do():
            if h.state != H_REQUEUED:
                return h
            remaining = h.max_new - len(h.tokens)
            if remaining <= 0:
                h._transition(H_RETIRED)
                return h
            if self.draining:
                h._transition(H_CANCELLED)
                return h
            rid = self.engine.submit(Request(
                prompt=h.prompt + h.tokens, max_new=remaining,
                sampling=h.sampling))
            h.req_id = rid
            self._by_req[rid] = h
            with h._lock:
                h.state = H_QUEUED if not h.tokens else H_STREAMING
            return h

        return self._command(do)

    def drain(self) -> concurrent.futures.Future:
        """Enter maintenance mode: new submits rejected (QueueFull reason
        "draining"), in-flight work runs to completion, then `drained` is
        set and the obs layer records the `drained` marker."""

        def do():
            self.draining = True
            if not self.engine.has_work() and not self._drain_marked:
                self._drain_marked = True
                if self.obs.enabled:
                    self.obs.on_drain(self.clock())
                self.drained.set()
            return True

        return self._command(do)

    def undrain(self) -> concurrent.futures.Future:
        def do():
            self.draining = False
            self._drain_marked = False
            self.drained.clear()
            return True

        return self._command(do)

    def snapshot(self) -> concurrent.futures.Future:
        """Future[dict]: engine stats + occupancy, read on the engine
        thread (so never torn by a concurrent tick)."""

        def do():
            eng = self.engine
            return {
                "stats": dict(eng.stats),
                "queue_depth": len(eng.queue),
                "free_slots": eng.free_slots,
                "pool_free_blocks": eng.pool.free_block_count,
                "pool_total_blocks": eng.pool.n_blocks,
                "live_handles": len(self._by_req),
                "draining": self.draining,
                "retry_after_s": eng.suggested_retry_after_s(),
            }

        return self._command(do)


# ---------------------------------------------------------------------------
# disaggregated prefill/decode
# ---------------------------------------------------------------------------


class _PairStats(MutableMapping):
    """Merged `engine.stats` over a (prefill, decode) pair: reads SUM the
    two engines' counters (ticks, prefill_* live on the prefill worker,
    decode_* / finished on the decode worker — the sum is what a
    single-engine caller expects); writes land the value on the prefill
    view and zero the decode one, so bench reset loops (`stats[k] = 0`)
    and absolute assignments read back unchanged."""

    __slots__ = ("_p", "_d")

    def __init__(self, p, d):
        self._p = p
        self._d = d

    def __getitem__(self, k):
        return self._p[k] + self._d[k]

    def __setitem__(self, k, v):
        self._p[k] = v
        self._d[k] = v * 0  # 0 or 0.0, matching the key's type

    def __delitem__(self, k):
        raise TypeError("engine.stats has a fixed key set")

    def __iter__(self):
        return iter(self._p)

    def __len__(self):
        return len(self._p)

    def __repr__(self):
        return repr(dict(self))


class EnginePair:
    """Disaggregated prefill/decode: two role-split ServeEngines behind the
    exact engine surface EngineBridge drives (submit / cancel / step /
    has_work / run / stats / queue / pool / free_slots / token_hook /
    clock / obs / suggested_retry_after_s), so the whole frontend stack —
    bridge thread, SSE streaming, visibility timeout, drain — works over a
    split deployment unchanged (ROADMAP item 3; this is the seam PR 8's
    bridge left for exactly this).

    One pair `step()` is one tick of EACH worker: finished prefills cross
    the role boundary first (`prefill.handoffs` -> `decode.submit_handoff`
    — the KV travels as immutable host payloads, docs/CONVENTIONS.md §9),
    then the decode worker ticks, then the prefill worker. The decode
    worker therefore never runs a prefill chunk: its per-token latency is
    flat no matter how long the prompts streaming into the prefill worker
    are. In-process the two engines still tick serially on the bridge
    thread; the handoff protocol is the deployment seam (the payloads are
    plain host bytes), not a transport.

    Lifecycle guarantees the pair preserves (tests/test_frontend.py,
    tests/test_cancel_races.py): cancel finds a request wherever it lives —
    prefill queue/slots, the in-transit handoff deque, the decode worker's
    handoff queue/slots — and reclaims that side's pool state, so
    conservation holds on BOTH pools; drain (`has_work` over both workers
    plus the in-transit deque) completes every leg before `drained` fires.
    """

    def __init__(self, prefill: ServeEngine, decode: ServeEngine):
        if prefill.role != "prefill" or decode.role != "decode":
            raise ValueError(
                f"EnginePair wants roles ('prefill', 'decode'), got "
                f"({prefill.role!r}, {decode.role!r})")
        if prefill.clock is not decode.clock:
            raise ValueError(
                "role-split engines must share one clock: arrival stamps "
                "taken on the prefill worker are compared against deadlines "
                "and visibility timeouts on the decode side")
        self.prefill = prefill
        self.decode = decode
        self.clock = prefill.clock
        self.obs = prefill.obs
        self._stats = _PairStats(prefill.stats, decode.stats)

    # ---- the engine surface the bridge drives ----------------------------

    @property
    def stats(self):
        return self._stats

    @property
    def queue(self):
        """Admission queue = the prefill worker's (submits land there)."""
        return self.prefill.queue

    @property
    def pool(self):
        """Primary pool = the decode worker's (where live sequences sit;
        the bridge snapshot reports its occupancy)."""
        return self.decode.pool

    @property
    def cache(self):
        """Prefix cache = the prefill worker's (matching happens at prompt
        admission; the decode worker imports finished KV and never
        matches)."""
        return self.prefill.cache

    @property
    def free_slots(self) -> int:
        return min(self.prefill.free_slots, self.decode.free_slots)

    @property
    def token_hook(self):
        return self.prefill.token_hook

    @token_hook.setter
    def token_hook(self, fn) -> None:
        # both workers flush through the same hook: the prefill worker
        # emits each request's first token, the decode worker the rest —
        # req_id is preserved across the handoff, so the bridge's by-id
        # routing sees one continuous stream
        self.prefill.token_hook = fn
        self.decode.token_hook = fn

    def submit(self, request: Request) -> int:
        return self.prefill.submit(request)

    def cancel(self, req_id: int, reason: str = "cancelled") -> bool:
        """Cancel wherever the request currently lives. A handoff caught
        in transit is just dropped: the prefill worker released its blocks
        at export and the decode worker never allocated."""
        if self.prefill.cancel(req_id, reason=reason):
            return True
        for h in self.prefill.handoffs:
            if h.req.req_id == req_id:
                self.prefill.handoffs.remove(h)
                self.prefill.stats["cancelled"] += 1
                if self.obs.enabled:
                    self.obs.on_cancel(h.req, self.clock(), reason=reason)
                return True
        return self.decode.cancel(req_id, reason=reason)

    def has_work(self) -> bool:
        return (self.prefill.has_work() or bool(self.prefill.handoffs)
                or self.decode.has_work())

    def suggested_retry_after_s(self) -> float:
        # the decode worker owns the generated-token backlog estimate; the
        # prefill worker's hint is the 1.0 floor until it has decode stats
        # (never, by construction) — max() picks the informed one
        return max(self.prefill.suggested_retry_after_s(),
                   self.decode.suggested_retry_after_s())

    def step(self):
        # ship finished prefills across the role boundary FIRST, so a KV
        # handoff exported last tick admits into a decode slot this tick
        while self.prefill.handoffs:
            self.decode.submit_handoff(self.prefill.handoffs.popleft())
        finished = []
        if self.decode.has_work():
            finished.extend(self.decode.step())
        if self.prefill.has_work():
            finished.extend(self.prefill.step())
        return finished

    def run(self):
        """Drain both workers; results in completion order."""
        out = []
        while self.has_work():
            out.extend(self.step())
        return out


def make_disagg_pair(cfg, params, econf: EngineConfig) -> EnginePair:
    """Build a prefill/decode EnginePair from one EngineConfig.

    The prefill worker takes `econf` with `role="prefill"` (it owns
    admission, the prefix cache, and the user's obs hook); the decode
    worker reuses the prefill worker's prequantized params (one weight
    cache serves both — in a real split deployment each worker would hold
    its own copy) with `role="decode"` and no prefix cache: it admits
    Handoffs, never prompts, so it would never match. Raises the same
    validation errors a role-split ServeEngine does (paged pool, no
    sliding window / recurrent state / spec_k)."""
    pe = ServeEngine(cfg, params, replace(econf, role="prefill"))
    de = ServeEngine(cfg, pe.params, replace(
        econf, role="decode", prequant=False, obs=None,
        prefix_cache=False, prefix_spill=False, replicate_hits=None))
    return EnginePair(pe, de)


# ---------------------------------------------------------------------------
# tenant quotas
# ---------------------------------------------------------------------------


@dataclass
class TenantQuota:
    """Per-tenant admission limits, both enforced up front (a rejected
    request consumes neither)."""

    rate_rps: float = float("inf")  # request admissions per second
    burst: int = 8                  # token-bucket capacity
    token_budget: int | None = None  # lifetime prompt+max_new tokens


class _TokenBucket:
    """Classic token bucket on the bridge's injectable clock — rate-limit
    tests drive it with a fake clock, no sleeps."""

    def __init__(self, quota: TenantQuota, clock):
        self.rate = quota.rate_rps
        self.capacity = max(quota.burst, 1)
        self.tokens = float(self.capacity)
        self.clock = clock
        self.last = clock()

    def try_take(self) -> bool:
        now = self.clock()
        self.tokens = min(self.capacity,
                          self.tokens + (now - self.last) * self.rate)
        self.last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


@dataclass
class FrontendConfig:
    host: str = "127.0.0.1"
    port: int = 0                  # 0: ephemeral (read back from .port)
    max_inflight: int = 64         # admitted-but-unfinished handle cap
    default_quota: TenantQuota = field(default_factory=TenantQuota)
    tenants: dict = field(default_factory=dict)  # tenant -> TenantQuota
    #: safety re-check period while awaiting tokens (a lost waker never
    #: wedges a stream, it just degrades to polling at this period)
    stream_wait_s: float = 1.0


# ---------------------------------------------------------------------------
# HTTP frontend
# ---------------------------------------------------------------------------

_JSON = {"Content-Type": "application/json"}


class CompletionFrontend:
    """OpenAI-style /v1/completions over hand-rolled HTTP/1.1 + SSE.

    Endpoints:
      POST /v1/completions   {"prompt": [ints], "max_tokens": n,
                              "temperature": f, "top_k": k,
                              "stream": bool, "user": tenant}
                             SSE (`stream: true`): one `data:` JSON event
                             per token flush, a final event with `usage`,
                             then `data: [DONE]`.
      GET  /healthz          liveness + drain state
      GET  /v1/stats         engine snapshot (read on the engine thread)
      GET  /metrics          Prometheus text (404 when obs is disabled)
      POST /admin/drain      enter maintenance mode; /admin/undrain exits

    Tenancy: `x-tenant` header, else the body's `user` field, else
    "default". All frontend-side accounting (buckets, budgets, inflight)
    lives on the asyncio thread — no locks needed."""

    def __init__(self, bridge: EngineBridge,
                 fconf: FrontendConfig | None = None):
        self.bridge = bridge
        self.fc = fconf or FrontendConfig()
        self.obs = bridge.obs
        self._buckets: dict[str, _TokenBucket] = {}
        self._spent: dict[str, int] = {}
        self._inflight = 0
        self._server: asyncio.AbstractServer | None = None
        self.port: int | None = None

    # ---- lifecycle -------------------------------------------------------

    async def start(self) -> "CompletionFrontend":
        self._server = await asyncio.start_server(
            self._serve_conn, self.fc.host, self.fc.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # ---- connection handling --------------------------------------------

    async def _serve_conn(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        try:
            req = await self._read_request(reader)
            if req is None:
                return
            method, path, headers, body = req
            if method == "POST" and path == "/v1/completions":
                await self._handle_completion(reader, writer, headers, body)
            elif method == "GET" and path == "/healthz":
                snap = await asyncio.wrap_future(self.bridge.snapshot())
                await self._respond(writer, 200, {
                    "status": "draining" if snap["draining"] else "ok",
                    "inflight": self._inflight,
                    "queue_depth": snap["queue_depth"]})
            elif method == "GET" and path == "/v1/stats":
                snap = await asyncio.wrap_future(self.bridge.snapshot())
                snap["tenant_tokens_spent"] = dict(self._spent)
                await self._respond(writer, 200, snap)
            elif method == "GET" and path == "/metrics":
                if not self.obs.enabled:
                    await self._respond(writer, 404,
                                        {"error": "observability disabled"})
                else:
                    text = self.obs.prometheus().encode()
                    await self._respond_raw(
                        writer, 200, text,
                        {"Content-Type": "text/plain; version=0.0.4"})
            elif method == "POST" and path == "/admin/drain":
                await asyncio.wrap_future(self.bridge.drain())
                await self._respond(writer, 202, {"draining": True})
            elif method == "POST" and path == "/admin/undrain":
                await asyncio.wrap_future(self.bridge.undrain())
                await self._respond(writer, 202, {"draining": False})
            else:
                await self._respond(writer, 404, {"error": "no such route"})
        except (ConnectionError, asyncio.IncompleteReadError):
            pass  # client went away; per-request cancel paths already ran
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    @staticmethod
    async def _read_request(reader):
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _version = line.decode("latin1").split()
        except ValueError:
            return None
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or 0)
        body = await reader.readexactly(n) if n else b""
        return method, path, headers, body

    @staticmethod
    async def _respond_raw(writer, status: int, payload: bytes,
                           headers: dict) -> None:
        reasons = {200: "OK", 202: "Accepted", 400: "Bad Request",
                   404: "Not Found", 429: "Too Many Requests",
                   500: "Internal Server Error", 503: "Service Unavailable"}
        head = [f"HTTP/1.1 {status} {reasons.get(status, 'OK')}"]
        hdrs = {"Content-Length": str(len(payload)),
                "Connection": "close", **headers}
        head += [f"{k}: {v}" for k, v in hdrs.items()]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + payload)
        await writer.drain()

    async def _respond(self, writer, status: int, obj,
                       headers: dict | None = None) -> None:
        await self._respond_raw(writer, status,
                                json.dumps(obj).encode(),
                                {**_JSON, **(headers or {})})

    # ---- admission -------------------------------------------------------

    def _quota(self, tenant: str) -> TenantQuota:
        return self.fc.tenants.get(tenant, self.fc.default_quota)

    def _admit(self, tenant: str, cost: int):
        """Frontend-side admission: returns (reason, retry_after_s) on
        rejection, None when admitted (cost charged)."""
        if self.bridge.draining:
            return "draining", self.bridge.retry_hint_s
        if self._inflight >= self.fc.max_inflight:
            return "backpressure", self.bridge.retry_hint_s
        q = self._quota(tenant)
        bucket = self._buckets.get(tenant)
        if bucket is None:
            bucket = self._buckets[tenant] = _TokenBucket(q, self.bridge.clock)
        if not bucket.try_take():
            return ("rate_limited",
                    1.0 / q.rate_rps if q.rate_rps > 0 else None)
        if q.token_budget is not None and \
                self._spent.get(tenant, 0) + cost > q.token_budget:
            return "budget_exhausted", None
        self._spent[tenant] = self._spent.get(tenant, 0) + cost
        return None

    async def _handle_completion(self, reader, writer, headers,
                                 body: bytes) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            prompt = spec["prompt"]
            if (not isinstance(prompt, list) or not prompt
                    or not all(isinstance(t, int) for t in prompt)):
                raise ValueError("prompt must be a non-empty list of "
                                 "token ids (no tokenizer is served)")
            max_new = int(spec.get("max_tokens", 16))
            if max_new <= 0:
                raise ValueError("max_tokens must be >= 1")
            sampling = SamplingParams(
                temperature=float(spec.get("temperature", 0.0)),
                top_k=int(spec.get("top_k", 0)))
            stream = bool(spec.get("stream", False))
        except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
            await self._respond(writer, 400, {"error": {
                "reason": "bad_request", "message": str(e)}})
            return
        tenant = headers.get("x-tenant") or spec.get("user") or "default"

        rejected = self._admit(tenant, len(prompt) + max_new)
        if rejected is not None:
            reason, retry = rejected
            if self.obs.enabled:
                self.obs.on_frontend_reject(reason)
            status = 503 if reason == "draining" else 429
            hdrs = {"Retry-After": f"{retry:.3f}"} if retry else {}
            await self._respond(writer, status, {"error": {
                "reason": reason, "message": f"rejected: {reason}",
                "retry_after_s": retry}}, hdrs)
            return

        try:
            handle = await asyncio.wrap_future(self.bridge.submit(
                prompt, max_new, sampling, tenant=tenant,
                track_visibility=stream))
        except Unservable as e:
            if self.obs.enabled:
                self.obs.on_frontend_reject(e.reason)
            await self._respond(writer, 400, {"error": {
                "message": str(e), **e.info()}})
            return
        except QueueFull as e:
            if self.obs.enabled:
                self.obs.on_frontend_reject(e.reason)
            hdrs = ({"Retry-After": f"{e.retry_after_s:.3f}"}
                    if e.retry_after_s else {})
            await self._respond(writer, 429, {"error": {
                "message": str(e), **e.info()}}, hdrs)
            return

        self._inflight += 1
        try:
            if stream:
                await self._stream_completion(reader, writer, handle)
            else:
                await self._plain_completion(reader, writer, handle)
        finally:
            self._inflight -= 1

    # ---- completion delivery --------------------------------------------

    @staticmethod
    def _watch_disconnect(reader, evt: asyncio.Event, flag: list):
        """Task body: the request is fully read, so any further read
        resolving means the client closed (EOF) or reset — either way the
        consumer is gone."""

        async def watch():
            try:
                await reader.read(1)
            except (ConnectionError, OSError):
                pass
            flag[0] = True
            evt.set()

        return asyncio.create_task(watch())

    def _event(self, handle: StreamHandle, tokens: list[int],
               final: bool) -> bytes:
        obj = {"id": f"cmpl-{handle.req_id}", "object": "text_completion",
               "choices": [{"index": 0, "tokens": tokens,
                            "finish_reason": "length" if final else None}]}
        if final:
            obj["usage"] = {"prompt_tokens": len(handle.prompt),
                            "completion_tokens": len(handle.tokens),
                            "requeues": handle.requeues}
        return b"data: " + json.dumps(obj).encode() + b"\n\n"

    async def _pump(self, handle: StreamHandle, evt: asyncio.Event,
                    gone: list, on_tokens) -> str:
        """Shared delivery loop: read new tokens, hand them to `on_tokens`
        (may await/write), resume parked handles, until a terminal state or
        disconnect. Returns the handle's final state ("disconnected" when
        the client vanished first)."""
        while True:
            evt.clear()
            new, state, _result, error = handle.read_new()
            if gone[0] and state not in TERMINAL:
                await asyncio.wrap_future(
                    self.bridge.cancel(handle, reason="disconnected"))
                return "disconnected"
            if new:
                try:
                    await on_tokens(new)
                except (ConnectionError, OSError):
                    await asyncio.wrap_future(
                        self.bridge.cancel(handle, reason="disconnected"))
                    return "disconnected"
            if state == H_REQUEUED:
                # this consumer is demonstrably live again (it is here,
                # reading): resume from the cached prefix
                await asyncio.wrap_future(self.bridge.resume(handle))
                continue
            if state in TERMINAL:
                if error is not None and state == H_ERRORED:
                    raise error
                return state
            try:
                await asyncio.wait_for(evt.wait(), self.fc.stream_wait_s)
            except asyncio.TimeoutError:
                pass  # safety poll; the waker is the fast path

    async def _stream_completion(self, reader, writer,
                                 handle: StreamHandle) -> None:
        loop = asyncio.get_running_loop()
        evt = asyncio.Event()
        gone = [False]
        handle.set_waker(lambda: loop.call_soon_threadsafe(evt.set))
        watcher = self._watch_disconnect(reader, evt, gone)
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-store\r\n"
                     b"Connection: close\r\n\r\n")
        try:
            await writer.drain()

            async def emit(new):
                writer.write(self._event(handle, new, final=False))
                await writer.drain()

            state = await self._pump(handle, evt, gone, emit)
            if state == H_RETIRED:
                writer.write(self._event(handle, [], final=True))
                writer.write(b"data: [DONE]\n\n")
                await writer.drain()
        except (ConnectionError, OSError):
            await asyncio.wrap_future(
                self.bridge.cancel(handle, reason="disconnected"))
        finally:
            handle.set_waker(None)
            watcher.cancel()

    async def _plain_completion(self, reader, writer,
                                handle: StreamHandle) -> None:
        loop = asyncio.get_running_loop()
        evt = asyncio.Event()
        gone = [False]
        handle.set_waker(lambda: loop.call_soon_threadsafe(evt.set))
        watcher = self._watch_disconnect(reader, evt, gone)
        try:

            async def absorb(new):
                return None  # tokens accumulate on the handle

            state = await self._pump(handle, evt, gone, absorb)
            if state == H_RETIRED:
                await self._respond(writer, 200, {
                    "id": f"cmpl-{handle.req_id}",
                    "object": "text_completion",
                    "choices": [{"index": 0, "tokens": handle.tokens,
                                 "finish_reason": "length"}],
                    "usage": {"prompt_tokens": len(handle.prompt),
                              "completion_tokens": len(handle.tokens),
                              "requeues": handle.requeues}})
            elif state != "disconnected":
                await self._respond(writer, 500, {"error": {
                    "reason": state, "message": f"request {state}"}})
        finally:
            handle.set_waker(None)
            watcher.cancel()


def serve_forever(engine: ServeEngine, fconf: FrontendConfig | None = None):
    """Blocking convenience runner: bridge + frontend until cancelled.
    Examples/ops entry point — tests drive the pieces directly."""

    async def main():
        with EngineBridge(engine) as bridge:
            fe = CompletionFrontend(bridge, fconf)
            await fe.start()
            try:
                await asyncio.Event().wait()  # until cancelled
            finally:
                await fe.stop()

    asyncio.run(main())
