"""NVFP4 serving subsystem.

Modules (import them directly; this package init stays import-free so the
model code can reach `repro.serve.kv_pool` without cycles):

    engine    — ServeEngine: continuous batching, admission control, slots
    kv_pool   — block-based paged KV pool + per-sequence block tables
    prequant  — quantize-once NVFP4 weight cache
    sampling  — greedy / temperature / top-k token sampling
    decode    — thin compatibility wrappers (prefill/serve steps, greedy loop)
"""
