"""NVFP4 serving subsystem.

Modules (import them directly; this package init stays import-free so the
model code can reach `repro.serve.kv_pool` without cycles):

    engine      — ServeEngine: continuous batching, admission control, slots
    kv_pool     — block-based paged KV pool + per-sequence block tables,
                  truncate/rollback API, recurrent-state snapshots
    spec_decode — self-speculative draft/verify loop (truncated-stack draft,
                  exact bitwise greedy verification)
    prequant    — quantize-once NVFP4 weight cache
    sampling    — greedy / temperature / top-k sampling + spec acceptance
    decode      — thin compatibility wrappers (prefill/serve steps, greedy loop)
"""
