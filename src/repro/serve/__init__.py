"""NVFP4 serving subsystem.

Modules (import them directly; this package init stays import-free so the
model code can reach `repro.serve.kv_pool` without cycles):

    engine      — ServeEngine: continuous batching, admission control, slots;
                  EngineConfig.mesh switches on mesh-sharded multi-host
                  mode, .prefix_cache on prompt-prefix sharing, .scheduler
                  swaps the admission/prefill policy, .clock injects a
                  monotonic time source, .token_hook streams tokens out at
                  tick boundaries; structured QueueFull/Unservable
                  rejections carry reason + retry-after
    frontend    — asyncio HTTP/SSE frontend (OpenAI-style /v1/completions):
                  EngineBridge hosts the engine on its own thread (the
                  only engine toucher — docs/CONVENTIONS.md §8) behind a
                  command queue; StreamHandle per-request mailboxes;
                  disconnect cancel, visibility-timeout requeue with exact
                  resume, tenant rate/budget quotas, backpressure 429s,
                  graceful drain
    kv_pool     — block-based paged KV pool + per-sequence block tables,
                  refcounted blocks with adopt_prefix / cow_block aliasing,
                  truncate/rollback API, recurrent-state snapshots,
                  slot-affine sharded allocation (n_shards)
    prefix_cache — radix-tree prompt-prefix cache: refcounted block reuse,
                  COW at the divergence, LRU eviction under pool pressure
    scheduler   — pluggable admission/prefill policies: FifoPolicy (exact
                  legacy behavior) and latency-aware LatencyPolicy
                  (priority, deadlines, starvation-free aging)
    spec_decode — self-speculative draft/verify loop (truncated-stack draft,
                  exact bitwise greedy verification, rejection-sampled
                  stochastic acceptance)
    prequant    — quantize-once NVFP4 weight cache
    sampling    — greedy / temperature / top-k sampling, spec acceptance,
                  distribution-preserving speculative_resample
    decode      — prefill/serve step builders (incl. the shard_map-wrapped
                  sharded step) + the legacy fixed-batch greedy loop
"""
