"""Self-speculative decoding with exact bitwise verification.

The Quartet II NVFP4 forward is DETERMINISTIC (quantize-once PackedQWeight
weights, RTN/4-over-6 activation quantizers, fixed seed), so speculation can
be validated exactly instead of statistically: a truncated-stack draft — the
first `draft_layers` blocks of the SAME model plus the shared LM head, no
second set of weights — proposes K tokens per slot, and one batched
(n_slots, K+1) chunk through the engine's existing chunked decode path
verifies every position. Accepted tokens are, by construction, exactly the
tokens the full model would emit greedily one at a time.

Round structure (all device calls batched over the fixed slot set):

  1. CATCH-UP   — the draft consumes committed tokens it has not seen yet
                  (it always trails the full model by >= 1 token after a
                  fully-accepted round).
  2. PROPOSE    — K single-token draft steps; each argmax feeds the next.
  3. VERIFY     — one full-model chunk over [last_tok, d_1 .. d_K]; logits
                  at chunk index j are the model's prediction for position
                  pos+j+1, so target t_{j+1} = argmax(logits[:, j]).
  4. ACCEPT     — greedy requests keep the longest prefix with d_j == t_j,
                  then emit one more model token for free (the correction /
                  bonus). Stochastic requests rejection-sample each draft
                  against the verify chunk's target distribution
                  (serve/sampling.py `speculative_resample`): the emitted
                  tokens follow the target sampling law exactly, with
                  per-(round, slot) keys keeping streams reproducible.
  5. ROLLBACK   — rejected positions are logically truncated: token caches
                  (kv / mla) need no physical undo (stale entries hide
                  behind the position mask until overwritten); recurrent
                  state (wkv / tm_prev / cm_prev / lru) integrated the whole
                  chunk, so it is restored from a pre-verify snapshot and
                  the committed prefix is replayed through the engine's
                  (n_slots, 1) step. Archs without recurrent state pay no
                  replay at all.

Numerics note: bitwise equality of the emitted stream with the
non-speculative engine requires the per-row forward to be chunk-size
invariant. That holds exactly for bf16 (row-independent arithmetic) and for
rwkv below the chunked-WKV threshold (cfg.rwkv.chunk); quantizing schemes
share one activation absmax across the (slots x chunk) tensor, so quartet2
streams are deterministic run-to-run but can differ from the S=1 engine in
near-tie argmaxes. tests/test_spec_decode.py pins both properties.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serve.kv_pool import KVPool
from repro.serve.sampling import accept_greedy, greedy_targets

_SEED = jnp.array([7, 7], jnp.uint32)  # deterministic forward; see decode.py


def _blank(n_slots: int, size: int = 1):
    return (np.zeros((n_slots, size), np.int32),
            np.zeros((n_slots,), np.int32),
            np.zeros((n_slots,), bool))


class DraftStack:
    """The truncated-stack draft: prefix forward + its own KV pool.

    Reuses the engine's (possibly prequantized) params by slicing the
    stacked layer leaves — the draft never owns weights. Its pool covers
    only the prefix layers' cache kinds, with the same paged/dense layout
    and slot count as the main pool so slot indices line up."""

    def __init__(self, cfg, params, econf):
        self.cfg = cfg
        self.econf = econf
        self.n_prefix = econf.draft_layers
        self.specs = lm.prefix_specs(cfg, econf.draft_layers)  # validates
        self.paged_kernel = econf.resolved_paged_kernel()
        e = econf
        self.mesh = e.mesh
        shards = (dict(self.mesh.shape).get("data", 1)
                  if self.mesh is not None else 1)
        self.pool = KVPool(cfg, e.n_slots, e.max_len, paged=e.paged,
                           block_size=e.block_size, n_blocks=e.n_blocks,
                           specs=self.specs, n_shards=shards)
        if self.mesh is not None:
            from repro.dist import sharding as SH
            self.pool.caches = jax.device_put(
                self.pool.caches,
                SH.serve_cache_shardings(self.pool.caches, self.mesh))
        self.params = params  # engine-owned; already mesh-placed when sharded
        from repro.serve.decode import _needs_unroll
        self.unroll = self.mesh is not None and _needs_unroll(self.mesh)
        self._step_fns: dict[int, object] = {}
        self._propose_fns: dict[int, object] = {}

    def _wrap(self, fn, *, out_batch_axis: int = 0):
        """Mesh mode: the draft's steps run under the same manual-"data" /
        auto-"model" shard_map as the engine's (serve/decode.py)."""
        if self.mesh is None:
            return fn
        from repro.serve.decode import shard_serve_step
        return shard_serve_step(fn, self.mesh, out_batch_axis=out_batch_axis)

    def propose(self, k: int, tok0, pos, active):
        """K greedy proposals in ONE device call.

        A lax.scan over single-token prefix steps keeps the whole
        propose-argmax-feed-back loop on device: one dispatch and one host
        sync per round instead of K. tok0/pos/active: (n_slots,) — each
        active row starts from its last emitted token at its own position.
        Returns np (k, n_slots) proposed ids; the draft cache advances k
        positions for active rows."""
        fn = self._propose_fns.get(k)
        if fn is None:
            cfg, scheme, npfx = self.cfg, self.econf.scheme, self.n_prefix
            pk, unroll = self.paged_kernel, self.unroll

            def prop_fn(params, caches, table, tok0, pos, active):
                def body(carry, t):
                    caches, cur = carry
                    logits, caches, _ = lm.forward_prefix(
                        params, cfg, {"tokens": cur[:, None]}, scheme, _SEED,
                        n_prefix=npfx, caches=caches, mode="decode",
                        pos=pos + t, active=active, block_table=table,
                        paged_kernel=pk, unroll_stages=unroll)
                    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                    return (caches, nxt), nxt

                # the propose loop is itself a scan: unroll it too under a
                # non-trivial auto axis (same while-body sharding limitation
                # as the layer scan — see lm._run_stages)
                (caches, _), toks = jax.lax.scan(
                    body, (caches, tok0), jnp.arange(k),
                    unroll=k if unroll else 1)
                return toks, caches

            fn = self._propose_fns[k] = jax.jit(
                self._wrap(prop_fn, out_batch_axis=1), donate_argnums=(1,))
        toks, self.pool.caches = fn(
            self.params, self.pool.caches, self.pool.table_device(),
            jnp.asarray(tok0, jnp.int32), jnp.asarray(pos),
            jnp.asarray(active))
        return np.asarray(toks)

    def forward(self, size: int, tokens, pos, active):
        fn = self._step_fns.get(size)
        if fn is None:
            cfg, scheme, npfx = self.cfg, self.econf.scheme, self.n_prefix
            pk, unroll = self.paged_kernel, self.unroll

            def step_fn(params, caches, table, tokens, pos, active):
                logits, caches, _ = lm.forward_prefix(
                    params, cfg, {"tokens": tokens}, scheme, _SEED,
                    n_prefix=npfx, caches=caches, mode="decode", pos=pos,
                    active=active, block_table=table, paged_kernel=pk,
                    unroll_stages=unroll)
                return logits, caches

            fn = self._step_fns[size] = jax.jit(
                self._wrap(step_fn), donate_argnums=(1,))
        logits, self.pool.caches = fn(
            self.params, self.pool.caches, self.pool.table_device(),
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(active))
        return logits


def spec_round(eng, dec: list[int]) -> int:
    """One speculative tick over the DECODE slots; returns tokens emitted.

    Mutates engine slots, both pools, and stats. Every compiled shape it
    uses is fixed per engine: the draft (n_slots, 1) step, the full-model
    (n_slots, spec_k + 1) verify chunk, and the existing (n_slots, 1) step
    for state replay."""
    e, K = eng.econf, eng.econf.spec_k
    slots, draft, pool = eng.slots, eng.draft, eng.pool

    # ---- 1. catch-up: feed the draft every committed-but-unseen token ----
    while True:
        lag = [i for i in dec if slots[i].draft_len < slots[i].length]
        if not lag:
            break
        tokens, pos, active = _blank(e.n_slots)
        for i in lag:
            s = slots[i]
            stream = s.req.prompt + s.generated
            tokens[i, 0] = stream[s.draft_len]
            pos[i] = s.draft_len
            active[i] = True
            draft.pool.ensure(i, s.draft_len + 1)
        draft.forward(1, tokens, pos, active)
        for i in lag:
            slots[i].draft_len += 1

    # ---- 2. propose: K draft tokens in one fused device call ------------
    dsnap = draft.pool.snapshot_states()
    tok0 = np.zeros((e.n_slots,), np.int32)
    pos = np.zeros((e.n_slots,), np.int32)
    active = np.zeros((e.n_slots,), bool)
    for i in dec:
        tok0[i] = slots[i].last_tok
        pos[i] = slots[i].length
        active[i] = True
        draft.pool.ensure(i, slots[i].length + K)
    toks = draft.propose(K, tok0, pos, active)        # (K, n_slots)
    proposals = {i: [int(toks[t, i]) for t in range(K)] for i in dec}
    for i in dec:
        slots[i].draft_len += K

    # ---- 3. verify: one (n_slots, K+1) full-model chunk ------------------
    snap = pool.snapshot_states()
    tokens = np.zeros((e.n_slots, K + 1), np.int32)
    pos = np.zeros((e.n_slots,), np.int32)
    active = np.zeros((e.n_slots,), bool)
    for i in dec:
        s = slots[i]
        tokens[i] = [s.last_tok] + proposals[i]
        pos[i] = s.length
        active[i] = True
        pool.ensure(i, s.length + K + 1)
    logits = eng._forward(K + 1, tokens, pos, active)
    targets = np.asarray(greedy_targets(logits))

    # ---- 4. accept (greedy or rejection-sampled) + commit ----------------
    emitted = 0
    reject_state: list[int] = []
    replay: dict[int, list[int]] = {}
    draft_reject: list[int] = []
    for i in dec:
        s = slots[i]
        length0 = s.length
        temp = s.req.sampling.temperature
        if temp == 0.0:
            a = accept_greedy(proposals[i], targets[i])
            emit = [int(targets[i, j]) for j in range(a + 1)]
        else:
            # stochastic request: rejection-sample against the verify
            # chunk's target distributions (greedy deterministic drafts =
            # point-mass proposals; see sampling.speculative_resample).
            # Token-by-token the emitted stream follows exactly the
            # distribution the non-speculative sampler draws from, though
            # the realized stream differs (different PRNG consumption).
            toks, cnt = eng._resample(
                jnp.asarray(proposals[i], jnp.int32),
                logits[i].astype(jnp.float32), eng._spec_key(i),
                temp, s.req.sampling.top_k)
            cnt = int(cnt)
            toks = np.asarray(toks)
            emit = [int(toks[j]) for j in range(cnt)]
            a = cnt - 1  # accepted drafts; the last emission is the
            #              resample / bonus token
        remaining = s.req.max_new - len(s.generated)
        emit = emit[:remaining]
        nacc = len(emit)
        # acceptance-rate accounting counts only drafts the verifier could
        # USE: on a request's final round max_new truncation caps usable
        # drafts at remaining - 1, and booking the rest as rejections would
        # bias the reported rate low even for a perfect draft
        eng.stats["draft_tokens"] += min(K, remaining - 1)
        eng.stats["accepted_tokens"] += nacc - 1
        if eng.obs.enabled:
            eng.obs.spec_accepted_hist.observe(float(nacc - 1))
        emitted += nacc
        s.generated.extend(emit)
        s.length = length0 + nacc
        s.last_tok = emit[-1]
        pool.truncate(i, s.length)
        if pool.has_state_kinds and nacc < K + 1:
            # the chunk integrated rejected inputs into wkv/lru state
            reject_state.append(i)
            replay[i] = [int(tokens[i, j]) for j in range(nacc)]
        if len(s.generated) >= s.req.max_new:
            continue  # retires next tick; its draft slot is released there
        if a >= K - 1:
            # every input the draft consumed (t0, d_1..d_{K-1}) was committed
            s.draft_len = length0 + K
        elif draft.pool.has_state_kinds:
            # draft state integrated rejected inputs: full rollback, the
            # restored snapshot is replayed by next round's catch-up
            draft_reject.append(i)
            s.draft_len = length0
            draft.pool.truncate(i, length0)
        else:
            # stateless draft caches keep the committed-correct prefix
            # (inputs t0, d_1..d_a ARE the emitted stream), so the next
            # round starts with zero catch-up work
            s.draft_len = length0 + a + 1
            draft.pool.truncate(i, length0 + a + 1)
    if draft_reject:
        draft.pool.restore_states(dsnap, draft_reject)

    # ---- 5. restore + replay recurrent state of rejected slots ----------
    if reject_state:
        pool.restore_states(snap, reject_state)
        for t in range(max(len(replay[i]) for i in reject_state)):
            tokens, pos, active = _blank(e.n_slots)
            for i in reject_state:
                if t >= len(replay[i]):
                    continue
                tokens[i, 0] = replay[i][t]
                pos[i] = slots[i].length - len(replay[i]) + t
                active[i] = True
            eng._forward(1, tokens, pos, active)

    eng.stats["spec_rounds"] += 1
    return emitted
