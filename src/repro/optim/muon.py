"""Muon (Jordan et al. 2024): momentum + Newton-Schulz orthogonalization for
2D hidden-layer weights; AdamW handles everything else (embeddings, norms,
heads). >2D leaves (scan-stacked layers, per-expert weights) are treated
matrix-wise over their last two dims — Newton-Schulz batches over leading dims.

Used by the nanochat-style reproduction (paper Sec. 6.2)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adamw

NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5


def newton_schulz(g: jax.Array, steps: int = NS_STEPS) -> jax.Array:
    """Approximate UV^T of the matrix (last two dims; leading dims batched)."""
    a, b, c = NS_COEFFS
    x = g.astype(jnp.float32)
    transpose = x.shape[-2] > x.shape[-1]
    if transpose:
        x = x.swapaxes(-1, -2)
    x = x / (jnp.linalg.norm(x, axis=(-2, -1), keepdims=True) + 1e-7)
    for _ in range(steps):
        s = x @ x.swapaxes(-1, -2)
        x = a * x + (b * s + c * (s @ s)) @ x
    if transpose:
        x = x.swapaxes(-1, -2)
    return x


class MuonState(NamedTuple):
    step: jax.Array
    mom: dict                # momentum (used only on matrix params)
    adam: adamw.AdamWState   # for non-matrix params


def partition_mask(params):
    """pytree of *static* bools: True -> Muon, False -> AdamW."""
    def walk(path, p):
        name = "/".join(str(k) for k in path).lower()
        if p.ndim < 2:
            return False
        return not any(t in name for t in ("embed", "head"))
    return jax.tree_util.tree_map_with_path(walk, params)


def init(params) -> MuonState:
    return MuonState(jnp.zeros((), jnp.int32),
                     jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                     adamw.init(params))


def update(grads, state: MuonState, params, *, lr, momentum=0.95,
           adam_lr_scale=0.3, weight_decay=0.0):
    mask = partition_mask(params)
    step = state.step + 1

    def muon_upd(g, m, p, use):
        if not use:  # static decision — no traced branching
            return (m, p)
        gf = g.astype(jnp.float32)
        m = momentum * m + gf
        upd = newton_schulz(momentum * m + gf)  # nesterov-style
        scale = jnp.sqrt(jnp.maximum(1.0, p.shape[-2] / p.shape[-1])) * 0.2
        newp = (p.astype(jnp.float32) - lr * scale * upd
                - lr * weight_decay * p.astype(jnp.float32))
        return (m, newp.astype(p.dtype))

    out = jax.tree.map(muon_upd, grads, state.mom, params, mask)
    mom = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    p_muon = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))

    p_adam, adam_state = adamw.update(grads, state.adam, params,
                                      lr=lr * adam_lr_scale,
                                      weight_decay=weight_decay)
    new_params = jax.tree.map(lambda pm, pa, u: pm if u else pa,
                              p_muon, p_adam, mask)
    return new_params, MuonState(step, mom, adam_state)
