"""AdamW with fp32 state (paper Table 4: FP32 optimizer/accumulators) and
decoupled weight decay. Pure-pytree implementation: state sharding follows
parameter sharding under pjit (ZeRO-by-construction)."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), z,
                      jax.tree.map(jnp.copy, z))


def update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    mu = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step, mu, nu)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), n
