"""LR schedules: cosine with warmup (paper Table 4) and WSD (nanochat Sec. 6.2)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, base_lr: float, total_steps: int, warmup_frac: float = 0.1,
                  final_frac: float = 0.0):
    warm = max(int(total_steps * warmup_frac), 1)
    s = jnp.asarray(step, jnp.float32)
    wu = s / warm
    prog = jnp.clip((s - warm) / max(total_steps - warm, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(s < warm, wu, cos)


def wsd(step, *, base_lr: float, total_steps: int, warmup_frac: float = 0.02,
        decay_frac: float = 0.2):
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat, linear decay tail."""
    warm = max(int(total_steps * warmup_frac), 1)
    decay_start = int(total_steps * (1 - decay_frac))
    s = jnp.asarray(step, jnp.float32)
    wu = s / warm
    dec = 1.0 - (s - decay_start) / max(total_steps - decay_start, 1)
    lr = jnp.where(s < warm, wu, jnp.where(s < decay_start, 1.0, jnp.clip(dec, 0.0, 1.0)))
    return base_lr * lr


def get(name: str):
    return {"cosine": warmup_cosine, "wsd": wsd}[name]
