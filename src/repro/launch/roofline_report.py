"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.roofline_report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os


def fmt_t(s: float) -> str:
    return f"{s * 1e3:.2f}"


def load(dirname: str) -> list[dict]:
    out = []
    for f in sorted(os.listdir(dirname)):
        if f.endswith(".json"):
            with open(os.path.join(dirname, f)) as fh:
                out.append(json.load(fh))
    return out


def dryrun_table(rows: list[dict]) -> str:
    lines = ["| arch | shape | mesh | scheme | compile | args GiB/dev | temp GiB/dev | collectives |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | SKIP | — | — | {r['skipped'][:60]} |")
            continue
        m = r["memory"]
        cc = r.get("collectives", {})
        cstr = " ".join(f"{k}:{int(v)}" for k, v in cc.items()
                        if k.endswith("_count"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['scheme']} "
            f"| {r['compile_s']:.0f}s | {m['args_bytes'] / 2**30:.2f} "
            f"| {m['temp_bytes'] / 2**30:.2f} | {cstr} |")
    return "\n".join(lines)


def roofline_table(rows: list[dict], mesh: str = "16x16") -> str:
    lines = ["| arch | shape | compute ms | memory ms | coll ms | bottleneck | useful-FLOPs ratio | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r or r.get("mesh") != mesh:
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(t['compute_s'])} "
            f"| {fmt_t(t['memory_s'])} | {fmt_t(t['collective_s'])} "
            f"| {r['bottleneck'].replace('_s', '')} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.dir)
    done = [r for r in rows if "skipped" not in r]
    skips = [r for r in rows if "skipped" in r]
    print(f"## Dry-run ({len(done)} compiled cells, {len(skips)} skips)\n")
    print(dryrun_table(rows))
    print(f"\n## Roofline ({args.mesh})\n")
    print(roofline_table(rows, args.mesh))


if __name__ == "__main__":
    main()
