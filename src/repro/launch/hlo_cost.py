"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

XLA's HloCostAnalysis (what `compiled.cost_analysis()` reports) counts each
while-loop body ONCE — with scan-over-layers models that undercounts FLOPs,
bytes and collective traffic by ~n_layers. This parser rebuilds the three
roofline inputs with loop trip counts applied:

  - flops:       dot ops, 2 * prod(out_shape) * prod(contracting_dims)
  - hbm_bytes:   per top-level op, operand bytes + output bytes (fusions
                 count their interface only — interior ops never touch HBM;
                 parameters / GTEs / tuples / constants / bitcasts are free)
  - wire_bytes:  collectives with ring-algorithm accounting (per device):
                 all-gather & all-to-all (g-1)/g*out; all-reduce 2(g-1)/g*out;
                 reduce-scatter (g-1)*out; collective-permute 1*out

Trip counts come from the loop condition region: scan lowers to
`while(cond: i < L)`, so the largest integer constant in the cond region is
the trip count. Nested loops multiply.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
                "f8e4m3b11fnuz": 1, "s4": 1, "u4": 1}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{")
_OP_LINE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}\s/*]+?)\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OPERAND = re.compile(r"%([\w.\-]+)")
_GROUPS_A = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_B = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CALLED = re.compile(r"(?:condition|body|to_apply|calls)=%([\w.\-]+)")

FREE_OPS = {"parameter", "get-tuple-element", "tuple", "constant", "bitcast",
            "after-all", "iota", "partition-id", "replica-id"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start"}


def _shape_elems_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str   # operand list + attributes (raw tail of the line)


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)   # name -> type_str
    ops: list = field(default_factory=list)


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.strip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1))
                for part in m.group(2).split(","):
                    part = part.strip()
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        cur.params[pname.strip().lstrip("%")] = ptype.strip()
                comps[cur.name] = cur
            continue
        if line == "}":
            cur = None
            continue
        m = _OP_LINE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2).strip(), m.group(3),
                              m.group(4)))
    return comps


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_by_type: dict = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.wire_bytes += o.wire_bytes
        for k, v in o.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.hbm_bytes * f, self.wire_bytes * f,
                    {k: v * f for k, v in self.coll_by_type.items()})


def _group_size(rest: str, default: int = 2) -> int:
    m = _GROUPS_A.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_B.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def _trip_count(comps: dict, cond_name: str) -> int:
    """Largest integer constant in the loop-condition region = trip count
    (scan lowers to `while (i < L)` with i starting at 0)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"(\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


SLICING = {"dynamic-slice", "slice", "gather"}


class CostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: dict[str, Cost] = {}
        self._fusion_memo: dict[str, tuple[list[float], float | None]] = {}
        # symbol tables: op name -> type string, per computation
        self._types: dict[str, dict[str, str]] = {}
        for cname, comp in self.comps.items():
            t = dict(comp.params)
            for op in comp.ops:
                t[op.name] = op.type_str
            self._types[cname] = t

    def _operands(self, rest: str) -> list[str]:
        head = rest.split("), ")[0] if "), " in rest else rest.split(")")[0]
        return _OPERAND.findall(head)

    def _operand_bytes(self, comp: str, rest: str) -> int:
        table = self._types[comp]
        return sum(_shape_elems_bytes(table.get(r, "")) for r in self._operands(rest))

    def _fusion_charges(self, fname: str):
        """Real HBM traffic of a fusion: per-parameter charged bytes + output
        charge. A parameter consumed by a (dynamic-)slice/gather inside the
        fusion is only read at slice-output size (the scan's per-layer param
        slicing would otherwise be charged the full stacked array every
        iteration — a ~n_layers x overcount). A fusion rooted in
        dynamic-update-slice writes only the update region (+aliases the
        buffer), not the whole output."""
        if fname in self._fusion_memo:
            return self._fusion_memo[fname]
        comp = self.comps.get(fname)
        if comp is None:
            self._fusion_memo[fname] = ([], None)
            return self._fusion_memo[fname]
        order = list(comp.params.keys())
        charge = {p: float(_shape_elems_bytes(t)) for p, t in comp.params.items()}
        table = self._types[fname]
        out_charge = None
        for op in comp.ops:
            refs = self._operands(op.rest)
            if op.opcode in SLICING and refs:
                src = refs[0]
                if src in charge:
                    charge[src] = min(charge[src],
                                      float(_shape_elems_bytes(op.type_str)))
            elif op.opcode == "dynamic-update-slice" and len(refs) >= 2:
                upd_b = float(_shape_elems_bytes(table.get(refs[1], "")))
                out_charge = 2.0 * upd_b  # read-modify-write of the region
                if refs[0] in charge:
                    charge[refs[0]] = 0.0  # buffer aliased in place
        self._fusion_memo[fname] = ([charge[p] for p in order], out_charge)
        return self._fusion_memo[fname]

    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        total = Cost()
        for op in comp.ops:
            oc = op.opcode
            if oc in FREE_OPS:
                continue
            out_b = _shape_elems_bytes(op.type_str)
            if oc == "while":
                called = dict(re.findall(r"(condition|body)=%([\w.\-]+)", op.rest))
                trips = _trip_count(self.comps, called.get("condition", ""))
                body = self.computation_cost(called.get("body", ""))
                total += body.scaled(trips)
                # loop state stays resident; count one pass of the tuple
                total.hbm_bytes += out_b
                continue
            if oc == "call":
                m = _CALLED.search(op.rest)
                if m:
                    total += self.computation_cost(m.group(1))
                continue
            if oc == "conditional":
                for branch in re.findall(r"%([\w.\-]+)", op.rest.split("),")[-1]):
                    if branch in self.comps:
                        total += self.computation_cost(branch)
                continue
            if oc == "fusion":
                m = _CALLED.search(op.rest)
                charges, out_charge = self._fusion_charges(m.group(1)) if m else ([], None)
                refs = self._operands(op.rest)
                in_b = 0.0
                for i, r in enumerate(refs):
                    if i < len(charges):
                        in_b += charges[i]
                    else:
                        in_b += _shape_elems_bytes(self._types[comp.name].get(r, ""))
                total.hbm_bytes += (out_charge if out_charge is not None else out_b) + in_b
                continue
            if oc in SLICING:
                total.hbm_bytes += 2.0 * out_b  # read slice + write slice
                continue
            if oc == "dynamic-update-slice":
                refs = self._operands(op.rest)
                upd = _shape_elems_bytes(self._types[comp.name].get(
                    refs[1] if len(refs) > 1 else "", ""))
                total.hbm_bytes += 2.0 * upd
                continue
            in_b = self._operand_bytes(comp.name, op.rest)
            total.hbm_bytes += out_b + in_b
            if oc == "dot":
                dims = _shape_dims(op.type_str)
                out_elems = 1
                for d in dims:
                    out_elems *= d
                # contracting size from lhs operand type
                m = _CONTRACT.search(op.rest)
                refs = _OPERAND.findall(op.rest)
                k = 1
                if m and refs:
                    lhs_t = self._types[comp.name].get(refs[0], "")
                    lhs_dims = _shape_dims(lhs_t)
                    for ci in m.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            k *= lhs_dims[int(ci)]
                total.flops += 2.0 * out_elems * k
            elif oc in ("convolution",):
                total.flops += 2.0 * _shape_elems_bytes(op.type_str)  # coarse
            elif oc.rstrip("-start") in COLLECTIVES or oc in COLLECTIVES:
                base = oc[:-6] if oc.endswith("-start") else oc
                g = _group_size(op.rest)
                if base == "all-reduce":
                    wire = 2 * out_b * (g - 1) / g
                elif base == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif base == "collective-permute":
                    wire = out_b
                else:
                    wire = out_b * (g - 1) / g
                total.wire_bytes += wire
                total.coll_by_type[base] = total.coll_by_type.get(base, 0.0) + wire
                total.coll_by_type[base + "_count"] = \
                    total.coll_by_type.get(base + "_count", 0) + 1
        self._memo[name] = total
        return total

    def entry_cost(self) -> Cost:
        for name, comp in self.comps.items():
            # the entry is the one whose name starts with 'main'
            if name.startswith("main"):
                return self.computation_cost(name)
        # fallback: largest computation
        best, bc = None, -1
        for name, comp in self.comps.items():
            if len(comp.ops) > bc:
                best, bc = name, len(comp.ops)
        return self.computation_cost(best)


def analyze(hlo_text: str) -> Cost:
    return CostModel(hlo_text).entry_cost()
