import os
# 512 host-platform placeholder devices for the production mesh; backend
# optimization level 0 halves compile time with IDENTICAL cost-model output
# (verified: flops/bytes/collectives match default opt bit-for-bit — the
# SPMD partitioner runs either way and we never execute the code).
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           "--xla_backend_optimization_level=0")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production mesh, print memory_analysis / cost_analysis, and extract the
roofline terms (compute / memory / collective) from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

The two XLA_FLAGS lines above MUST precede any jax import: this container has
one CPU device and the 16x16(x2-pod) mesh needs 512 host-platform
placeholders; jax locks the device count on first init. Smoke tests and
benches never import this module, so they still see 1 device.
"""

import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.base import SHAPES
from repro.dist import sharding as SH
from repro.launch import hlo_cost
from repro.launch import specs as SPECS
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.serve.decode import (make_paged_serve_step, make_prefill_step,
                                make_sharded_serve_step)
from repro.train.train_step import make_train_step

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "s4": 1, "u4": 1}

def model_flops(cfg, shape_name: str) -> float:
    """6*N_active*D (train) or 2*N_active*tokens (decode) — 'useful' FLOPs."""
    cell = SHAPES[shape_name]
    p = SPECS.param_specs(cfg)
    total = sum(x.size for x in jax.tree.leaves(p))
    active = total
    if cfg.moe:
        # routed experts beyond top_k are inactive per token
        def expert_count(path, leaf):
            return leaf.size if ("ff/w" in path and leaf.ndim >= 3) else 0
        flat = jax.tree_util.tree_flatten_with_path(p)[0]
        e_params = sum(l.size for pth, l in flat
                       if "ff" in "/".join(str(k) for k in pth)
                       and l.ndim >= 4)
        active = total - e_params * (1 - cfg.moe.top_k / cfg.moe.n_routed)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6 if cell.kind == "train" else 2
    return mult * active * tokens, total, active


def run_cell(arch: str, shape: str, *, multi_pod: bool, scheme: str,
             fsdp: bool | None = None, remat: bool = True,
             hints: bool | None = None, verbose: bool = True,
             serve_sharded: bool = False) -> dict:
    cfg = registry.get(arch)
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape, "skipped":
                "full-attention arch; 500k decode requires sub-quadratic "
                "attention (DESIGN.md Section 4)"}
    if serve_sharded and cell.kind != "decode":
        return {"arch": arch, "shape": shape, "skipped":
                "--serve-sharded applies to decode cells only"}

    # big models need FSDP for optimizer state; small ones stay TP-only
    n_params = sum(x.size for x in jax.tree.leaves(SPECS.param_specs(cfg)))
    if fsdp is None:
        fsdp = n_params > 3e9
    lm.REMAT = remat

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    # Perf iteration 1: Megatron-layout sharding hints in qlinear (see
    # core/linear.py MESH_AXES). Baseline sweep runs without; opt-in via
    # --hints / REPRO_SHARDING_HINTS=1.
    if hints is None:
        hints = os.environ.get("REPRO_SHARDING_HINTS", "0") == "1"
    from repro.core import linear as QL
    if hints:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_size = 1
        for a in dp_axes:
            dp_size *= sizes[a]
        QL.MESH_AXES = {"dp": dp_axes if len(dp_axes) > 1 else dp_axes[0],
                        "tp": "model", "dp_size": dp_size,
                        "tp_size": sizes["model"]}
    else:
        QL.MESH_AXES = None
    t0 = time.time()

    with mesh:
        params_s = SPECS.param_specs(cfg)
        if cell.kind == "train":
            init_state, train_step = make_train_step(
                cfg, scheme, total_steps=10_000, microbatches=1)
            state_s = jax.eval_shape(init_state, params_s)
            state_sh = SH.state_shardings(state_s, mesh, fsdp=fsdp)
            batch_s = SPECS.train_batch_specs(cfg, shape)
            batch_sh = SH.input_shardings(batch_s, mesh)
            jitted = jax.jit(train_step,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state_s, batch_s)
        elif cell.kind == "prefill":
            fn = make_prefill_step(cfg, scheme)
            batch_s, cache_s = SPECS.prefill_specs(cfg, shape)
            p_sh = SH.state_shardings(params_s, mesh, fsdp=fsdp)
            c_sh = SH.cache_shardings(cache_s, mesh)
            b_sh = SH.input_shardings(batch_s, mesh)
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(params_s, cache_s, batch_s)
        elif cell.kind == "decode" and serve_sharded:
            # the SHARDED engine step (serve/decode.make_sharded_serve_step):
            # slot-affine pool + per-slot LOCAL block tables under a manual
            # shard_map over "data", prequantized (packed NVFP4) weights +
            # head under GSPMD on "model". The before/after pair with the
            # baseline decode cell below is the PR's acceptance measurement:
            # the baseline all-gathers the pool every step (XLA cannot prove
            # a replicated table's rows are device-local); slot affinity
            # makes the same gather provably local, so the only collectives
            # left are activation-sized "model" reductions.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.serve.prequant import prequantize_specs
            data = dict(mesh.shape).get("data", 1)
            if cell.global_batch % data:
                return {"arch": arch, "shape": shape, "skipped":
                        f"decode batch {cell.global_batch} not divisible by "
                        f"the mesh data axis ({data}): slot sharding needs "
                        "equal shard extents"}
            fn = make_sharded_serve_step(cfg, scheme, mesh)
            in_s, cache_s = SPECS.paged_decode_specs(cfg, shape)
            params_q = prequantize_specs(params_s, cfg, scheme)
            p_sh = SH.serve_param_shardings(params_q, mesh)
            c_sh = SH.serve_cache_shardings(cache_s, mesh)
            d_sh = NamedSharding(mesh, P("data"))
            jitted = jax.jit(fn, in_shardings=(
                p_sh, c_sh, d_sh, d_sh, d_sh, d_sh))
            lowered = jitted.lower(params_q, cache_s, in_s["table"],
                                   in_s["tokens"], in_s["pos"], in_s["active"])
        else:  # decode — the engine's paged step (pos vector + block table),
            # so the cost model prices the pool gather/scatter traffic the
            # serving hot path actually moves (not the legacy dense cache).
            # NOTE the collective term it surfaces is real and damning: the
            # generic cache sharding puts the pool's BLOCK axis on "data",
            # and with a replicated block table XLA cannot prove any row's
            # blocks are device-local, so the gather all-gathers the pool
            # every step. That priced pain is what the --serve-sharded cell
            # above makes local (slot-affine pool sharding, per-slot host
            # tables) — and what the paged_attention kernel replaces
            # wholesale on-device.
            fn = make_paged_serve_step(cfg, scheme)
            in_s, cache_s = SPECS.paged_decode_specs(cfg, shape)
            p_sh = SH.state_shardings(params_s, mesh, fsdp=fsdp)
            c_sh = SH.cache_shardings(cache_s, mesh)
            i_sh = SH.input_shardings(in_s, mesh)
            jitted = jax.jit(fn, in_shardings=(
                p_sh, c_sh, i_sh["table"], i_sh["tokens"], i_sh["pos"],
                i_sh["active"]), out_shardings=(None, c_sh))
            lowered = jitted.lower(params_s, cache_s, in_s["table"],
                                   in_s["tokens"], in_s["pos"], in_s["active"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # some jax versions: one dict/program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # trip-count-aware cost model (XLA's cost_analysis counts scan bodies
    # once; hlo_cost multiplies by while trip counts) — see hlo_cost.py
    hc = hlo_cost.analyze(hlo)
    coll = dict(hc.coll_by_type)
    coll["total"] = hc.wire_bytes

    flops_dev = hc.flops
    bytes_dev = hc.hbm_bytes
    mf, n_total, n_active = model_flops(cfg, shape)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll.get("total", 0.0) / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape, "scheme": scheme,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "fsdp": fsdp, "remat": remat,
        "params_total": n_total, "params_active": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
        "xla_flops_per_device_1trip": float(cost.get("flops", 0.0)),
        "xla_bytes_per_device_1trip": float(cost.get("bytes accessed", 0.0)),
        "collectives": {k: v for k, v in coll.items()},
        "memory": {
            "args_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "model_flops": mf,
        "useful_flops_ratio": mf / max(flops_dev * chips, 1.0),
        "roofline": terms,
        "bottleneck": bottleneck,
        # 1.0 == perfectly compute-bound: the dominant term IS the matmuls
        "roofline_fraction": t_compute / max(t_compute, t_memory, t_coll, 1e-30),
    }
    if cell.kind == "decode":
        # pool-collective accounting: the acceptance bar for slot-affine
        # sharding is that NO decode step moves pool-scale collectives.
        # Yardstick: one "data"-shard's pool slice — the baseline paged
        # step's replicated-table gather moves a multiple of it over the
        # wire every step (llama_200m decode_32k: 37.8 GB/dev ~ 3x the
        # 13 GB slice), while the slot-affine sharded step's remaining
        # collectives are activation-sized (~4 MB/dev, "model" reductions)
        pool_bytes = sum(x.size * x.dtype.itemsize
                         for x in jax.tree.leaves(cache_s))
        pool_slice = pool_bytes / max(dict(mesh.shape).get("data", 1), 1)
        result["serve_sharded"] = serve_sharded
        result["pool_bytes_global"] = pool_bytes
        result["pool_bytes_per_data_shard"] = pool_slice
        result["no_pool_allgather"] = bool(
            coll.get("total", 0.0) < 0.1 * pool_slice)
    if verbose:
        print(f"[dryrun] {arch} x {shape} on {result['mesh']} ({scheme}) — "
              f"compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB per device")
        print(f"  cost_analysis: {flops_dev:.3e} flops/dev, "
              f"{bytes_dev:.3e} bytes/dev")
        print(f"  collectives (wire B/dev): " + ", ".join(
            f"{k}={v:.2e}" for k, v in coll.items() if not k.endswith('_count')))
        print(f"  roofline: compute={t_compute*1e3:.2f}ms "
              f"memory={t_memory*1e3:.2f}ms coll={t_coll*1e3:.2f}ms "
              f"-> bottleneck={bottleneck}")
    return result


ALL_CELLS = [(a, s) for a in registry.names() if a != "llama_200m"
             for s in SHAPES]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--scheme", default="quartet2")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--hints", action="store_true",
                    help="qlinear Megatron-layout sharding hints (Perf iter 1)")
    ap.add_argument("--serve-sharded", action="store_true",
                    help="decode cells lower the slot-affine SHARDED serving "
                         "step (shard_map over 'data', prequantized weights "
                         "over 'model') instead of the baseline paged step")
    ap.add_argument("--fsdp", default=None, choices=["on", "off"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--jobs", type=int, default=2)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        # subprocess per cell: isolates compile memory, allows parallelism
        jobs = []
        for arch, shape in ALL_CELLS:
            for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}_{args.scheme}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--scheme", args.scheme, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                if args.no_remat:
                    cmd.append("--no-remat")
                jobs.append((tag, cmd))
        running: list = []
        while jobs or running:
            while jobs and len(running) < args.jobs:
                tag, cmd = jobs.pop(0)
                print(f"[driver] start {tag} ({len(jobs)} queued)")
                running.append((tag, subprocess.Popen(
                    cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT)))
            done = [(t, p) for t, p in running if p.poll() is not None]
            running = [(t, p) for t, p in running if p.poll() is None]
            for tag, p in done:
                out = p.stdout.read().decode()
                status = "ok" if p.returncode == 0 else f"FAIL rc={p.returncode}"
                print(f"[driver] {tag}: {status}")
                if p.returncode != 0:
                    print(out[-2000:])
            time.sleep(2)
        return

    assert args.arch and args.shape, "--arch/--shape or --all"
    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   scheme=args.scheme,
                   fsdp=None if args.fsdp is None else args.fsdp == "on",
                   remat=not args.no_remat,
                   hints=True if args.hints else None,
                   serve_sharded=args.serve_sharded)
    tag = (f"{args.arch}_{args.shape}_"
           f"{'2x16x16' if args.multi_pod else '16x16'}_{args.scheme}"
           + ("_hints" if (args.hints or os.environ.get('REPRO_SHARDING_HINTS') == '1') else "")
           + ("_sharded" if args.serve_sharded else ""))
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(res, f, indent=1)
    print(f"[dryrun] wrote {tag}.json")


if __name__ == "__main__":
    main()
