"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama_200m --reduced \
        --scheme quartet2 --steps 500 --ckpt /tmp/run1

On a real multi-host TPU job this binary runs once per host (jax.distributed
initializes from the TPU environment); here it drives the same code paths on
CPU. Checkpoints are mesh-elastic (see checkpoint/)."""

from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import lm
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the architecture")
    ap.add_argument("--scheme", default="quartet2")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "muon"])
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--steps", type=int, default=500)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    corpus = SyntheticCorpus(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        d_model=cfg.d_model, emit_embeds=cfg.input_mode == "embeds"))
    init_state, train_step = make_train_step(
        cfg, args.scheme, optimizer=args.optimizer, schedule=args.schedule,
        base_lr=args.lr, total_steps=args.steps,
        microbatches=args.microbatches)
    state = init_state(lm.init(cfg, jax.random.PRNGKey(0)))
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=max(args.steps // 5, 50), log_every=10),
        jax.jit(train_step), corpus)
    trainer.run(state, resume=args.resume)


if __name__ == "__main__":
    main()
