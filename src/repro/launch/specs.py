"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero device allocation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig
from repro.models import lm

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: str) -> dict:
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    out = {}
    if cfg.input_mode == "embeds":
        out["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec or cfg.input_mode == "tokens":
        out["tokens"] = SDS((b, s), jnp.int32)
    out["labels"] = SDS((b, s), jnp.int32)
    return out


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    """ShapeDtypeStructs of the decode cache (eval_shape over init_cache)."""
    if cfg.enc_dec:
        return jax.eval_shape(
            lambda: lm.init_encdec_cache(cfg, batch, max_len, enc_len=max_len))
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_len))


def prefill_specs(cfg: ArchConfig, shape: str):
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec or cfg.input_mode == "tokens":
        batch["tokens"] = SDS((b, s), jnp.int32)
    return batch, cache_specs(cfg, b, s)


def decode_specs(cfg: ArchConfig, shape: str):
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    tokens = SDS((b, 1), jnp.int32)
    return tokens, cache_specs(cfg, b, s)


def param_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda: lm.init(cfg, jax.random.PRNGKey(0)))
