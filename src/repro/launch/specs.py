"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero device allocation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ArchConfig
from repro.models import lm

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ArchConfig, shape: str) -> dict:
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    out = {}
    if cfg.input_mode == "embeds":
        out["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec or cfg.input_mode == "tokens":
        out["tokens"] = SDS((b, s), jnp.int32)
    out["labels"] = SDS((b, s), jnp.int32)
    return out


def cache_specs(cfg: ArchConfig, batch: int, max_len: int):
    """ShapeDtypeStructs of the decode cache (eval_shape over init_cache)."""
    if cfg.enc_dec:
        return jax.eval_shape(
            lambda: lm.init_encdec_cache(cfg, batch, max_len, enc_len=max_len))
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_len))


def prefill_specs(cfg: ArchConfig, shape: str):
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec or cfg.input_mode == "tokens":
        batch["tokens"] = SDS((b, s), jnp.int32)
    return batch, cache_specs(cfg, b, s)


PAGED_BLOCK = 16  # dry-run pool block size (matches EngineConfig default)


def paged_decode_specs(cfg: ArchConfig, shape: str, *,
                       block_size: int = PAGED_BLOCK):
    """Input stand-ins for the ENGINE's paged decode step — per-slot position
    vector, active mask, (n_slots, max_blocks) block table, and pool-shaped
    cache leaves — so dry-run decode cells price the block-table
    gather/scatter traffic the serving hot path actually moves.

    Pure-lattn stacks size the pool at O(window) blocks per slot (the
    sliding-window reclamation bound in serve/kv_pool.py), which is exactly
    why long_500k decode state stays sublinear for the hybrid archs.

    The --serve-sharded decode cells reuse these structs unchanged: shapes
    are identical under slot-affine sharding — only the table's VALUE
    semantics shift to shard-local physical indices (KVPool.table_device),
    which a ShapeDtypeStruct never sees."""
    from repro.serve import kv_pool as KV
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    max_blocks = -(-s // block_size)
    window = KV.reclaim_window(cfg)
    blocks_per_slot = (max_blocks if window is None
                       else min(max_blocks, -(-window // block_size) + 1))
    n_blocks = b * blocks_per_slot
    cache = jax.eval_shape(
        lambda: KV.init_cache(cfg, b, s, paged=True, n_blocks=n_blocks,
                              block_size=block_size))
    return {
        "tokens": SDS((b, 1), jnp.int32),
        "pos": SDS((b,), jnp.int32),
        "active": SDS((b,), jnp.bool_),
        "table": SDS((b, max_blocks), jnp.int32),
    }, cache


def param_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda: lm.init(cfg, jax.random.PRNGKey(0)))
