"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

A FUNCTION, not a module constant, so importing never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    if hasattr(jax.sharding, "AxisType"):  # newer jax wants explicit Auto
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Elastic variant: any (pod, data, model) factorization of the job's
    device count (checkpoints are mesh-independent, see checkpoint/)."""
    return _mk(shape, axes)


def make_serve_mesh(data: int, model: int = 1):
    """(data, model) mesh for the mesh-sharded serving engine
    (`EngineConfig(mesh=...)`): decode slots + the slot-affine KV pool split
    over "data", packed weights over "model". Tests simulate `data=2` on CPU
    via `--xla_force_host_platform_device_count` (set before any jax import;
    tests/conftest.py does this for the whole suite)."""
    return _mk((data, model), ("data", "model"))
